//! Bench: factorisation-as-a-service under open-loop load.
//!
//! Two regimes, both appended as JSON rows to `BENCH_sched.json`:
//!
//! * `"source": "serve"` — the deterministic virtual-time serving
//!   model ([`gprm::serve::ServeModel`]) sweeping offered load from
//!   20% to 400% of the pool's saturation rate at the paper-scale
//!   mixed factorisation stream (NB=16/BS=16, 8 workers, shed bound
//!   64, 2000 requests, seed 1). These are the committed baselines:
//!   all-integer cycle arithmetic, so every row re-derives
//!   digit-for-digit on any platform.
//! * `"source": "serve-host"` — a real loopback `gprm serve` loop
//!   driven by the in-process open-loop load generator with digest
//!   verification on, at a below-saturation and an above-saturation
//!   offered rate (wall-clock; machine-dependent, not committed).
//!
//! `cargo bench --bench serve`

use gprm::serve::{
    loadgen, LoadConfig, Request, Response, ServeConfig, ServeModel,
    Server,
};
use std::io::Write as _;

const NB: usize = 16;
const BS: usize = 16;
const WORKERS: usize = 8;
const MAX_PENDING: usize = 64;
const REQUESTS: usize = 2000;
const SEED: u64 = 1;
const PCTS: [u64; 7] = [20, 50, 80, 95, 120, 200, 400];

struct ModelRow {
    pct: u64,
    offered: f64,
    achieved: f64,
    p50: u64,
    p99: u64,
    p999: u64,
    shed: usize,
    completed: usize,
}

impl ModelRow {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"serve mixed NB={NB} BS={BS}\", \
             \"source\": \"serve\", \"workers\": {WORKERS}, \
             \"exec\": \"model\", \"offered_pct\": {}, \
             \"offered_jobs_per_sec\": {:.1}, \
             \"achieved_jobs_per_sec\": {:.1}, \"p50_us\": {}, \
             \"p99_us\": {}, \"p999_us\": {}, \"shed\": {}, \
             \"completed\": {}}}",
            self.pct, self.offered, self.achieved, self.p50, self.p99,
            self.p999, self.shed, self.completed
        )
    }
}

/// Host loopback sizing: small jobs, verification on.
const HOST_NB: usize = 8;
const HOST_BS: usize = 8;
const HOST_WORKERS: usize = 4;
const HOST_REQUESTS: usize = 200;

struct HostRow {
    rate: f64,
    achieved: f64,
    p50: u64,
    p99: u64,
    p999: u64,
    shed: usize,
    completed: usize,
}

impl HostRow {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"serve mixed NB={HOST_NB} \
             BS={HOST_BS}\", \"source\": \"serve-host\", \
             \"workers\": {HOST_WORKERS}, \"exec\": \"host\", \
             \"offered_jobs_per_sec\": {:.1}, \
             \"achieved_jobs_per_sec\": {:.1}, \"p50_us\": {}, \
             \"p99_us\": {}, \"p999_us\": {}, \"shed\": {}, \
             \"completed\": {}}}",
            self.rate, self.achieved, self.p50, self.p99, self.p999,
            self.shed, self.completed
        )
    }
}

fn main() {
    println!(
        "### serve — open-loop offered load (mixed factorisation \
         stream)"
    );
    println!(
        "== serving model NB={NB} BS={BS}, {WORKERS} workers, shed \
         bound {MAX_PENDING}, {REQUESTS} requests (virtual time \
         @866 MHz) =="
    );
    let m = ServeModel::calibrate(WORKERS, NB, BS, MAX_PENDING);
    println!(
        "  calibrated: service {} cycles/job, makespan {} cycles",
        m.service, m.makespan
    );
    let mut mrows = Vec::new();
    for pct in PCTS {
        let gap = m.gap_for_offered_pct(pct);
        let o = m.run(gap, REQUESTS, SEED);
        let row = ModelRow {
            pct,
            offered: m.clock_hz / gap as f64,
            achieved: o.achieved_per_sec(),
            p50: o.percentile_us(500),
            p99: o.percentile_us(990),
            p999: o.percentile_us(999),
            shed: o.shed,
            completed: o.completed(),
        };
        println!(
            "  {pct:>4}% offered ({:>7.1}/s): achieved {:>7.1}/s  \
             p50 {:>7} p99 {:>7} p999 {:>7} us  shed {}",
            row.offered, row.achieved, row.p50, row.p99, row.p999,
            row.shed
        );
        mrows.push(row);
    }

    println!(
        "== host loopback NB={HOST_NB} BS={HOST_BS}, {HOST_WORKERS} \
         workers, {HOST_REQUESTS} requests, verify on (wall clock) =="
    );
    let server = Server::bind(
        "127.0.0.1:0",
        ServeConfig::new(HOST_WORKERS),
    )
    .expect("bind loopback");
    let addr = server.local_addr().expect("local addr");
    let run = std::thread::spawn(move || server.run());
    let mut hrows = Vec::new();
    let mut failed = false;
    for rate in [100.0f64, 800.0] {
        let cfg = LoadConfig {
            rate_per_sec: rate,
            requests: HOST_REQUESTS,
            conns: 4,
            nb: HOST_NB,
            bs: HOST_BS,
            seed: SEED,
            verify: true,
            ..LoadConfig::new(&addr.to_string())
        };
        let r = loadgen::run(&cfg).expect("loadgen run");
        let verdict = if r.pass() { "PASS" } else { "FAIL" };
        failed |= !r.pass();
        println!(
            "  {rate:>6.0}/s offered: achieved {:>7.1}/s  p50 {:>6} \
             p99 {:>6} p999 {:>6} us  busy {} done {} — {verdict}",
            r.achieved_per_sec,
            r.hist.p50(),
            r.hist.p99(),
            r.hist.p999(),
            r.busy,
            r.done
        );
        hrows.push(HostRow {
            rate,
            achieved: r.achieved_per_sec,
            p50: r.hist.p50(),
            p99: r.hist.p99(),
            p999: r.hist.p999(),
            shed: r.busy,
            completed: r.done,
        });
    }
    // Drain the server and make sure it acknowledges.
    let ack = gprm::serve::Client::connect(addr)
        .ok()
        .and_then(|mut c| c.request(&Request::Shutdown).ok());
    let stats = run.join().expect("serve thread");
    println!("  drained: ack={:?} stats={stats:?}", ack);
    failed |= !matches!(ack, Some(Response::ShuttingDown));

    // Append rows to the repo-root BENCH_sched.json (JSON lines; the
    // committed baselines carry the model rows).
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    {
        Ok(mut f) => {
            for r in &mrows {
                let _ = writeln!(f, "{}", r.json());
            }
            for r in &hrows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!(
                "\nappended {} rows to {path:?}",
                mrows.len() + hrows.len()
            );
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!("serve bench FAILED");
        std::process::exit(1);
    }
}
