//! Bench: mutex-scoreboard vs lock-free work-stealing executor on the
//! Fig-6 workload shape (NB=32, BS=16) at 1/2/4/8/16 workers — for
//! **every workload in the registry** (`sched::workload::registry`):
//! the engine is kernel-agnostic, so the race uses identical
//! machinery and adding a workload adds a table here with zero bench
//! edits. Reports tasks/sec and GFLOP/s (flops via each graph's op
//! table), host wall-clock on the omp runtime plus the tilesim
//! claim-cost models, appended as JSON rows to `BENCH_sched.json`
//! with a `workload` field (the committed baseline rows were produced
//! by the tilesim model; machines with real cores append
//! `host-wall-clock` rows next to them).
//!
//! `cargo bench --bench steal`

use gprm::apps::dataflow::{run_workload, DataflowRt};
use gprm::linalg::blocked::BlockedSparseMatrix;
use gprm::omp::OmpRuntime;
use gprm::sched::workload::{registry, Params, Workload};
use gprm::sched::{ExecOpts, TaskGraph};
use gprm::tilesim::{CostModel, DataflowSim, SchedModel};
use std::io::Write as _;

const NB: usize = 32;
const BS: usize = 16;
const WORKERS: [usize; 5] = [1, 2, 4, 8, 16];

struct Row {
    workload: &'static str,
    source: &'static str,
    workers: usize,
    exec: &'static str,
    secs: f64,
    tasks_per_sec: f64,
    gflops: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"{} NB={NB} BS={BS}\", \
             \"source\": \"{}\", \"workers\": {}, \"exec\": \"{}\", \
             \"secs\": {:.6}, \"tasks_per_sec\": {:.0}, \
             \"gflops\": {:.3}}}",
            self.workload, self.source, self.workers, self.exec,
            self.secs, self.tasks_per_sec, self.gflops
        )
    }
}

/// Race mutex vs steal for one registry entry: tilesim model rows +
/// host wall-clock rows (whole dataflow runs on fresh clones of the
/// declaration's canonical input; cloning is excluded from the timed
/// region). Returns true if stealing lost anywhere at >= 4 workers
/// (host rows).
fn bench_workload(
    w: &'static dyn Workload,
    p: &Params,
    graph: &TaskGraph,
    input: &BlockedSparseMatrix,
    rows: &mut Vec<Row>,
) -> bool {
    let workload = w.name();
    let n_tasks = graph.len();
    let total_flops = w.graph_flops(graph, BS);
    println!(
        "\n### {workload} NB={NB} BS={BS} — {n_tasks} tasks, {:.3} GFLOP",
        total_flops as f64 / 1e9
    );
    let hz = CostModel::default().clock_hz;
    println!("== tilesim model (virtual time @866 MHz) ==");
    for &workers in &WORKERS {
        for (name, sched) in [
            ("mutex", SchedModel::MutexScoreboard),
            ("steal", SchedModel::WorkSteal),
        ] {
            let r = DataflowSim::with_sched(workers, sched)
                .run_workload(w, p);
            let secs = r.cycles as f64 / hz;
            let row = Row {
                workload,
                source: "tilesim-model",
                workers,
                exec: name,
                secs,
                tasks_per_sec: n_tasks as f64 / secs,
                gflops: total_flops as f64 / secs / 1e9,
            };
            println!(
                "  {name:>5} @{workers:>2} workers: {secs:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
    }

    // Host wall-clock: whole dataflow runs, best of SAMPLES.
    const SAMPLES: usize = 5;
    let host_once = |rt: &OmpRuntime, exec: ExecOpts| -> f64 {
        let mut a = input.deep_clone();
        let t0 = std::time::Instant::now();
        run_workload(&DataflowRt::Omp(rt), w, &mut a, exec)
            .expect("bench dataflow run failed");
        let secs = t0.elapsed().as_secs_f64();
        gprm::bench::black_box(a.allocated_blocks());
        secs
    };
    println!("== host wall-clock (omp-backed dataflow driver) ==");
    for &workers in &WORKERS {
        let rt = OmpRuntime::new(workers);
        for (name, exec) in [
            ("mutex", ExecOpts::mutex_baseline()),
            ("steal", ExecOpts::default()),
        ] {
            host_once(&rt, exec); // warmup
            let mut best = f64::MAX;
            for _ in 0..SAMPLES {
                best = best.min(host_once(&rt, exec));
            }
            let row = Row {
                workload,
                source: "host-wall-clock",
                workers,
                exec: name,
                secs: best,
                tasks_per_sec: n_tasks as f64 / best,
                gflops: total_flops as f64 / best / 1e9,
            };
            println!(
                "  {name:>5} @{workers:>2} workers: {best:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
        rt.shutdown();
    }

    // Acceptance: work stealing must win on tasks/sec at >= 4 workers
    // (host rows; the tilesim rows assert the same in unit tests).
    let mut failed = false;
    for &workers in WORKERS.iter().filter(|&&workers| workers >= 4) {
        let tps = |exec: &str| {
            rows.iter()
                .find(|r| {
                    r.workload == workload
                        && r.source == "host-wall-clock"
                        && r.workers == workers
                        && r.exec == exec
                })
                .map(|r| r.tasks_per_sec)
                .unwrap()
        };
        let (m, s) = (tps("mutex"), tps("steal"));
        failed |= s <= m;
        println!(
            "  @{workers} workers: steal/mutex = {:.2}x {}",
            s / m,
            if s > m { "PASS" } else { "FAIL" }
        );
    }
    failed
}

fn main() {
    let p = Params::new(NB, BS);
    let mut rows: Vec<Row> = Vec::new();
    let mut failed = false;

    // Every registered workload races on the identical machinery.
    for w in registry() {
        let graph = w.graph(&p);
        let input = w.make_input(&p, 0);
        failed |= bench_workload(*w, &p, &graph, &input, &mut rows);
    }

    // Append all rows to the repo-root BENCH_sched.json (JSON lines;
    // the committed file carries the tilesim baseline rows). Anchored
    // via the manifest dir — `cargo bench` runs with cwd = rust/.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!("\nappended {} rows to {path:?}", rows.len());
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!("steal bench FAILED: work stealing lost at >= 4 workers");
        std::process::exit(1);
    }
}
