//! Bench: mutex-scoreboard vs lock-free work-stealing executor on the
//! Fig-6 workload (NB=32, BS=16) at 1/2/4/8/16 workers — tasks/sec and
//! GFLOP/s (via `kernel_flops`), host wall-clock on both runtimes plus
//! the tilesim claim-cost models, appended as JSON rows to
//! `BENCH_sched.json` (the committed baseline rows in the repo root
//! were produced by the tilesim model; machines with real cores append
//! `host-wall-clock` rows next to them).
//!
//! `cargo bench --bench steal`

use gprm::apps::sparselu::{sparselu_dataflow, DataflowRt, LuRunConfig};
use gprm::linalg::genmat::{genmat, genmat_pattern};
use gprm::linalg::lu::kernel_flops;
use gprm::omp::OmpRuntime;
use gprm::sched::{ExecOpts, TaskGraph};
use gprm::tilesim::{CostModel, DataflowSim, SchedModel};
use std::io::Write as _;

const NB: usize = 32;
const BS: usize = 16;
const WORKERS: [usize; 5] = [1, 2, 4, 8, 16];

struct Row {
    source: &'static str,
    workers: usize,
    exec: &'static str,
    secs: f64,
    tasks_per_sec: f64,
    gflops: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"sparselu NB={NB} BS={BS}\", \
             \"source\": \"{}\", \"workers\": {}, \"exec\": \"{}\", \
             \"secs\": {:.6}, \"tasks_per_sec\": {:.0}, \
             \"gflops\": {:.3}}}",
            self.source, self.workers, self.exec, self.secs,
            self.tasks_per_sec, self.gflops
        )
    }
}

fn main() {
    let graph = TaskGraph::sparselu(&genmat_pattern(NB), NB);
    let n_tasks = graph.len();
    let total_flops: u64 =
        graph.tasks().iter().map(|t| kernel_flops(t.op, BS)).sum();
    println!(
        "steal bench: SparseLU NB={NB} BS={BS} — {n_tasks} tasks, {:.3} GFLOP",
        total_flops as f64 / 1e9
    );
    let mut rows: Vec<Row> = Vec::new();

    // Tilesim claim-cost models (deterministic; these are the baseline
    // rows committed in BENCH_sched.json).
    let hz = CostModel::default().clock_hz;
    println!("\n== tilesim model (virtual time @866 MHz) ==");
    for &w in &WORKERS {
        for (name, sched) in [
            ("mutex", SchedModel::MutexScoreboard),
            ("steal", SchedModel::WorkSteal),
        ] {
            let r = DataflowSim::with_sched(w, sched).run_sparselu(NB, BS);
            let secs = r.cycles as f64 / hz;
            let row = Row {
                source: "tilesim-model",
                workers: w,
                exec: name,
                secs,
                tasks_per_sec: n_tasks as f64 / secs,
                gflops: total_flops as f64 / secs / 1e9,
            };
            println!(
                "  {name:>5} @{w:>2} workers: {secs:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
    }

    // Host wall-clock: whole dataflow factorisations, best of SAMPLES.
    const SAMPLES: usize = 5;
    println!("\n== host wall-clock (omp-backed dataflow driver) ==");
    let a0 = genmat(NB, BS);
    for &w in &WORKERS {
        let rt = OmpRuntime::new(w);
        for (name, exec) in [
            ("mutex", ExecOpts::mutex_baseline()),
            ("steal", ExecOpts::default()),
        ] {
            let cfg = LuRunConfig { exec, ..Default::default() };
            // Warmup.
            let mut a = a0.deep_clone();
            sparselu_dataflow(&DataflowRt::Omp(&rt), &mut a, &cfg);
            let mut best = f64::MAX;
            for _ in 0..SAMPLES {
                let mut a = a0.deep_clone();
                let t0 = std::time::Instant::now();
                sparselu_dataflow(&DataflowRt::Omp(&rt), &mut a, &cfg);
                best = best.min(t0.elapsed().as_secs_f64());
                gprm::bench::black_box(a.allocated_blocks());
            }
            let row = Row {
                source: "host-wall-clock",
                workers: w,
                exec: name,
                secs: best,
                tasks_per_sec: n_tasks as f64 / best,
                gflops: total_flops as f64 / best / 1e9,
            };
            println!(
                "  {name:>5} @{w:>2} workers: {best:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
        rt.shutdown();
    }

    // Acceptance: work stealing must win on tasks/sec at >= 4 workers
    // (host rows; the tilesim rows assert the same in unit tests). A
    // loss anywhere exits nonzero so scripted runs actually gate.
    let mut failed = false;
    for &w in WORKERS.iter().filter(|&&w| w >= 4) {
        let tps = |exec: &str| {
            rows.iter()
                .find(|r| {
                    r.source == "host-wall-clock"
                        && r.workers == w
                        && r.exec == exec
                })
                .map(|r| r.tasks_per_sec)
                .unwrap()
        };
        let (m, s) = (tps("mutex"), tps("steal"));
        failed |= s <= m;
        println!(
            "  @{w} workers: steal/mutex = {:.2}x {}",
            s / m,
            if s > m { "PASS" } else { "FAIL" }
        );
    }

    // Append all rows to the repo-root BENCH_sched.json (JSON lines;
    // the committed file carries the tilesim baseline rows). Anchored
    // via the manifest dir — `cargo bench` runs with cwd = rust/.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!("\nappended {} rows to {path:?}", rows.len());
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!("steal bench FAILED: work stealing lost at >= 4 workers");
        std::process::exit(1);
    }
}
