//! Bench: mutex-scoreboard vs lock-free work-stealing executor on the
//! Fig-6 workload shape (NB=32, BS=16) at 1/2/4/8/16 workers — for
//! **both** engine workloads (SparseLU and tiled Cholesky; the engine
//! is kernel-agnostic, so the race uses identical machinery). Reports
//! tasks/sec and GFLOP/s (flops via each graph's op table), host
//! wall-clock on the omp runtime plus the tilesim claim-cost models,
//! appended as JSON rows to `BENCH_sched.json` with a `workload` field
//! (the committed baseline rows were produced by the tilesim model;
//! machines with real cores append `host-wall-clock` rows next to
//! them).
//!
//! `cargo bench --bench steal`

use gprm::apps::cholesky::cholesky_dataflow;
use gprm::apps::sparselu::{sparselu_dataflow, DataflowRt, LuRunConfig};
use gprm::linalg::cholesky::gen_spd;
use gprm::linalg::genmat::{genmat, genmat_pattern};
use gprm::omp::OmpRuntime;
use gprm::sched::{ExecOpts, TaskGraph};
use gprm::tilesim::{CostModel, DataflowSim, SchedModel, SimReport};
use std::io::Write as _;

const NB: usize = 32;
const BS: usize = 16;
const WORKERS: [usize; 5] = [1, 2, 4, 8, 16];

struct Row {
    workload: &'static str,
    source: &'static str,
    workers: usize,
    exec: &'static str,
    secs: f64,
    tasks_per_sec: f64,
    gflops: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"{} NB={NB} BS={BS}\", \
             \"source\": \"{}\", \"workers\": {}, \"exec\": \"{}\", \
             \"secs\": {:.6}, \"tasks_per_sec\": {:.0}, \
             \"gflops\": {:.3}}}",
            self.workload, self.source, self.workers, self.exec,
            self.secs, self.tasks_per_sec, self.gflops
        )
    }
}

/// Total useful flops of a graph, priced through its own op table —
/// workload-agnostic.
fn graph_flops(graph: &TaskGraph, bs: usize) -> u64 {
    graph
        .tasks()
        .iter()
        .map(|t| (graph.ops()[t.op.0].flops)(bs))
        .sum()
}

/// Race mutex vs steal for one workload: tilesim model rows + host
/// wall-clock rows. `host_once` runs one full factorisation on a
/// fresh input and returns the seconds spent in the factorisation
/// alone (input cloning excluded from the timed region). Returns true
/// if stealing lost anywhere at >= 4 workers (host rows).
fn bench_workload(
    workload: &'static str,
    graph: &TaskGraph,
    sim: &dyn Fn(usize, SchedModel) -> SimReport,
    host_once: &dyn Fn(&OmpRuntime, ExecOpts) -> f64,
    rows: &mut Vec<Row>,
) -> bool {
    let n_tasks = graph.len();
    let total_flops = graph_flops(graph, BS);
    println!(
        "\n### {workload} NB={NB} BS={BS} — {n_tasks} tasks, {:.3} GFLOP",
        total_flops as f64 / 1e9
    );
    let hz = CostModel::default().clock_hz;
    println!("== tilesim model (virtual time @866 MHz) ==");
    for &w in &WORKERS {
        for (name, sched) in [
            ("mutex", SchedModel::MutexScoreboard),
            ("steal", SchedModel::WorkSteal),
        ] {
            let r = sim(w, sched);
            let secs = r.cycles as f64 / hz;
            let row = Row {
                workload,
                source: "tilesim-model",
                workers: w,
                exec: name,
                secs,
                tasks_per_sec: n_tasks as f64 / secs,
                gflops: total_flops as f64 / secs / 1e9,
            };
            println!(
                "  {name:>5} @{w:>2} workers: {secs:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
    }

    // Host wall-clock: whole dataflow factorisations, best of SAMPLES.
    const SAMPLES: usize = 5;
    println!("== host wall-clock (omp-backed dataflow driver) ==");
    for &w in &WORKERS {
        let rt = OmpRuntime::new(w);
        for (name, exec) in [
            ("mutex", ExecOpts::mutex_baseline()),
            ("steal", ExecOpts::default()),
        ] {
            host_once(&rt, exec); // warmup
            let mut best = f64::MAX;
            for _ in 0..SAMPLES {
                best = best.min(host_once(&rt, exec));
            }
            let row = Row {
                workload,
                source: "host-wall-clock",
                workers: w,
                exec: name,
                secs: best,
                tasks_per_sec: n_tasks as f64 / best,
                gflops: total_flops as f64 / best / 1e9,
            };
            println!(
                "  {name:>5} @{w:>2} workers: {best:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
        rt.shutdown();
    }

    // Acceptance: work stealing must win on tasks/sec at >= 4 workers
    // (host rows; the tilesim rows assert the same in unit tests).
    let mut failed = false;
    for &w in WORKERS.iter().filter(|&&w| w >= 4) {
        let tps = |exec: &str| {
            rows.iter()
                .find(|r| {
                    r.workload == workload
                        && r.source == "host-wall-clock"
                        && r.workers == w
                        && r.exec == exec
                })
                .map(|r| r.tasks_per_sec)
                .unwrap()
        };
        let (m, s) = (tps("mutex"), tps("steal"));
        failed |= s <= m;
        println!(
            "  @{w} workers: steal/mutex = {:.2}x {}",
            s / m,
            if s > m { "PASS" } else { "FAIL" }
        );
    }
    failed
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();
    let mut failed = false;

    // SparseLU — the original acceptance workload.
    let lu_graph = TaskGraph::sparselu(&genmat_pattern(NB), NB);
    let a0 = genmat(NB, BS);
    failed |= bench_workload(
        "sparselu",
        &lu_graph,
        &|w, sched| DataflowSim::with_sched(w, sched).run_sparselu(NB, BS),
        &|rt, exec| {
            let mut a = a0.deep_clone();
            let cfg = LuRunConfig { exec, ..Default::default() };
            let t0 = std::time::Instant::now();
            sparselu_dataflow(&DataflowRt::Omp(rt), &mut a, &cfg);
            let secs = t0.elapsed().as_secs_f64();
            gprm::bench::black_box(a.allocated_blocks());
            secs
        },
        &mut rows,
    );

    // Cholesky — the second workload on the same engine; same race.
    let chol_graph = TaskGraph::cholesky(NB);
    let c0 = gen_spd(NB, BS);
    failed |= bench_workload(
        "cholesky",
        &chol_graph,
        &|w, sched| DataflowSim::with_sched(w, sched).run_cholesky(NB, BS),
        &|rt, exec| {
            let mut a = c0.deep_clone();
            let t0 = std::time::Instant::now();
            cholesky_dataflow(&DataflowRt::Omp(rt), &mut a, exec);
            let secs = t0.elapsed().as_secs_f64();
            gprm::bench::black_box(a.allocated_blocks());
            secs
        },
        &mut rows,
    );

    // Append all rows to the repo-root BENCH_sched.json (JSON lines;
    // the committed file carries the tilesim baseline rows for both
    // workloads). Anchored via the manifest dir — `cargo bench` runs
    // with cwd = rust/.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!("\nappended {} rows to {path:?}", rows.len());
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!("steal bench FAILED: work stealing lost at >= 4 workers");
        std::process::exit(1);
    }
}
