//! Bench: regenerate paper Fig 7 (SparseLU speedup vs concurrency
//! level up to 128, GPRM round-robin + contiguous vs OpenMP tasks).
//!
//! `cargo bench --bench fig7_scaling`

use gprm::harness::{run_experiment, Scale};

fn main() {
    let report = run_experiment("fig7", Scale(1.0));
    println!("{}", report.render());
    assert!(report.all_pass(), "fig7 shape checks failed");

    // Table I accompanies Fig 6/7 in the paper; regenerate it here
    // too so `cargo bench` covers every table and figure.
    let report = run_experiment("table1", Scale(1.0));
    println!("{}", report.render());
    assert!(report.all_pass(), "table1 shape checks failed");
}
