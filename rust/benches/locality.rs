//! Bench: uniform vs locality-aware work stealing on the Fig-6
//! workload shape (NB=32, BS=16) at 1/2/4/8/16 workers — for **every
//! workload in the registry** (`sched::workload::registry`). The
//! locality executor pins workers into min(2, workers) affinity
//! domains and steals nearest-domain-first (`ExecOpts::with_domains`);
//! the tilesim counterpart prices each off-home claim by mesh
//! distance (`SchedModel::LocalitySteal`). Appends `steal-local` JSON
//! rows to `BENCH_sched.json` next to the `steal` baseline rows the
//! steal bench produces (the committed rows are tilesim-model;
//! machines with real cores append `host-wall-clock` rows).
//!
//! `cargo bench --bench locality`

use gprm::apps::dataflow::{run_workload, DataflowRt};
use gprm::linalg::blocked::BlockedSparseMatrix;
use gprm::omp::OmpRuntime;
use gprm::sched::workload::{registry, Params, Workload};
use gprm::sched::{ExecOpts, TaskGraph};
use gprm::tilesim::{CostModel, DataflowSim, SchedModel};
use std::io::Write as _;

const NB: usize = 32;
const BS: usize = 16;
const WORKERS: [usize; 5] = [1, 2, 4, 8, 16];

struct Row {
    workload: &'static str,
    source: &'static str,
    workers: usize,
    exec: &'static str,
    secs: f64,
    tasks_per_sec: f64,
    gflops: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"{} NB={NB} BS={BS}\", \
             \"source\": \"{}\", \"workers\": {}, \"exec\": \"{}\", \
             \"secs\": {:.6}, \"tasks_per_sec\": {:.0}, \
             \"gflops\": {:.3}}}",
            self.workload, self.source, self.workers, self.exec,
            self.secs, self.tasks_per_sec, self.gflops
        )
    }
}

/// Race uniform vs nearest-first stealing for one registry entry:
/// tilesim `steal-local` model rows (the uniform `steal` baseline is
/// recomputed for the printed gain but not re-appended — the steal
/// bench owns those rows) plus host wall-clock rows for both victim
/// policies. Returns true if the locality executor lost badly
/// (< 0.9x uniform) at any gated worker count — >= 4 workers AND
/// within the machine's available parallelism; oversubscribed counts
/// are printed but never fail the bench (host rows — a tolerant
/// bar, since host domains only pay off with real per-core caches).
fn bench_workload(
    w: &'static dyn Workload,
    p: &Params,
    graph: &TaskGraph,
    input: &BlockedSparseMatrix,
    rows: &mut Vec<Row>,
) -> bool {
    let workload = w.name();
    let n_tasks = graph.len();
    let total_flops = w.graph_flops(graph, BS);
    println!(
        "\n### {workload} NB={NB} BS={BS} — {n_tasks} tasks, {:.3} GFLOP",
        total_flops as f64 / 1e9
    );
    let hz = CostModel::default().clock_hz;
    println!("== tilesim model (virtual time @866 MHz) ==");
    for &workers in &WORKERS {
        let uniform = DataflowSim::with_sched(workers, SchedModel::WorkSteal)
            .run_workload(w, p);
        let local = DataflowSim::with_sched(
            workers,
            SchedModel::LocalitySteal { domains: workers.min(2) },
        )
        .run_workload(w, p);
        let secs = local.cycles as f64 / hz;
        let row = Row {
            workload,
            source: "tilesim-model",
            workers,
            exec: "steal-local",
            secs,
            tasks_per_sec: n_tasks as f64 / secs,
            gflops: total_flops as f64 / secs / 1e9,
        };
        println!(
            "  steal-local @{workers:>2} workers: {secs:>8.4}s  {:>9.0} tasks/s  \
             {:>6.3} GFLOP/s  ({:.4}x vs uniform)",
            row.tasks_per_sec,
            row.gflops,
            uniform.cycles as f64 / local.cycles as f64
        );
        rows.push(row);
    }

    // Host wall-clock: whole dataflow runs, best of SAMPLES.
    const SAMPLES: usize = 5;
    let host_once = |rt: &OmpRuntime, exec: ExecOpts| -> f64 {
        let mut a = input.deep_clone();
        let t0 = std::time::Instant::now();
        run_workload(&DataflowRt::Omp(rt), w, &mut a, exec)
            .expect("bench dataflow run failed");
        let secs = t0.elapsed().as_secs_f64();
        gprm::bench::black_box(a.allocated_blocks());
        secs
    };
    println!("== host wall-clock (omp-backed dataflow driver) ==");
    for &workers in &WORKERS {
        let rt = OmpRuntime::new(workers);
        for (name, exec) in [
            ("steal", ExecOpts::default()),
            ("steal-local", ExecOpts::default().with_domains(2)),
        ] {
            host_once(&rt, exec); // warmup
            let mut best = f64::MAX;
            for _ in 0..SAMPLES {
                best = best.min(host_once(&rt, exec));
            }
            let row = Row {
                workload,
                source: "host-wall-clock",
                workers,
                exec: name,
                secs: best,
                tasks_per_sec: n_tasks as f64 / best,
                gflops: total_flops as f64 / best / 1e9,
            };
            println!(
                "  {name:>11} @{workers:>2} workers: {best:>8.4}s  {:>9.0} tasks/s  {:>6.3} GFLOP/s",
                row.tasks_per_sec, row.gflops
            );
            rows.push(row);
        }
        rt.shutdown();
    }

    // Acceptance: domains must never cost more than 10% on host
    // tasks/sec at >= 4 workers. (The model asserts strict wins in
    // unit tests; host wins depend on real cache topology, so the
    // bench only refuses regressions.) Worker counts above the
    // machine's available parallelism are oversubscribed — their
    // wall-clock is scheduler noise, not a victim-policy signal — so
    // they are reported but never gate the exit code.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut failed = false;
    for &workers in WORKERS.iter().filter(|&&workers| workers >= 4) {
        if workers > cores {
            println!(
                "  @{workers} workers: oversubscribed ({cores} cores) — \
                 reported only, not gating"
            );
            continue;
        }
        let tps = |exec: &str| {
            rows.iter()
                .find(|r| {
                    r.workload == workload
                        && r.source == "host-wall-clock"
                        && r.workers == workers
                        && r.exec == exec
                })
                .map(|r| r.tasks_per_sec)
                .unwrap()
        };
        let (u, l) = (tps("steal"), tps("steal-local"));
        failed |= l < 0.9 * u;
        println!(
            "  @{workers} workers: steal-local/steal = {:.2}x {}",
            l / u,
            if l >= 0.9 * u { "PASS" } else { "FAIL" }
        );
    }
    failed
}

fn main() {
    let p = Params::new(NB, BS);
    let mut rows: Vec<Row> = Vec::new();
    let mut failed = false;

    // Every registered workload races on the identical machinery.
    for w in registry() {
        let graph = w.graph(&p);
        let input = w.make_input(&p, 0);
        failed |= bench_workload(*w, &p, &graph, &input, &mut rows);
    }

    // Append all rows to the repo-root BENCH_sched.json (JSON lines;
    // the committed file carries the tilesim baseline rows). Anchored
    // via the manifest dir — `cargo bench` runs with cwd = rust/.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!("\nappended {} rows to {path:?}", rows.len());
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!(
            "locality bench FAILED: steal-local lost > 10% at >= 4 workers"
        );
        std::process::exit(1);
    }
}
