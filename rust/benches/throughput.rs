//! Bench: multi-job throughput — a stream of 8 mixed jobs
//! (NB=16/BS=16) pushed through ONE persistent pool
//! (`sched::pool::Pool`, jobs submitted before any wait, cross-job
//! stealing) vs the pre-pool regime of one one-shot executor launch
//! per job (a fresh `OmpRuntime` team spawned and joined around every
//! job). The stream composition is derived from the **workload
//! registry**: it cycles the phase-capable (factorisation) entries —
//! SparseLU and Cholesky alternating at the current registry, exactly
//! the committed `mixed8` baseline — so the bench never names a
//! workload. Reports jobs/sec and tasks/sec from both the tilesim
//! launch models (`LaunchModel::{PersistentPool, OneShotPerJob}`) and
//! host wall-clock, appending JSON rows to `BENCH_sched.json` (the
//! committed baseline rows were produced by the tilesim model).
//!
//! `cargo bench --bench throughput`

use gprm::apps::dataflow::{
    run_dataflow_batch, run_workload, DataflowRt, PoolJob,
};
use gprm::linalg::blocked::BlockedSparseMatrix;
use gprm::linalg::genmat::genmat_pattern;
use gprm::omp::OmpRuntime;
use gprm::sched::workload::{registry, Cholesky, Params, Sparselu, Workload};
use gprm::sched::{ExecOpts, Pool, PoolConfig, TaskGraph};
use gprm::tilesim::{CostModel, DataflowSim, LaunchModel, SimJob};
use std::io::Write as _;

const NB: usize = 16;
const BS: usize = 16;
const N_JOBS: usize = 8;
const WORKERS: [usize; 5] = [1, 2, 4, 8, 16];

struct Row {
    source: &'static str,
    workers: usize,
    exec: &'static str,
    secs: f64,
    jobs_per_sec: f64,
    tasks_per_sec: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"mixed{N_JOBS} NB={NB} BS={BS}\", \
             \"source\": \"{}\", \"workers\": {}, \"exec\": \"{}\", \
             \"secs\": {:.6}, \"jobs_per_sec\": {:.1}, \
             \"tasks_per_sec\": {:.0}}}",
            self.source, self.workers, self.exec, self.secs,
            self.jobs_per_sec, self.tasks_per_sec
        )
    }
}

/// Sizing of the recovery-overhead rows — matches the `faults`
/// experiment's virtual-time table so the committed fault-tagged
/// baselines and `gprm exp faults` price the identical stream.
const FAULT_NB: usize = 12;
const FAULT_BS: usize = 8;
const FAULT_TILES: usize = 8;

/// One fault-tagged row: the virtual-time cost of the mixed stream
/// under a retry regime (`DataflowSim::run_jobs_recovering`, guard
/// always on).
struct FaultRow {
    exec: &'static str,
    rate: f64,
    retries: u64,
    secs: f64,
    cycles: u64,
    retry_cycles: u64,
    guard_cycles: u64,
    overhead_pct: f64,
}

impl FaultRow {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"mixed{N_JOBS} NB={FAULT_NB} \
             BS={FAULT_BS}\", \"source\": \"tilesim-model\", \
             \"workers\": {FAULT_TILES}, \"exec\": \"{}\", \
             \"fault_rate\": {:.2}, \"retries\": {}, \"secs\": {:.6}, \
             \"cycles\": {}, \"retry_cycles\": {}, \
             \"guard_cycles\": {}, \"overhead_pct\": {:.2}}}",
            self.exec, self.rate, self.retries, self.secs, self.cycles,
            self.retry_cycles, self.guard_cycles, self.overhead_pct
        )
    }
}

/// Price the fault/recovery regimes on the virtual machine: the
/// committed fault-tagged baseline rows re-derive from exactly this
/// loop (fault rate 0 / 1% / 5% × both launch models, NB=12/BS=8,
/// 8 tiles, cancellation guard on).
fn fault_rows(hz: f64) -> Vec<FaultRow> {
    let lu = TaskGraph::sparselu(&genmat_pattern(FAULT_NB), FAULT_NB);
    let ch = TaskGraph::cholesky(FAULT_NB);
    let jobs: Vec<SimJob> = (0..N_JOBS)
        .map(|i| {
            if i % 2 == 0 {
                SimJob { workload: &Sparselu, graph: &lu, bs: FAULT_BS }
            } else {
                SimJob { workload: &Cholesky, graph: &ch, bs: FAULT_BS }
            }
        })
        .collect();
    let sim = DataflowSim::tilepro(FAULT_TILES);
    let mut rows = Vec::new();
    println!("== tilesim recovery overhead (NB={FAULT_NB} BS={FAULT_BS}, {FAULT_TILES} tiles, guard on) ==");
    for (name, launch) in [
        ("pool", LaunchModel::PersistentPool),
        ("oneshot", LaunchModel::OneShotPerJob),
    ] {
        for rate in [0.0f64, 0.01, 0.05] {
            let retries: Vec<usize> = jobs
                .iter()
                .map(|j| (rate * j.graph.len() as f64).round() as usize)
                .collect();
            let r = sim.run_jobs_recovering(&jobs, launch, &retries, true);
            let row = FaultRow {
                exec: name,
                rate,
                retries: r.retries,
                secs: r.cycles as f64 / hz,
                cycles: r.cycles,
                retry_cycles: r.retry_cycles,
                guard_cycles: r.guard_cycles,
                overhead_pct: r.overhead() * 100.0,
            };
            println!(
                "  {name:>7} @{rate:>4.2} fault rate: {:>8.4}s  {:>4} retries  {:>+9.2}% overhead",
                row.secs, row.retries, row.overhead_pct
            );
            rows.push(row);
        }
    }
    rows
}

/// One kind of the mixed stream: the registry entry, its canonical
/// input and the matching graph.
struct Kind {
    w: &'static dyn Workload,
    input: BlockedSparseMatrix,
    graph: TaskGraph,
}

/// One timed pass of the whole stream through a warm persistent pool.
fn host_pool_once(pool: &Pool, kinds: &[Kind]) -> f64 {
    let mut mats: Vec<BlockedSparseMatrix> = (0..N_JOBS)
        .map(|i| kinds[i % kinds.len()].input.deep_clone())
        .collect();
    let mut jobs: Vec<PoolJob> = mats
        .iter_mut()
        .enumerate()
        .map(|(i, a)| {
            let k = &kinds[i % kinds.len()];
            PoolJob { a, graph: &k.graph, kernels: k.w.kernels() }
        })
        .collect();
    let t0 = std::time::Instant::now();
    run_dataflow_batch(pool, &mut jobs).expect("pool batch failed");
    let secs = t0.elapsed().as_secs_f64();
    drop(jobs);
    gprm::bench::black_box(
        mats.iter().map(|m| m.allocated_blocks()).sum::<usize>(),
    );
    secs
}

/// One timed pass of the stream through per-launch one-shot
/// executors: every job pays a fresh team spawn + join. Input clones
/// happen before the clock starts, exactly like the pool pass, so
/// the regimes differ only in how jobs reach workers.
fn host_one_shot_once(workers: usize, kinds: &[Kind]) -> f64 {
    let mut inputs: Vec<BlockedSparseMatrix> = (0..N_JOBS)
        .map(|i| kinds[i % kinds.len()].input.deep_clone())
        .collect();
    let t0 = std::time::Instant::now();
    for (i, a) in inputs.iter_mut().enumerate() {
        let rt = OmpRuntime::new(workers);
        run_workload(
            &DataflowRt::Omp(&rt),
            kinds[i % kinds.len()].w,
            a,
            ExecOpts::default(),
        )
        .expect("one-shot run failed");
        gprm::bench::black_box(a.allocated_blocks());
        rt.shutdown();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let p = Params::new(NB, BS);
    // The stream cycles the registry's phase-capable entries.
    let kinds: Vec<Kind> = registry()
        .iter()
        .copied()
        .filter(|w| w.phases(&p).is_some())
        .map(|w| {
            let input = w.make_input(&p, 0);
            let graph = w.graph_for(&input);
            Kind { w, input, graph }
        })
        .collect();
    assert!(!kinds.is_empty(), "registry has no phase-capable entries");
    let n_tasks: usize =
        (0..N_JOBS).map(|i| kinds[i % kinds.len()].graph.len()).sum();
    let sim_jobs: Vec<SimJob> = (0..N_JOBS)
        .map(|i| {
            let k = &kinds[i % kinds.len()];
            SimJob { workload: k.w, graph: &k.graph, bs: BS }
        })
        .collect();
    println!(
        "### mixed{N_JOBS} NB={NB} BS={BS} — {n_tasks} tasks (stream \
         cycles: {})",
        kinds
            .iter()
            .map(|k| k.w.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let mut rows: Vec<Row> = Vec::new();
    let hz = CostModel::default().clock_hz;
    println!("== tilesim launch models (virtual time @866 MHz) ==");
    for &w in &WORKERS {
        let sim = DataflowSim::tilepro(w);
        for (name, launch) in [
            ("pool", LaunchModel::PersistentPool),
            ("oneshot", LaunchModel::OneShotPerJob),
        ] {
            let r = sim.run_jobs(&sim_jobs, launch);
            let secs = r.cycles as f64 / hz;
            let row = Row {
                source: "tilesim-model",
                workers: w,
                exec: name,
                secs,
                jobs_per_sec: N_JOBS as f64 / secs,
                tasks_per_sec: n_tasks as f64 / secs,
            };
            println!(
                "  {name:>7} @{w:>2} workers: {secs:>8.4}s  {:>7.1} jobs/s  {:>9.0} tasks/s",
                row.jobs_per_sec, row.tasks_per_sec
            );
            rows.push(row);
        }
    }

    let frows = fault_rows(hz);

    const SAMPLES: usize = 5;
    println!("== host wall-clock (pool vs per-launch omp team) ==");
    let mut failed = false;
    for &w in &WORKERS {
        let pool = Pool::with_config(PoolConfig {
            workers: w,
            task_capacity: n_tasks,
            max_jobs: N_JOBS,
            max_pending: None,
            domains: 1,
        });
        let mut best = [f64::MAX; 2];
        // Warmups, then best-of-SAMPLES for each regime.
        host_pool_once(&pool, &kinds);
        host_one_shot_once(w, &kinds);
        for _ in 0..SAMPLES {
            best[0] = best[0].min(host_pool_once(&pool, &kinds));
            best[1] = best[1].min(host_one_shot_once(w, &kinds));
        }
        pool.shutdown();
        for (name, secs) in [("pool", best[0]), ("oneshot", best[1])] {
            let row = Row {
                source: "host-wall-clock",
                workers: w,
                exec: name,
                secs,
                jobs_per_sec: N_JOBS as f64 / secs,
                tasks_per_sec: n_tasks as f64 / secs,
            };
            println!(
                "  {name:>7} @{w:>2} workers: {secs:>8.4}s  {:>7.1} jobs/s  {:>9.0} tasks/s",
                row.jobs_per_sec, row.tasks_per_sec
            );
            rows.push(row);
        }
        let gain = best[1] / best[0];
        if w >= 4 {
            failed |= gain <= 1.0;
            println!(
                "  @{w} workers: pool/oneshot jobs-per-sec gain = {gain:.2}x {}",
                if gain > 1.0 { "PASS" } else { "FAIL" }
            );
        }
    }

    // Append rows to the repo-root BENCH_sched.json (JSON lines; the
    // committed baselines carry the tilesim-model rows).
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            for r in &frows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!(
                "\nappended {} rows to {path:?}",
                rows.len() + frows.len()
            );
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!(
            "throughput bench FAILED: the pool lost to per-launch spawn at >= 4 workers"
        );
        std::process::exit(1);
    }
}
