//! Bench: multi-job throughput — a stream of 8 mixed jobs (4×
//! SparseLU + 4× tiled Cholesky, alternating, NB=16/BS=16) pushed
//! through ONE persistent pool (`sched::pool::Pool`, jobs submitted
//! before any wait, cross-job stealing) vs the pre-pool regime of one
//! one-shot executor launch per job (a fresh `OmpRuntime` team
//! spawned and joined around every factorisation). Reports jobs/sec
//! and tasks/sec from both the tilesim launch models
//! (`LaunchModel::{PersistentPool, OneShotPerJob}`) and host
//! wall-clock, appending JSON rows to `BENCH_sched.json` (the
//! committed baseline rows were produced by the tilesim model).
//!
//! `cargo bench --bench throughput`

use gprm::apps::cholesky::{cholesky_dataflow, CHOLESKY_RUST_KERNELS};
use gprm::apps::dataflow::{run_dataflow_batch, PoolJob};
use gprm::apps::sparselu::{
    sparselu_dataflow, DataflowRt, LuRunConfig, LU_RUST_KERNELS,
};
use gprm::linalg::blocked::BlockedSparseMatrix;
use gprm::linalg::cholesky::gen_spd;
use gprm::linalg::genmat::{genmat, genmat_pattern};
use gprm::omp::OmpRuntime;
use gprm::sched::{ExecOpts, Pool, PoolConfig, TaskGraph};
use gprm::tilesim::{CostModel, DataflowSim, LaunchModel};
use std::io::Write as _;

const NB: usize = 16;
const BS: usize = 16;
const N_JOBS: usize = 8;
const WORKERS: [usize; 5] = [1, 2, 4, 8, 16];

struct Row {
    source: &'static str,
    workers: usize,
    exec: &'static str,
    secs: f64,
    jobs_per_sec: f64,
    tasks_per_sec: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"mixed{N_JOBS} NB={NB} BS={BS}\", \
             \"source\": \"{}\", \"workers\": {}, \"exec\": \"{}\", \
             \"secs\": {:.6}, \"jobs_per_sec\": {:.1}, \
             \"tasks_per_sec\": {:.0}}}",
            self.source, self.workers, self.exec, self.secs,
            self.jobs_per_sec, self.tasks_per_sec
        )
    }
}

/// One timed pass of the whole stream through a warm persistent pool.
fn host_pool_once(
    pool: &Pool,
    lu_graph: &TaskGraph,
    ch_graph: &TaskGraph,
    lu0_mat: &BlockedSparseMatrix,
    ch0_mat: &BlockedSparseMatrix,
) -> f64 {
    let mut mats: Vec<BlockedSparseMatrix> = (0..N_JOBS)
        .map(|i| {
            if i % 2 == 0 { lu0_mat.deep_clone() } else { ch0_mat.deep_clone() }
        })
        .collect();
    let mut jobs: Vec<PoolJob> = mats
        .iter_mut()
        .enumerate()
        .map(|(i, a)| {
            if i % 2 == 0 {
                PoolJob { a, graph: lu_graph, kernels: &LU_RUST_KERNELS }
            } else {
                PoolJob {
                    a,
                    graph: ch_graph,
                    kernels: &CHOLESKY_RUST_KERNELS,
                }
            }
        })
        .collect();
    let t0 = std::time::Instant::now();
    run_dataflow_batch(pool, &mut jobs).expect("pool batch failed");
    let secs = t0.elapsed().as_secs_f64();
    drop(jobs);
    gprm::bench::black_box(
        mats.iter().map(|m| m.allocated_blocks()).sum::<usize>(),
    );
    secs
}

/// One timed pass of the stream through per-launch one-shot
/// executors: every job pays a fresh team spawn + join. Input clones
/// happen before the clock starts, exactly like the pool pass, so
/// the regimes differ only in how jobs reach workers.
fn host_one_shot_once(
    workers: usize,
    lu0_mat: &BlockedSparseMatrix,
    ch0_mat: &BlockedSparseMatrix,
) -> f64 {
    let mut inputs: Vec<BlockedSparseMatrix> = (0..N_JOBS)
        .map(|i| {
            if i % 2 == 0 { lu0_mat.deep_clone() } else { ch0_mat.deep_clone() }
        })
        .collect();
    let t0 = std::time::Instant::now();
    for (i, a) in inputs.iter_mut().enumerate() {
        let rt = OmpRuntime::new(workers);
        if i % 2 == 0 {
            sparselu_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                &LuRunConfig::default(),
            );
        } else {
            cholesky_dataflow(&DataflowRt::Omp(&rt), a, ExecOpts::default());
        }
        gprm::bench::black_box(a.allocated_blocks());
        rt.shutdown();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let lu_graph = TaskGraph::sparselu(&genmat_pattern(NB), NB);
    let ch_graph = TaskGraph::cholesky(NB);
    let n_tasks = (N_JOBS / 2) * (lu_graph.len() + ch_graph.len());
    let sim_jobs: Vec<(&TaskGraph, usize)> = (0..N_JOBS)
        .map(|i| (if i % 2 == 0 { &lu_graph } else { &ch_graph }, BS))
        .collect();
    println!(
        "### mixed{N_JOBS} NB={NB} BS={BS} — {n_tasks} tasks \
         ({} sparselu + {} cholesky per stream)",
        lu_graph.len() * N_JOBS / 2,
        ch_graph.len() * N_JOBS / 2,
    );
    let mut rows: Vec<Row> = Vec::new();
    let hz = CostModel::default().clock_hz;
    println!("== tilesim launch models (virtual time @866 MHz) ==");
    for &w in &WORKERS {
        let sim = DataflowSim::tilepro(w);
        for (name, launch) in [
            ("pool", LaunchModel::PersistentPool),
            ("oneshot", LaunchModel::OneShotPerJob),
        ] {
            let r = sim.run_jobs(&sim_jobs, launch);
            let secs = r.cycles as f64 / hz;
            let row = Row {
                source: "tilesim-model",
                workers: w,
                exec: name,
                secs,
                jobs_per_sec: N_JOBS as f64 / secs,
                tasks_per_sec: n_tasks as f64 / secs,
            };
            println!(
                "  {name:>7} @{w:>2} workers: {secs:>8.4}s  {:>7.1} jobs/s  {:>9.0} tasks/s",
                row.jobs_per_sec, row.tasks_per_sec
            );
            rows.push(row);
        }
    }

    const SAMPLES: usize = 5;
    let lu0_mat = genmat(NB, BS);
    let ch0_mat = gen_spd(NB, BS);
    println!("== host wall-clock (pool vs per-launch omp team) ==");
    let mut failed = false;
    for &w in &WORKERS {
        let pool = Pool::with_config(PoolConfig {
            workers: w,
            task_capacity: n_tasks,
            max_jobs: N_JOBS,
        });
        let mut best = [f64::MAX; 2];
        // Warmups, then best-of-SAMPLES for each regime.
        host_pool_once(&pool, &lu_graph, &ch_graph, &lu0_mat, &ch0_mat);
        host_one_shot_once(w, &lu0_mat, &ch0_mat);
        for _ in 0..SAMPLES {
            best[0] = best[0].min(host_pool_once(
                &pool, &lu_graph, &ch_graph, &lu0_mat, &ch0_mat,
            ));
            best[1] =
                best[1].min(host_one_shot_once(w, &lu0_mat, &ch0_mat));
        }
        pool.shutdown();
        for (name, secs) in [("pool", best[0]), ("oneshot", best[1])] {
            let row = Row {
                source: "host-wall-clock",
                workers: w,
                exec: name,
                secs,
                jobs_per_sec: N_JOBS as f64 / secs,
                tasks_per_sec: n_tasks as f64 / secs,
            };
            println!(
                "  {name:>7} @{w:>2} workers: {secs:>8.4}s  {:>7.1} jobs/s  {:>9.0} tasks/s",
                row.jobs_per_sec, row.tasks_per_sec
            );
            rows.push(row);
        }
        let gain = best[1] / best[0];
        if w >= 4 {
            failed |= gain <= 1.0;
            println!(
                "  @{w} workers: pool/oneshot jobs-per-sec gain = {gain:.2}x {}",
                if gain > 1.0 { "PASS" } else { "FAIL" }
            );
        }
    }

    // Append rows to the repo-root BENCH_sched.json (JSON lines; the
    // committed baselines carry the tilesim-model rows).
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!("\nappended {} rows to {path:?}", rows.len());
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!(
            "throughput bench FAILED: the pool lost to per-launch spawn at >= 4 workers"
        );
        std::process::exit(1);
    }
}
