//! Bench: regenerate paper Fig 3 (speedup for 200,000 fine-grained
//! jobs at 63 threads).
//!
//! `cargo bench --bench fig3_finegrained`

use gprm::harness::{run_experiment, Scale};

fn main() {
    let report = run_experiment("fig3", Scale(1.0));
    println!("{}", report.render());
    assert!(report.all_pass(), "fig3 shape checks failed");
}
