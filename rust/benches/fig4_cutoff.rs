//! Bench: regenerate paper Fig 4 (the cutoff sweep rescuing OpenMP
//! tasking on 200,000 jobs of 50×50 and 100×100).
//!
//! `cargo bench --bench fig4_cutoff`

use gprm::harness::{run_experiment, Scale};

fn main() {
    let report = run_experiment("fig4", Scale(1.0));
    println!("{}", report.render());
    assert!(report.all_pass(), "fig4 shape checks failed");
}
