//! Bench: the microkernel layer — per-op kernel GFLOP/s, scalar vs
//! packed/SIMD vs SIMD+fast, per candidate block size, for every
//! vectorised op in the registry vocabulary (`bmod`, `gemm`, `syrk`,
//! `trsm`, `madd`).
//!
//! Two row families are appended to `BENCH_sched.json`:
//!
//! * `"source": "kernel"` — the TILEPro64 cycle model
//!   ([`CostModel::kernel_scalar`] / [`CostModel::kernel_simd`]):
//!   deterministic, machine-independent; these are the committed
//!   baseline rows.
//! * `"source": "kernel-host"` — this machine's wall clock through
//!   each workload's [`Workload::kernels_for`] table (bit-identical
//!   and fast modes; the `exec` field records the dispatched SIMD
//!   level). Build with `--features simd` to exercise the vector
//!   paths.
//!
//! Acceptance gate: the model must never price the packed/SIMD path
//! slower than scalar at bs >= 8 (exit 1 otherwise).
//!
//! `cargo bench --bench kernels` (optionally `--features simd`)

use gprm::linalg::autotune::{is_vectorised, CANDIDATE_BS};
use gprm::linalg::dense::DenseMatrix;
use gprm::linalg::microkernel::{simd_level, KernelMode};
use gprm::sched::workload::{registry, Params, Workload};
use gprm::tilesim::CostModel;
use std::io::Write as _;

struct Row {
    workload: String,
    source: &'static str,
    exec: String,
    secs: f64,
    calls_per_sec: f64,
    gflops: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"workload\": \"{}\", \"source\": \"{}\", \
             \"workers\": 1, \"exec\": \"{}\", \"secs\": {:.9}, \
             \"tasks_per_sec\": {:.0}, \"gflops\": {:.3}}}",
            self.workload, self.source, self.exec, self.secs,
            self.calls_per_sec, self.gflops
        )
    }
}

/// The vectorised ops, deduped across the registry, with their
/// declaring workload, op index and read arity (from a small canonical
/// graph — the kernel table wants the right number of read blocks).
fn vectorised_ops(
) -> Vec<(&'static dyn Workload, usize, &'static str, usize)> {
    let mut out: Vec<(&'static dyn Workload, usize, &'static str, usize)> =
        Vec::new();
    for w in registry() {
        let g = w.graph(&Params::new(4, 8));
        let mut arity = vec![0usize; w.ops().len()];
        for t in g.tasks() {
            arity[t.op.0] = t.reads().len();
        }
        for (i, op) in w.ops().iter().enumerate() {
            if is_vectorised(op.name)
                && !out.iter().any(|&(_, _, n, _)| n == op.name)
            {
                out.push((*w, i, op.name, arity[i]));
            }
        }
    }
    out
}

fn main() {
    let cost = CostModel::default();
    let hz = cost.clock_hz;
    let mut rows: Vec<Row> = Vec::new();
    let mut failed = false;

    println!("== kernel cycle model (virtual time @866 MHz) ==");
    for (w, i, name, _arity) in vectorised_ops() {
        for &bs in &CANDIDATE_BS {
            let flops = (w.ops()[i].flops)(bs);
            for (exec, cycles) in [
                ("kernel-scalar", cost.kernel_scalar(flops, bs)),
                ("kernel-simd", cost.kernel_simd(flops, bs, false)),
                ("kernel-simd-fast", cost.kernel_simd(flops, bs, true)),
            ] {
                let secs = cycles / hz;
                let row = Row {
                    workload: format!("{name} BS={bs}"),
                    source: "kernel",
                    exec: exec.to_string(),
                    secs,
                    calls_per_sec: 1.0 / secs,
                    gflops: flops as f64 / secs / 1e9,
                };
                println!(
                    "  {name:>4} bs={bs:>2} {exec:>16}: {cycles:>8.0} cy  {:>7.3} GFLOP/s",
                    row.gflops
                );
                rows.push(row);
            }
            if bs >= 8 {
                let simd = cost.kernel_simd(flops, bs, false);
                let scalar = cost.kernel_scalar(flops, bs);
                if simd > scalar {
                    eprintln!(
                        "FAIL: {name} bs={bs}: simd {simd:.0} cy > scalar {scalar:.0} cy"
                    );
                    failed = true;
                }
            }
        }
    }

    // Host wall-clock through the dispatched kernel tables (best of
    // SAMPLES batches; the per-call cost is sub-microsecond, so each
    // sample times a batch of calls).
    const SAMPLES: usize = 5;
    const BATCH: usize = 200;
    println!(
        "== host wall-clock (dispatch level: {}) ==",
        simd_level().name()
    );
    for (w, i, name, arity) in vectorised_ops() {
        for &bs in &CANDIDATE_BS {
            let flops = (w.ops()[i].flops)(bs);
            let srcs: Vec<Vec<f32>> = (0..2)
                .map(|s| {
                    DenseMatrix::bots_random(bs, bs, 81 + s)
                        .as_slice()
                        .to_vec()
                })
                .collect();
            let reads: Vec<&[f32]> =
                srcs[..arity].iter().map(|b| b.as_slice()).collect();
            for (mode, label) in [
                (KernelMode::BitIdentical, "bit"),
                (KernelMode::Fast, "fast"),
            ] {
                let kernel = w.kernels_for(mode)[i];
                let mut write = DenseMatrix::bots_random(bs, bs, 83)
                    .as_slice()
                    .to_vec();
                kernel(&reads, &mut write, bs); // warmup
                let mut best = f64::MAX;
                for _ in 0..SAMPLES {
                    let t0 = std::time::Instant::now();
                    for _ in 0..BATCH {
                        kernel(&reads, &mut write, bs);
                    }
                    best = best
                        .min(t0.elapsed().as_secs_f64() / BATCH as f64);
                }
                gprm::bench::black_box(&write);
                let row = Row {
                    workload: format!("{name} BS={bs}"),
                    source: "kernel-host",
                    exec: format!("{label}-{}", simd_level().name()),
                    secs: best,
                    calls_per_sec: 1.0 / best,
                    gflops: flops as f64 / best / 1e9,
                };
                println!(
                    "  {name:>4} bs={bs:>2} {:>12}: {:>9.1} ns/call  {:>7.3} GFLOP/s",
                    row.exec,
                    best * 1e9,
                    row.gflops
                );
                rows.push(row);
            }
        }
    }

    // Append to the repo-root BENCH_sched.json (JSON lines), anchored
    // via the manifest dir — `cargo bench` runs with cwd = rust/.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest
        .parent()
        .unwrap_or(manifest)
        .join("BENCH_sched.json");
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in &rows {
                let _ = writeln!(f, "{}", r.json());
            }
            println!("\nappended {} rows to {path:?}", rows.len());
        }
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
    if failed {
        eprintln!(
            "kernels bench FAILED: packed/SIMD modelled slower than \
             scalar at bs >= 8"
        );
        std::process::exit(1);
    }
}
