//! Bench: regenerate paper Fig 2 (MatMul micro-benchmark, four
//! approaches across job sizes) on the TILEPro64 simulator, and time
//! the simulator itself with the in-crate harness.
//!
//! `cargo bench --bench fig2_matmul`

use gprm::bench::Bench;
use gprm::harness::{run_experiment, Scale};

fn main() {
    // The figure itself, at paper scale.
    let report = run_experiment("fig2", Scale(1.0));
    println!("{}", report.render());
    assert!(report.all_pass(), "fig2 shape checks failed");

    // Simulator throughput (how fast we can regenerate the figure).
    let b = Bench::quick();
    let r = b.measure_once("fig2 full regeneration", || {
        let rep = run_experiment("fig2", Scale(1.0));
        gprm::bench::black_box(rep.tables.len());
    });
    println!("{}", r.report());
}
