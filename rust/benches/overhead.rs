//! Runtime-overhead microbenchmarks — the quantities the whole paper
//! is about: per-task cost in GPRM vs per-task cost in the OpenMP
//! model, worksharing per-iteration cost, and PJRT dispatch cost.
//! These feed EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench overhead`

use gprm::bench::{black_box, Bench};
use gprm::coordinator::kernel::Registry;
use gprm::coordinator::{par_for, GprmConfig, GprmRuntime, Prog};
use gprm::omp::OmpRuntime;
use std::sync::atomic::{AtomicU64, Ordering};

fn main() {
    let b = Bench::default();
    let threads = 4;

    // --- GPRM -----------------------------------------------------------
    let gprm = GprmRuntime::new(
        GprmConfig { n_tiles: threads, pin: false },
        Registry::new(),
    );

    // Cost of one par_invoke round trip (CL native tasks + barrier).
    let r = b.measure("gprm par_invoke(CL) round-trip", || {
        gprm.par_invoke(threads, |_| {}).unwrap();
    });
    println!("{}", r.report());

    // Per-task cost: 64 native tasks per round trip.
    let counter = AtomicU64::new(0);
    let r = b.measure("gprm 64 native tasks", || {
        gprm.par_invoke(64, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
    });
    println!("{}", r.report());

    // Compiled-program reuse: evaluate a 3-node S-expression.
    let mut reg = Registry::new();
    reg.register(std::sync::Arc::new(
        gprm::coordinator::ClosureKernel::new("k").method("id", |a| {
            a.first().cloned().unwrap_or(gprm::coordinator::Value::Unit)
        }),
    ));
    let rt2 = GprmRuntime::new(GprmConfig { n_tiles: threads, pin: false }, reg);
    let prog = Prog::call(
        "k",
        "id",
        vec![Prog::call("k", "id", vec![Prog::lit(1i64)])],
    );
    let compiled = rt2.compile(&prog).unwrap();
    let r = b.measure("gprm 2-task bytecode eval (compiled)", || {
        black_box(rt2.run_compiled(&compiled).unwrap());
    });
    println!("{}", r.report());

    // par_for per-iteration overhead (pure, no runtime).
    let r = b.measure("par_for 10k iterations (listing 1)", || {
        let mut acc = 0u64;
        par_for(0, 10_000, 1, 4, |i| acc += i as u64);
        black_box(acc);
    });
    println!("{}", r.report());

    // --- OpenMP model ----------------------------------------------------
    let omp = OmpRuntime::new(threads);

    // Empty region fork/join.
    let r = b.measure("omp empty parallel region", || {
        omp.parallel(|_| {}).unwrap();
    });
    println!("{}", r.report());

    // 64 empty tasks through the central queue.
    let sum = AtomicU64::new(0);
    let sum_ref = &sum;
    let r = b.measure("omp 64 tasks via central queue", || {
        omp.parallel(|ctx| {
            ctx.single(|| {
                for _ in 0..64 {
                    ctx.task(move |_| {
                        sum_ref.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        })
        .unwrap();
    });
    println!("{}", r.report());

    // taskwait latency.
    let r = b.measure("omp task + taskwait", || {
        omp.parallel(|ctx| {
            ctx.single(|| {
                ctx.task(|_| {});
                ctx.taskwait();
            });
        })
        .unwrap();
    });
    println!("{}", r.report());

    // --- PJRT ------------------------------------------------------------
    let dir = gprm::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let mut eng = gprm::runtime::BlockEngine::new(&dir).unwrap();
        let bs = 8usize;
        let blk: Vec<f32> = (0..bs * bs).map(|i| i as f32 * 0.01 + 1.0).collect();
        // warm the compile cache
        let mut i0 = blk.clone();
        eng.bmod(bs, &blk, &blk, &mut i0).unwrap();
        let r = b.measure("pjrt bmod bs=8 dispatch", || {
            let mut inner = blk.clone();
            eng.bmod(bs, &blk, &blk, &mut inner).unwrap();
            black_box(inner[0]);
        });
        println!("{}", r.report());

        let mut big = vec![0.0f32; 80 * 80];
        for (i, v) in big.iter_mut().enumerate() {
            *v = (i % 83) as f32 * 0.02 + 1.0;
        }
        let mut i0 = big.clone();
        eng.bmod(80, &big, &big, &mut i0).unwrap();
        let r = b.measure("pjrt bmod bs=80 dispatch", || {
            let mut inner = big.clone();
            eng.bmod(80, &big, &big, &mut inner).unwrap();
            black_box(inner[0]);
        });
        println!("{}", r.report());

        // rust kernel for comparison.
        let r = b.measure("rust bmod bs=80 (in-process)", || {
            let mut inner = big.clone();
            gprm::linalg::lu::bmod(&big, &big, &mut inner, 80);
            black_box(inner[0]);
        });
        println!("{}", r.report());
    } else {
        println!("(skipping PJRT benches: run `make artifacts`)");
    }

    gprm.shutdown();
    rt2.shutdown();
    omp.shutdown();
}
