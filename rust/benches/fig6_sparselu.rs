//! Bench: regenerate paper Fig 6 (SparseLU 4000×4000 execution time
//! vs block count, GPRM vs OpenMP tasks) and, as a wall-clock
//! complement, time the *real* host-thread SparseLU implementations
//! on a reduced matrix with the in-crate harness.
//!
//! `cargo bench --bench fig6_sparselu`

use gprm::apps::sparselu::{sparselu_gprm, sparselu_omp, LuRunConfig};
use gprm::bench::Bench;
use gprm::coordinator::kernel::Registry;
use gprm::coordinator::{GprmConfig, GprmRuntime};
use gprm::harness::{run_experiment, Scale};
use gprm::linalg::genmat::genmat;
use gprm::linalg::lu::sparselu_seq;
use gprm::omp::OmpRuntime;

fn main() {
    // Simulator: the figure at a scale that keeps NB=500 (~10M tasks)
    // tractable in CI; pass GPRM_FULL=1 for paper scale.
    let scale = if std::env::var("GPRM_FULL").is_ok() {
        Scale(1.0)
    } else {
        Scale(0.4)
    };
    let report = run_experiment("fig6", scale);
    println!("{}", report.render());
    assert!(report.all_pass(), "fig6 shape checks failed");

    // Host wall-clock: the real runtimes on a 400×400 matrix
    // (25 blocks of 16), dominated by runtime overhead on 1 core.
    let threads = 8;
    let b = Bench::quick();
    let a0 = genmat(25, 16);

    let r = b.measure_once("host sparselu seq   25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_seq(&mut a);
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());

    let gprm = GprmRuntime::new(
        GprmConfig { n_tiles: threads, pin: false },
        Registry::new(),
    );
    let r = b.measure_once("host sparselu gprm  25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_gprm(&gprm, &mut a, &LuRunConfig::default());
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());
    gprm.shutdown();

    let omp = OmpRuntime::new(threads);
    let r = b.measure_once("host sparselu omp   25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_omp(&omp, &mut a, &LuRunConfig::default());
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());
    omp.shutdown();
}
