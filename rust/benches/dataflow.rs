//! Bench: dataflow (DAG) scheduling vs the paper's phase-barrier
//! drivers — simulator makespans on the Fig-6-shaped workload, plus
//! host wall-clock of the real SparseLU drivers.
//!
//! `cargo bench --bench dataflow`

use gprm::apps::sparselu::{
    sparselu_dataflow, sparselu_gprm, sparselu_omp, DataflowRt, LuRunConfig,
};
use gprm::bench::Bench;
use gprm::coordinator::GprmRuntime;
use gprm::harness::{run_experiment, Scale};
use gprm::linalg::genmat::genmat;
use gprm::omp::OmpRuntime;

fn main() {
    // Simulator: the dataflow experiment at the acceptance scale
    // (NB=32 is cheap enough to always run unscaled).
    let report = run_experiment("dataflow", Scale(1.0));
    println!("{}", report.render());
    assert!(report.all_pass(), "dataflow shape checks failed");

    // Host wall-clock: phase-barrier vs dataflow on the same matrix.
    let threads = 8;
    let b = Bench::quick();
    let a0 = genmat(25, 16);

    let omp = OmpRuntime::new(threads);
    let r = b.measure_once("host sparselu omp (barriers) 25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_omp(&omp, &mut a, &LuRunConfig::default());
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());

    let r = b.measure_once("host sparselu dataflow-omp  25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_dataflow(&DataflowRt::Omp(&omp), &mut a, &LuRunConfig::default());
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());
    omp.shutdown();

    let gprm = GprmRuntime::with_tiles(threads);
    let r = b.measure_once("host sparselu gprm (barriers) 25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_gprm(&gprm, &mut a, &LuRunConfig::default());
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());

    let r = b.measure_once("host sparselu dataflow-gprm 25x25 bs=16", || {
        let mut a = a0.deep_clone();
        sparselu_dataflow(&DataflowRt::Gprm(&gprm), &mut a, &LuRunConfig::default());
        gprm::bench::black_box(a.allocated_blocks());
    });
    println!("{}", r.report());
    gprm.shutdown();
}
