//! Integration suite for the microkernel layer through the public
//! API: SIMD-vs-scalar dispatch equality, packed-tile round trips,
//! the fast-mode residual bound, and the end-to-end conformance run
//! with startup autotuning enabled. Runs in its own process, so the
//! global tuned-size cache needs no cross-test serialisation here
//! beyond using one test for everything that touches it.
//!
//! CI runs this suite twice — default features and `--features simd`
//! — in release mode, so the intrinsic paths execute under the exact
//! assertions the scalar build establishes.

use gprm::apps::dataflow::{run_workload_mode, DataflowRt};
use gprm::linalg::autotune::{
    autotune_registry, tune, Calibrator, HostCalibrator, ModelCalibrator,
    CANDIDATE_BS,
};
use gprm::linalg::dense::DenseMatrix;
use gprm::linalg::microkernel::{
    bmod_mk, gemm_nt_mk, madd_mk, simd_level, syrk_mk, trsm_mk,
    KernelMode, PackedTile, SimdLevel,
};
use gprm::omp::OmpRuntime;
use gprm::sched::workload::{
    clear_tuned_bs, registry, tuned_bs, Params, Workload,
};
use gprm::sched::ExecOpts;
use gprm::tilesim::CostModel;

fn block(bs: usize, seed: u32) -> Vec<f32> {
    DenseMatrix::bots_random(bs, bs, seed).as_slice().to_vec()
}

fn rel_diff(a: &[f32], b: &[f32]) -> f64 {
    let scale = a
        .iter()
        .fold(0f64, |m, &x| m.max(f64::from(x).abs()))
        .max(1e-30);
    a.iter()
        .zip(b)
        .fold(0f64, |m, (&x, &y)| m.max((f64::from(x) - f64::from(y)).abs()))
        / scale
}

#[test]
fn dispatch_level_matches_build_features() {
    // Without the `simd` feature the dispatcher is the scalar constant;
    // with it, whatever the CPU supports (still allowed to be scalar).
    if !cfg!(feature = "simd") {
        assert_eq!(simd_level(), SimdLevel::Scalar);
    }
    // Either way the level must be stable across calls (cached).
    assert_eq!(simd_level(), simd_level());
}

#[test]
fn packed_tiles_round_trip_through_the_public_api() {
    for bs in [1usize, 3, 4, 7, 8, 16] {
        let src = block(bs, 11);
        let mut back = vec![0.0f32; bs * bs];
        PackedTile::pack(&src, bs).unpack_into(&mut back);
        assert_eq!(src, back, "pack/unpack bs={bs}");
        let mut tback = vec![0.0f32; bs * bs];
        PackedTile::pack_transposed(&src, bs)
            .unpack_transposed_into(&mut tback);
        assert_eq!(src, tback, "transposed pack/unpack bs={bs}");
    }
}

#[test]
fn bit_identical_mode_is_exact_across_dispatch_levels() {
    // Whatever level the build dispatches (scalar here; SSE2/AVX under
    // `--features simd` on x86-64), BitIdentical must produce the same
    // f32 bits as the scalar reference semantics. The in-crate unit
    // tests pin the reference; this pins the public surface per build.
    for bs in [4usize, 8, 16] {
        let (a, b, c0) = (block(bs, 21), block(bs, 22), block(bs, 23));

        let mut c1 = c0.clone();
        bmod_mk(KernelMode::BitIdentical, &a, &b, &mut c1, bs);
        let mut c2 = c0.clone();
        gprm::linalg::lu::bmod(&a, &b, &mut c2, bs);
        assert_eq!(c1, c2, "bmod bs={bs}");

        let mut g1 = c0.clone();
        gemm_nt_mk(KernelMode::BitIdentical, &a, &b, &mut g1, bs);
        let mut g2 = c0.clone();
        gprm::linalg::cholesky::gemm_nt(&a, &b, &mut g2, bs);
        assert_eq!(g1, g2, "gemm_nt bs={bs}");

        let mut s1 = c0.clone();
        syrk_mk(KernelMode::BitIdentical, &a, &mut s1, bs);
        let mut s2 = c0.clone();
        gprm::linalg::cholesky::syrk(&a, &mut s2, bs);
        assert_eq!(s1, s2, "syrk bs={bs}");

        let spd = gprm::linalg::cholesky::gen_spd(1, bs);
        let mut diag = spd.block(0, 0).unwrap().to_vec();
        gprm::linalg::cholesky::potrf(&mut diag, bs);
        let mut t1 = c0.clone();
        trsm_mk(KernelMode::BitIdentical, &diag, &mut t1, bs);
        let mut t2 = c0.clone();
        gprm::linalg::cholesky::trsm(&diag, &mut t2, bs);
        assert_eq!(t1, t2, "trsm bs={bs}");

        let mut m1 = c0.clone();
        madd_mk(KernelMode::BitIdentical, &a, &b, &mut m1, bs);
        let mut m2 = c0.clone();
        gprm::sched::workload::madd(&a, &b, &mut m2, bs);
        assert_eq!(m1, m2, "madd bs={bs}");
    }
}

#[test]
fn fast_mode_is_residual_bounded_on_every_kernel() {
    for bs in [4usize, 8, 9, 16] {
        let (a, b, c0) = (block(bs, 41), block(bs, 42), block(bs, 43));
        let mut bit = c0.clone();
        let mut fast = c0.clone();
        bmod_mk(KernelMode::BitIdentical, &a, &b, &mut bit, bs);
        bmod_mk(KernelMode::Fast, &a, &b, &mut fast, bs);
        assert!(rel_diff(&bit, &fast) <= 1e-5, "bmod bs={bs}");
        let mut bit = c0.clone();
        let mut fast = c0.clone();
        madd_mk(KernelMode::BitIdentical, &a, &b, &mut bit, bs);
        madd_mk(KernelMode::Fast, &a, &b, &mut fast, bs);
        assert!(rel_diff(&bit, &fast) <= 1e-5, "madd bs={bs}");
    }
}

#[test]
fn conformance_holds_with_autotune_enabled() {
    // The full `--autotune on` path: tune every workload, cache the
    // winners, then run each at its tuned sizing on a real host —
    // results must stay bit-identical to the sequential reference in
    // the conformance default, and residual-bounded in fast mode.
    // This test owns the process-global tuned cache (its own binary).
    let n = 64;
    let results = autotune_registry(n, &ModelCalibrator::new(4));
    assert_eq!(results.len(), registry().len());
    let rt = OmpRuntime::new(4);
    for (w, r) in registry().iter().zip(&results) {
        let bs = tuned_bs(*w).expect("autotune cached a winner");
        assert_eq!(bs, r.best_bs);
        assert!(n % bs == 0, "{}: tuned bs divides n", w.name());
        let p = Params::new(n / bs, bs);
        let orig = w.make_input(&p, 0);
        let mut want = w.make_input(&p, 0);
        w.reference_seq(&mut want);
        let mut got = w.make_input(&p, 0);
        run_workload_mode(
            &DataflowRt::Omp(&rt),
            *w,
            &mut got,
            ExecOpts::default(),
            KernelMode::BitIdentical,
        )
        .unwrap_or_else(|e| panic!("{}: {e:?}", w.name()));
        w.verify_bits(&got, &want)
            .unwrap_or_else(|e| panic!("tuned bs={bs}: {e}"));
        let mut fast = w.make_input(&p, 0);
        run_workload_mode(
            &DataflowRt::Omp(&rt),
            *w,
            &mut fast,
            ExecOpts::default(),
            KernelMode::Fast,
        )
        .unwrap_or_else(|e| panic!("{}: {e:?}", w.name()));
        let res = w.residual(&orig, &fast);
        assert!(res < 1e-3, "{} fast residual {res}", w.name());
    }
    rt.shutdown();
    clear_tuned_bs();
    assert!(registry().iter().all(|w| tuned_bs(*w).is_none()));
}

#[test]
fn model_and_host_calibrators_agree_on_the_sweep_shape() {
    // Both calibrators must produce a full sweep at n=128; the model's
    // winner is interior by construction. The host winner depends on
    // this machine, so only the sweep's completeness is asserted.
    let w = &gprm::sched::workload::Cholesky;
    let m = tune(w, 128, &ModelCalibrator::new(1));
    assert_eq!(m.candidates.len(), CANDIDATE_BS.len());
    assert!(m.best_bs == 8 || m.best_bs == 16, "model best {}", m.best_bs);
    let h = tune(w, 128, &HostCalibrator::new());
    assert_eq!(h.candidates.len(), CANDIDATE_BS.len());
    assert!(h.candidates.iter().all(|&(_, c)| c > 0.0));
}

#[test]
fn simd_pricing_never_slower_at_acceptance_sizes() {
    // The acceptance machine-check, through the public API: packed/
    // SIMD never prices above scalar at bs >= 8 in the cost model.
    let c = CostModel::default();
    for w in registry() {
        for bs in [8usize, 16, 32] {
            let p = Params::new(4, bs);
            let scalar = ModelCalibrator {
                cost: c.clone(),
                workers: 1,
                simd: false,
                fast: false,
            }
            .cost(*w, &p);
            let simd = ModelCalibrator {
                cost: c.clone(),
                workers: 1,
                simd: true,
                fast: false,
            }
            .cost(*w, &p);
            assert!(
                simd <= scalar,
                "{} bs={bs}: {simd} > {scalar}",
                w.name()
            );
        }
    }
}
