//! Concurrent-submission stress tests for the persistent multi-job
//! pool (`sched::pool`): ≥8 mixed SparseLU/Cholesky jobs race through
//! ONE pool — under randomized kernel spins so claim/steal/park and
//! cross-job interleavings vary wildly — and every job's matrix must
//! come out **bit-identical** (f32) to its sequential reference, with
//! no deadlock (a stuck pool hangs the test). Admission is stressed
//! too: the capacity is set so only part of the stream fits at once,
//! forcing FIFO queuing, and one test drives three successive waves
//! through the same pool to exercise slot recycling and deep-idle
//! parking between waves.

use gprm::apps::cholesky::CHOLESKY_RUST_KERNELS;
use gprm::apps::dataflow::{run_dataflow_batch, BlockKernel, PoolJob};
use gprm::apps::matmul::{
    matmul_blocked_input, matmul_blocked_seq, matmul_extract_c,
    MATMUL_RUST_KERNELS,
};
use gprm::apps::sparselu::LU_RUST_KERNELS;
use gprm::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use gprm::linalg::cholesky::{
    cholesky_seq, gemm_nt, gen_spd, potrf, syrk, trsm,
};
use gprm::linalg::dense::DenseMatrix;
use gprm::linalg::genmat::{genmat, genmat_pattern};
use gprm::linalg::lu::{bdiv, bmod, fwd, lu0, sparselu_seq};
use gprm::sched::workload::kernel_runner;
use gprm::sched::{
    JobHandle, Pool, PoolConfig, SubmitError, TaskGraph, TaskId,
};
use gprm::testkit::{check, Triple, UsizeRange};
use gprm::util::prng::SplitMix64;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Cheap deterministic spin: xorshift a counter with the case seed
/// into a busy-wait length, so schedules differ run to run and case
/// to case.
fn spin_for(x: usize, seed: usize) {
    let mut v = (x as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(seed as u64 | 1);
    v ^= v >> 12;
    v ^= v << 25;
    v ^= v >> 27;
    for _ in 0..(v % 2_000) as u32 {
        std::hint::spin_loop();
    }
}

#[test]
fn stress_concurrent_mixed_jobs_bit_identical() {
    // The satellite's acceptance test: 8 mixed jobs (4 SparseLU + 4
    // Cholesky, alternating) through one pool whose capacity only
    // fits about half the stream (queued admission in every case),
    // with randomized kernel spins. Per-job f32 bit-identity against
    // the sequential references, every case.
    check(
        "pool-mixed-stress",
        20,
        &Triple(UsizeRange(3, 13), UsizeRange(1, 9), UsizeRange(0, 1 << 16)),
        |&(nb, workers, seed)| {
            let bs = 4 + (seed % 4); // bs ∈ [4, 7]
            let mut lu_want = genmat(nb, bs);
            sparselu_seq(&mut lu_want);
            let lu_want = lu_want.to_dense();
            let mut ch_want = gen_spd(nb, bs);
            cholesky_seq(&mut ch_want);
            let ch_want = ch_want.to_dense();

            let lu_graph = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let ch_graph = TaskGraph::cholesky(nb);
            let mut mats: Vec<BlockedSparseMatrix> = (0..8)
                .map(|i| {
                    if i % 2 == 0 { genmat(nb, bs) } else { gen_spd(nb, bs) }
                })
                .collect();

            let ctr = AtomicUsize::new(0);
            let sp = || spin_for(ctr.fetch_add(1, Ordering::Relaxed), seed);
            let k_lu0 = |_: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                lu0(w, bs)
            };
            let k_fwd = |r: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                fwd(r[0], w, bs)
            };
            let k_bdiv = |r: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                bdiv(r[0], w, bs)
            };
            let k_bmod = |r: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                bmod(r[0], r[1], w, bs)
            };
            let lu_kernels: [BlockKernel; 4] =
                [&k_lu0, &k_fwd, &k_bdiv, &k_bmod];
            let k_potrf = |_: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                potrf(w, bs)
            };
            let k_trsm = |r: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                trsm(r[0], w, bs)
            };
            let k_syrk = |r: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                syrk(r[0], w, bs)
            };
            let k_gemm = |r: &[&[f32]], w: &mut [f32], bs: usize| {
                sp();
                gemm_nt(r[0], r[1], w, bs)
            };
            let ch_kernels: [BlockKernel; 4] =
                [&k_potrf, &k_trsm, &k_syrk, &k_gemm];

            // Half-stream capacity: forces FIFO queuing, never drops.
            let total = 4 * (lu_graph.len() + ch_graph.len());
            let cap = (total / 2).max(lu_graph.len().max(ch_graph.len()));
            let pool = Pool::with_config(PoolConfig {
                workers,
                task_capacity: cap,
                max_jobs: 8,
                max_pending: None,
                domains: 1,
            });
            let mut jobs: Vec<PoolJob> = mats
                .iter_mut()
                .enumerate()
                .map(|(i, a)| {
                    if i % 2 == 0 {
                        PoolJob { a, graph: &lu_graph, kernels: &lu_kernels }
                    } else {
                        PoolJob { a, graph: &ch_graph, kernels: &ch_kernels }
                    }
                })
                .collect();
            let stats = run_dataflow_batch(&pool, &mut jobs)
                .map_err(|e| e.to_string())?;
            drop(jobs);
            for (i, s) in stats.iter().enumerate() {
                let want =
                    if i % 2 == 0 { lu_graph.len() } else { ch_graph.len() };
                if s.executed != want {
                    return Err(format!(
                        "job {i}: executed {} of {want}",
                        s.executed
                    ));
                }
            }
            for (i, m) in mats.iter().enumerate() {
                let want = if i % 2 == 0 { &lu_want } else { &ch_want };
                if m.to_dense().as_slice() != want.as_slice() {
                    return Err(format!(
                        "job {i} not bit-identical to its sequential \
                         reference (nb={nb} bs={bs} workers={workers})"
                    ));
                }
            }
            pool.shutdown();
            Ok(())
        },
    );
}

#[test]
fn stress_three_waves_through_one_pool() {
    // Persistence across bursts: three successive 8-job waves reuse
    // one pool (slot recycling, deep-idle park between waves), each
    // wave fully verified.
    check(
        "pool-wave-stress",
        8,
        &Triple(UsizeRange(3, 10), UsizeRange(2, 9), UsizeRange(0, 1 << 16)),
        |&(nb, workers, seed)| {
            let bs = 4 + (seed % 4);
            let mut lu_want = genmat(nb, bs);
            sparselu_seq(&mut lu_want);
            let lu_want = lu_want.to_dense();
            let mut ch_want = gen_spd(nb, bs);
            cholesky_seq(&mut ch_want);
            let ch_want = ch_want.to_dense();
            let lu_graph = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let ch_graph = TaskGraph::cholesky(nb);
            let pool = Pool::new(workers);
            for wave in 0..3 {
                let mut mats: Vec<BlockedSparseMatrix> = (0..8)
                    .map(|i| {
                        if i % 2 == 0 {
                            genmat(nb, bs)
                        } else {
                            gen_spd(nb, bs)
                        }
                    })
                    .collect();
                let mut jobs: Vec<PoolJob> = mats
                    .iter_mut()
                    .enumerate()
                    .map(|(i, a)| {
                        if i % 2 == 0 {
                            PoolJob {
                                a,
                                graph: &lu_graph,
                                kernels: &LU_RUST_KERNELS,
                            }
                        } else {
                            PoolJob {
                                a,
                                graph: &ch_graph,
                                kernels: &CHOLESKY_RUST_KERNELS,
                            }
                        }
                    })
                    .collect();
                run_dataflow_batch(&pool, &mut jobs)
                    .map_err(|e| e.to_string())?;
                drop(jobs);
                for (i, m) in mats.iter().enumerate() {
                    let want = if i % 2 == 0 { &lu_want } else { &ch_want };
                    if m.to_dense().as_slice() != want.as_slice() {
                        return Err(format!(
                            "wave {wave} job {i} not bit-identical"
                        ));
                    }
                }
                if wave == 1 {
                    // Let the workers reach the deep-idle park before
                    // the next wave hits the injector.
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
            pool.shutdown();
            Ok(())
        },
    );
}

#[test]
fn fifo_admission_order_under_capacity_churn() {
    // Property test: randomized seeded submit/wait interleavings
    // against a pool whose task budget (and job-slot count) only fits
    // part of the stream. Two invariants, every case: admission order
    // (the pool's event clock, `JobHandle::admission_index`) equals
    // submission order, and the pending queue never exceeds the
    // submitted backlog — neither while submitting nor at the end.
    check(
        "pool-fifo-churn",
        25,
        &Triple(UsizeRange(3, 8), UsizeRange(1, 7), UsizeRange(0, 1 << 16)),
        |&(nb, workers, seed)| {
            let g = TaskGraph::cholesky(nb);
            // Budget for one or two graphs depending on the case, and
            // only 3 job slots for 8 jobs: both admission paths
            // (capacity and slot exhaustion) queue mid-stream.
            let cap = g.len() * (1 + seed % 2);
            let pool = Pool::with_config(PoolConfig {
                workers,
                task_capacity: cap,
                max_jobs: 3,
                max_pending: None,
                domains: 1,
            });
            let n_jobs = 8usize;
            let mut rng = SplitMix64::new(seed as u64 ^ 0xD1CE);
            pool.scope(|s| {
                let mut handles: Vec<JobHandle> = Vec::new();
                for i in 0..n_jobs {
                    let h = s
                        .submit(&g, move |t: TaskId| {
                            spin_for(t.0 * 31 + i, seed)
                        })
                        .map_err(|e| e.to_string())?;
                    handles.push(h);
                    let depth = pool.pending_jobs();
                    if depth > n_jobs - 1 {
                        return Err(format!(
                            "pending depth {depth} exceeds the \
                             submitted backlog after job {i}"
                        ));
                    }
                    // Churn: randomly wait on an arbitrary earlier
                    // handle mid-stream, draining part of the queue.
                    if rng.chance(0.4) {
                        let k = rng.range(0, handles.len());
                        handles[k].wait().map_err(|e| e.to_string())?;
                    }
                }
                for h in &handles {
                    h.wait().map_err(|e| e.to_string())?;
                }
                let adm: Option<Vec<usize>> =
                    handles.iter().map(|h| h.admission_index()).collect();
                let adm = adm.ok_or("a completed job has no \
                                     admission stamp")?;
                if !adm.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!(
                        "admission order differs from submission \
                         order: {adm:?} (workers={workers} cap={cap})"
                    ));
                }
                Ok(())
            })?;
            if pool.peak_pending() > n_jobs - 1 {
                return Err(format!(
                    "peak pending {} exceeds the submitted backlog",
                    pool.peak_pending()
                ));
            }
            if pool.pending_jobs() != 0 {
                return Err("queue not drained after all waits".into());
            }
            pool.shutdown();
            Ok(())
        },
    );
}

#[test]
fn poisoned_job_mid_stream_contains_and_pool_serves_fresh_wave() {
    // Regression test for poison containment: job 3 of a 6-job mixed
    // wave panics mid-graph; every sibling's output must still be
    // bit-identical to its solo sequential run, and the same pool
    // must then serve a fully clean second wave (slot recycling and
    // admission state survive the failure).
    let (nb, bs) = (7usize, 5usize);
    let mut lu_want = genmat(nb, bs);
    sparselu_seq(&mut lu_want);
    let lu_want = lu_want.to_dense();
    let mut ch_want = gen_spd(nb, bs);
    cholesky_seq(&mut ch_want);
    let ch_want = ch_want.to_dense();
    let lu_graph = TaskGraph::sparselu(&genmat_pattern(nb), nb);
    let ch_graph = TaskGraph::cholesky(nb);
    let pool = Pool::new(4);
    for wave in 0..2 {
        let poison_at = if wave == 0 { Some(3usize) } else { None };
        let shares: Vec<SharedBlocked> = (0..6)
            .map(|i| {
                SharedBlocked::new(if i % 2 == 0 {
                    genmat(nb, bs)
                } else {
                    gen_spd(nb, bs)
                })
            })
            .collect();
        // Runners are built outside the scope: submit borrows them
        // for the scope's 'env lifetime.
        let runners: Vec<Box<dyn Fn(TaskId) + Send + Sync + '_>> = shares
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                let graph =
                    if i % 2 == 0 { &lu_graph } else { &ch_graph };
                let kernels: &[BlockKernel] = if i % 2 == 0 {
                    &LU_RUST_KERNELS
                } else {
                    &CHOLESKY_RUST_KERNELS
                };
                let base = kernel_runner(graph, kernels, sh, bs);
                let poisoned = poison_at == Some(i);
                Box::new(move |t: TaskId| {
                    if poisoned && t.0 == 1 {
                        panic!("scenario poison: injected kernel failure");
                    }
                    base(t)
                }) as Box<dyn Fn(TaskId) + Send + Sync + '_>
            })
            .collect();
        pool.scope(|s| {
            let handles: Vec<JobHandle> = runners
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    let graph =
                        if i % 2 == 0 { &lu_graph } else { &ch_graph };
                    s.submit(graph, move |t| r(t)).unwrap()
                })
                .collect();
            for (i, h) in handles.iter().enumerate() {
                match h.wait() {
                    Err(e) if poison_at == Some(i) => assert!(
                        e.to_string().contains("scenario poison"),
                        "wave {wave} job {i}: wrong poison message: {e}"
                    ),
                    Err(e) => {
                        panic!("wave {wave} job {i} not contained: {e}")
                    }
                    Ok(stats) => {
                        assert_ne!(
                            poison_at,
                            Some(i),
                            "wave {wave}: poisoned job reported success"
                        );
                        let want = if i % 2 == 0 {
                            lu_graph.len()
                        } else {
                            ch_graph.len()
                        };
                        assert_eq!(
                            stats.executed, want,
                            "wave {wave} job {i} did not drain"
                        );
                    }
                }
            }
        });
        drop(runners);
        for (i, sh) in shares.into_iter().enumerate() {
            if poison_at == Some(i) {
                continue; // poisoned output is partial by design
            }
            let got = sh.into_inner().to_dense();
            let want = if i % 2 == 0 { &lu_want } else { &ch_want };
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "wave {wave} job {i} not bit-identical to its solo run"
            );
        }
        assert_eq!(pool.active_jobs(), 0, "wave {wave} left jobs active");
    }
    pool.shutdown();
}

#[test]
fn all_three_workloads_share_one_pool() {
    // 12-job stream mixing SparseLU, Cholesky AND the blocked matmul:
    // the engine is kernel-agnostic, so one pool serves all three,
    // each bit-identical to its own sequential reference.
    let (nb, bs) = (6usize, 5usize);
    let mut lu_want = genmat(nb, bs);
    sparselu_seq(&mut lu_want);
    let lu_want = lu_want.to_dense();
    let mut ch_want = gen_spd(nb, bs);
    cholesky_seq(&mut ch_want);
    let ch_want = ch_want.to_dense();
    let mm_a = DenseMatrix::bots_random(nb * bs, nb * bs, 91);
    let mm_b = DenseMatrix::bots_random(nb * bs, nb * bs, 92);
    let mm_want = matmul_blocked_seq(&mm_a, &mm_b, nb, bs);

    let lu_graph = TaskGraph::sparselu(&genmat_pattern(nb), nb);
    let ch_graph = TaskGraph::cholesky(nb);
    let mm_graph = TaskGraph::matmul(nb);
    let mut mats: Vec<BlockedSparseMatrix> = (0..12)
        .map(|i| match i % 3 {
            0 => genmat(nb, bs),
            1 => gen_spd(nb, bs),
            _ => matmul_blocked_input(&mm_a, &mm_b, nb, bs),
        })
        .collect();
    let pool = Pool::new(4);
    let mut jobs: Vec<PoolJob> = mats
        .iter_mut()
        .enumerate()
        .map(|(i, a)| match i % 3 {
            0 => PoolJob { a, graph: &lu_graph, kernels: &LU_RUST_KERNELS },
            1 => PoolJob {
                a,
                graph: &ch_graph,
                kernels: &CHOLESKY_RUST_KERNELS,
            },
            _ => PoolJob {
                a,
                graph: &mm_graph,
                kernels: &MATMUL_RUST_KERNELS,
            },
        })
        .collect();
    let stats = run_dataflow_batch(&pool, &mut jobs).unwrap();
    assert_eq!(stats.len(), 12);
    drop(jobs);
    for (i, m) in mats.iter().enumerate() {
        match i % 3 {
            0 => assert_eq!(
                m.to_dense().as_slice(),
                lu_want.as_slice(),
                "sparselu job {i}"
            ),
            1 => assert_eq!(
                m.to_dense().as_slice(),
                ch_want.as_slice(),
                "cholesky job {i}"
            ),
            _ => assert_eq!(
                matmul_extract_c(m, nb).as_slice(),
                mm_want.as_slice(),
                "matmul job {i}"
            ),
        }
    }
    pool.shutdown();
}

#[test]
fn shed_boundary_is_exact_and_never_drops_admitted() {
    // Property test for the overload shedding boundary: a 1-slot pool
    // whose only active job is gated open, so the pending queue fills
    // deterministically. Exactly `limit` further submissions are
    // admitted; the next one must be refused with the typed
    // `Overloaded` carrying the *exact* queue coordinates; and after
    // the gate opens, every admitted job (and a whole second wave)
    // completes — shedding never drops admitted work.
    use std::sync::atomic::AtomicBool;
    check(
        "pool-shed-boundary",
        12,
        &Triple(UsizeRange(1, 5), UsizeRange(4, 6), UsizeRange(0, 1 << 16)),
        |&(limit, nb, seed)| {
            let g = TaskGraph::cholesky(nb);
            let pool = Pool::with_config(PoolConfig {
                workers: 2,
                task_capacity: g.len() * (limit + 2),
                max_jobs: 1,
                max_pending: Some(limit),
                domains: 1,
            });
            let release = AtomicBool::new(false);
            let gate_runner = |_t: TaskId| {
                while !release.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            };
            pool.scope(|s| {
                let gate =
                    s.submit(&g, &gate_runner).map_err(|e| e.to_string())?;
                let mut fillers = Vec::new();
                for i in 0..limit {
                    fillers.push(s.submit(&g, move |t: TaskId| {
                        spin_for(t.0 + i, seed)
                    }).map_err(|e| {
                        format!("filler {i} refused below the bound: {e}")
                    })?);
                }
                match s.submit(&g, |_t: TaskId| {}) {
                    Err(gprm::sched::Error::Submit(
                        SubmitError::Overloaded { pending, limit: l },
                    )) => {
                        if pending != limit || l != limit {
                            return Err(format!(
                                "Overloaded coordinates {pending}/{l}, \
                                 want exactly {limit}/{limit}"
                            ));
                        }
                    }
                    Err(e) => {
                        return Err(format!(
                            "expected Overloaded at the bound, got {e}"
                        ))
                    }
                    Ok(_) => {
                        return Err(format!(
                            "submission {} past the bound was admitted",
                            limit + 1
                        ))
                    }
                }
                release.store(true, Ordering::Release);
                gate.wait().map_err(|e| e.to_string())?;
                for (i, f) in fillers.iter().enumerate() {
                    f.wait().map_err(|e| {
                        format!("admitted filler {i} was dropped: {e}")
                    })?;
                }
                // Second wave: the shed state fully recovers once the
                // queue drains — the same pool admits and completes a
                // fresh batch of `limit + 1` jobs (serially waited, so
                // the bound is never hit).
                for i in 0..=limit {
                    let h = s.submit(&g, move |t: TaskId| {
                        spin_for(t.0 * 7 + i, seed)
                    }).map_err(|e| {
                        format!("wave-2 job {i} refused after drain: {e}")
                    })?;
                    h.wait().map_err(|e| e.to_string())?;
                }
                Ok(())
            })?;
            if pool.pending_jobs() != 0 {
                return Err("queue not drained after all waits".into());
            }
            pool.shutdown();
            Ok(())
        },
    );
}

#[test]
fn drain_races_concurrent_submitters_typed_and_bit_identical() {
    // `Pool::drain` racing multi-threaded submission: four submitter
    // threads each push SparseLU jobs with real kernels; a barrier
    // lines everyone up so the drain fires strictly between each
    // thread's first and second half. Deterministic outcome: every
    // pre-drain submission is admitted and completes bit-identically
    // to the solo sequential run, every post-drain submission is
    // refused with the typed `Draining` — nothing admitted is ever
    // dropped, nothing refused is untyped.
    use std::sync::Barrier;
    let (nb, bs) = (7usize, 5usize);
    let mut want = genmat(nb, bs);
    sparselu_seq(&mut want);
    let want = want.to_dense();
    let graph = TaskGraph::sparselu(&genmat_pattern(nb), nb);
    let n_sub = 4usize;
    let per = 6usize; // jobs per submitter; first half pre-drain
    let half = per / 2;
    let pool = Pool::with_config(PoolConfig {
        workers: 3,
        task_capacity: graph.len() * 2,
        max_jobs: 2,
        max_pending: None,
        domains: 1,
    });
    let shares: Vec<SharedBlocked> = (0..n_sub * per)
        .map(|_| SharedBlocked::new(genmat(nb, bs)))
        .collect();
    let runners: Vec<_> = shares
        .iter()
        .map(|sh| kernel_runner(&graph, &LU_RUST_KERNELS, sh, bs))
        .collect();
    let barrier = Barrier::new(n_sub + 1);
    // admitted[k] records whether submission k returned a handle.
    let admitted: Vec<AtomicUsize> =
        (0..n_sub * per).map(|_| AtomicUsize::new(0)).collect();
    pool.scope(|s| {
        std::thread::scope(|ts| {
            for i in 0..n_sub {
                let (graph, barrier) = (&graph, &barrier);
                let (runners, admitted) = (&runners, &admitted);
                ts.spawn(move || {
                    let mut handles = Vec::new();
                    for j in 0..half {
                        let k = i * per + j;
                        let h = s
                            .submit(graph, &runners[k])
                            .expect("pre-drain submission refused");
                        admitted[k].store(1, Ordering::SeqCst);
                        handles.push(h);
                    }
                    barrier.wait(); // all first halves submitted
                    barrier.wait(); // drain completed
                    for j in half..per {
                        let k = i * per + j;
                        match s.submit(graph, &runners[k]) {
                            Err(gprm::sched::Error::Submit(
                                SubmitError::Draining,
                            )) => {}
                            Err(e) => panic!(
                                "post-drain submission {k}: want the \
                                 typed Draining, got {e}"
                            ),
                            Ok(_) => panic!(
                                "post-drain submission {k} was admitted"
                            ),
                        }
                    }
                    for (j, h) in handles.iter().enumerate() {
                        h.wait().unwrap_or_else(|e| {
                            panic!("admitted job {i}/{j} dropped: {e}")
                        });
                    }
                });
            }
            barrier.wait(); // every submitter parked with half in
            pool.drain(); // blocks until all admitted jobs complete
            barrier.wait();
        });
    });
    drop(runners);
    for (k, sh) in shares.into_iter().enumerate() {
        if admitted[k].load(Ordering::SeqCst) == 0 {
            continue; // refused post-drain: input untouched by design
        }
        assert_eq!(
            sh.into_inner().to_dense().as_slice(),
            want.as_slice(),
            "admitted job {k} not bit-identical to its solo run"
        );
    }
    assert_eq!(pool.active_jobs(), 0);
    assert_eq!(pool.pending_jobs(), 0);
    pool.shutdown();
}
