//! Cross-module integration tests: the full L3 stack (runtimes + apps
//! + workloads + simulator) without PJRT (see runtime_pjrt.rs for the
//! artifact path).

use gprm::apps::cholesky::cholesky_dataflow;
use gprm::apps::matmul::{run_matmul, MatmulApproach, MatmulExec};
use gprm::apps::sparselu::{
    sparselu_dataflow, sparselu_gprm, sparselu_omp, DataflowRt, LuRunConfig,
};
use gprm::coordinator::kernel::Registry;
use gprm::coordinator::{ClosureKernel, GprmConfig, GprmRuntime, Prog, Value};
use gprm::linalg::genmat::genmat;
use gprm::linalg::lu::sparselu_seq;
use gprm::linalg::verify::{assert_blocked_close, lu_residual_sparse};
use gprm::omp::OmpRuntime;
use gprm::tilesim::{GprmSim, OmpSim, OmpStrategy, Workload};
use std::sync::Arc;

#[test]
fn sparselu_all_runtimes_agree_and_verify() {
    let nb = 16;
    let bs = 8;
    let a0 = genmat(nb, bs);
    let dense0 = a0.to_dense();

    let mut a_seq = a0.deep_clone();
    sparselu_seq(&mut a_seq);
    assert!(lu_residual_sparse(&dense0, &a_seq) < 1e-4);

    let omp = OmpRuntime::new(6);
    let mut a_omp = a0.deep_clone();
    sparselu_omp(&omp, &mut a_omp, &LuRunConfig::default());

    // Dataflow driver on both host backends.
    let mut a_df_omp = a0.deep_clone();
    sparselu_dataflow(&DataflowRt::Omp(&omp), &mut a_df_omp, &LuRunConfig::default());
    omp.shutdown();

    let gprm = GprmRuntime::with_tiles(6);
    let mut a_gprm = a0.deep_clone();
    sparselu_gprm(&gprm, &mut a_gprm, &LuRunConfig::default());

    let mut a_df_gprm = a0.deep_clone();
    sparselu_dataflow(
        &DataflowRt::Gprm(&gprm),
        &mut a_df_gprm,
        &LuRunConfig::default(),
    );
    gprm.shutdown();

    // Same kernels, same per-block operation order → f32-identical.
    assert_blocked_close(&a_omp, &a_seq, 1e-4);
    assert_blocked_close(&a_gprm, &a_seq, 1e-4);
    assert_blocked_close(&a_df_omp, &a_seq, 1e-4);
    assert_blocked_close(&a_df_gprm, &a_seq, 1e-4);
    assert!(lu_residual_sparse(&dense0, &a_df_omp) < 1e-4);
    assert!(lu_residual_sparse(&dense0, &a_df_gprm) < 1e-4);
}

#[test]
fn cholesky_seq_and_dataflow_agree_and_verify() {
    use gprm::linalg::cholesky::{cholesky_seq, gen_spd, sym_dense};
    use gprm::linalg::verify::chol_residual_sparse;
    use gprm::sched::ExecOpts;
    let nb = 10;
    let bs = 8;
    let a0 = gen_spd(nb, bs);
    let orig = sym_dense(&a0);

    let mut a_seq = a0.deep_clone();
    cholesky_seq(&mut a_seq);
    assert!(chol_residual_sparse(&orig, &a_seq) < 1e-5);

    let omp = OmpRuntime::new(6);
    let gprm = GprmRuntime::with_tiles(6);
    for (name, rt) in
        [("omp", DataflowRt::Omp(&omp)), ("gprm", DataflowRt::Gprm(&gprm))]
    {
        for exec in [ExecOpts::default(), ExecOpts::mutex_baseline()] {
            let mut a = a0.deep_clone();
            cholesky_dataflow(&rt, &mut a, exec);
            // Bit-identical to the sequential tiled reference on both
            // executors (the PR's acceptance criterion).
            assert_eq!(
                a.to_dense().as_slice(),
                a_seq.to_dense().as_slice(),
                "{name} steal={} differs from seq",
                exec.steal
            );
        }
    }
    omp.shutdown();
    gprm.shutdown();
}

#[test]
fn sparselu_dataflow_is_deterministic_across_runs() {
    // Same input, fixed worker count: the dataflow schedule may vary
    // between runs, but the numeric result must be bit-identical —
    // the DAG chains pin the per-block operation order.
    let omp = OmpRuntime::new(7);
    let gprm = GprmRuntime::with_tiles(7);
    for rt in [DataflowRt::Omp(&omp), DataflowRt::Gprm(&gprm)] {
        let mut first = None;
        for _ in 0..3 {
            let mut a = genmat(12, 4);
            sparselu_dataflow(&rt, &mut a, &LuRunConfig::default());
            let d = a.to_dense();
            if let Some(f) = &first {
                let diff = d.max_abs_diff(f);
                assert_eq!(diff, 0.0, "nondeterministic dataflow result");
            } else {
                first = Some(d);
            }
        }
    }
    omp.shutdown();
    gprm.shutdown();
}

#[test]
fn sparselu_repeated_runs_are_deterministic() {
    let gprm = GprmRuntime::with_tiles(5);
    let mut first = None;
    for _ in 0..3 {
        let mut a = genmat(10, 4);
        sparselu_gprm(&gprm, &mut a, &LuRunConfig::default());
        let d = a.to_dense();
        if let Some(f) = &first {
            let diff = d.max_abs_diff(f);
            assert_eq!(diff, 0.0, "nondeterministic result");
        } else {
            first = Some(d);
        }
    }
    gprm.shutdown();
}

#[test]
fn matmul_all_approaches_verify_on_shared_pools() {
    let gprm = GprmRuntime::with_tiles(3);
    let omp = OmpRuntime::new(3);
    let exec = MatmulExec { gprm: Some(&gprm), omp: Some(&omp) };
    for approach in [
        MatmulApproach::OmpForStatic,
        MatmulApproach::OmpForDynamic,
        MatmulApproach::OmpTask { cutoff: 4 },
        MatmulApproach::GprmParFor,
    ] {
        let (_dt, err) = run_matmul(approach, 57, 23, &exec);
        assert_eq!(err, 0.0, "{approach}");
    }
    gprm.shutdown();
    omp.shutdown();
}

#[test]
fn gprm_sexpr_program_drives_real_kernels() {
    // A kernel whose methods do real linear algebra, driven from
    // communication code — the paper's full programming model.
    use gprm::linalg::dense::DenseMatrix;
    use std::sync::Mutex;

    let result = Arc::new(Mutex::new(None::<f32>));
    let result2 = result.clone();
    let mut reg = Registry::new();
    reg.register(Arc::new(
        ClosureKernel::new("la")
            .method("matmul_trace", move |args| {
                let n = args[0].int() as usize;
                let a = DenseMatrix::bots_random(n, n, 1);
                let b = DenseMatrix::bots_random(n, n, 2);
                let c = a.matmul_opt(&b);
                let trace: f32 = (0..n).map(|i| c[(i, i)]).sum();
                *result2.lock().unwrap() = Some(trace);
                Value::Float(trace as f64)
            })
            .method("add", |args| {
                Value::Float(args.iter().map(|v| v.as_float().unwrap()).sum())
            }),
    ));
    let rt = GprmRuntime::new(GprmConfig { n_tiles: 4, pin: false }, reg);
    let prog = Prog::call(
        "la",
        "add",
        vec![
            Prog::call("la", "matmul_trace", vec![Prog::lit(16i64)]),
            Prog::lit(0.0f64),
        ],
    );
    let v = rt.run(&prog).unwrap();
    let trace = result.lock().unwrap().unwrap();
    assert!((v.as_float().unwrap() - trace as f64).abs() < 1e-3);
    rt.shutdown();
}

#[test]
fn simulator_and_host_runtime_agree_on_task_counts() {
    // The simulator's workload DAG must count exactly the tasks the
    // real OMP runtime spawns for the same matrix structure.
    let nb = 12;
    let bs = 4;
    let sim_tasks: usize =
        Workload::sparselu(nb, bs).map(|p| p.task_count()).sum();
    // Count real tasks: fwd + bdiv + bmod spawned by the omp driver
    // equals spawned tasks reported by its regions… easier: count from
    // the structural walk, which the workload tests already tie to the
    // simulator; here tie it to the real factorisation's fill-in.
    let mut a = genmat(nb, bs);
    let before = a.allocated_blocks();
    let omp = OmpRuntime::new(4);
    sparselu_omp(&omp, &mut a, &LuRunConfig::default());
    omp.shutdown();
    let after = a.allocated_blocks();
    // Every fill-in block was created by some bmod task; and there is
    // at least one lu0-equivalent task per kk in the sim stream.
    assert!(sim_tasks >= (after - before) + nb);
}

#[test]
fn sim_experiments_run_end_to_end_smoke() {
    // One cheap simulator run of each kind.
    let m = std::iter::once(Workload::matmul_jobs(300, 20, 20, 1));
    let r = OmpSim::tilepro(8, OmpStrategy::Tasks).run(m, 0, 0);
    assert_eq!(r.tasks, 300);
    let r = GprmSim::tilepro(63).run(Workload::sparselu(10, 8), 100, 256);
    assert!(r.cycles > 0 && r.tasks > 0);
}

#[test]
fn failure_injection_gprm_partial_panic_recovers() {
    let rt = GprmRuntime::with_tiles(4);
    // One failing phase must not poison subsequent phases.
    let e = rt
        .par_invoke(4, |ind| {
            if ind == 3 {
                panic!("injected");
            }
        })
        .unwrap_err();
    assert!(e.contains("injected"));
    // Machine still healthy:
    rt.par_invoke(4, |_| {}).unwrap();
    let mut a = genmat(6, 4);
    sparselu_gprm(&rt, &mut a, &LuRunConfig::default());
    assert!(a.allocated_blocks() > 0);
    rt.shutdown();
}

#[test]
fn failure_injection_omp_task_panic_recovers() {
    let omp = OmpRuntime::new(4);
    let e = omp
        .parallel(|ctx| {
            ctx.single(|| {
                for i in 0..10 {
                    ctx.task(move |_| {
                        if i == 7 {
                            panic!("task 7 injected");
                        }
                    });
                }
            });
        })
        .unwrap_err();
    assert!(e.contains("injected"));
    let mut a = genmat(6, 4);
    sparselu_omp(&omp, &mut a, &LuRunConfig::default());
    assert!(lu_residual_sparse(&genmat(6, 4).to_dense(), &a) < 1e-3);
    omp.shutdown();
}

#[test]
fn large_cl_and_thread_counts_work_on_small_problems() {
    // More tiles/threads than work items must be safe everywhere.
    let gprm = GprmRuntime::with_tiles(16);
    let mut a = genmat(3, 2);
    sparselu_gprm(&gprm, &mut a, &LuRunConfig::default());
    gprm.shutdown();
    let omp = OmpRuntime::new(16);
    let mut b = genmat(3, 2);
    sparselu_omp(&omp, &mut b, &LuRunConfig::default());
    omp.shutdown();
    assert_blocked_close(&a, &b, 1e-5);
}
