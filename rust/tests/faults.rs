//! Conformance suite for the fault-injection & recovery layer
//! (`sched::fault`): every fault scenario × both host executor modes
//! × three distinct seeds with every declared invariant
//! machine-checked, plan determinism across replays, and end-to-end
//! probes of the properties the CLI repro path
//! (`gprm exp --fault <name> --seed N`) depends on — transient faults
//! heal bit-identically under retry, deadline misses and drain
//! rejections reproduce exactly.

use gprm::sched::fault::{self, FAULT_SCENARIOS};
use gprm::sched::scenario::{
    run_and_check, run_host, ExecMode, ScenarioOutcome, ALL_SCENARIOS,
};
use gprm::sched::{Error, SubmitError};

/// Distinct from both the harness's pinned seeds and the scenario
/// suite's, so the fault plans get their own six-seed coverage
/// between this suite and the `faults` experiment.
const SEEDS: [u64; 3] = [7, 13, 1 << 33];

#[test]
fn every_fault_scenario_declares_reason_and_two_invariants() {
    assert!(
        FAULT_SCENARIOS.len() >= 4,
        "acceptance bar: at least four fault scenarios, have {}",
        FAULT_SCENARIOS.len()
    );
    for (i, sc) in FAULT_SCENARIOS.iter().enumerate() {
        assert!(
            !sc.reason.is_empty(),
            "{}: every fault scenario states why it exists",
            sc.name
        );
        assert!(
            sc.invariants.len() >= 2,
            "{}: every fault scenario declares at least two invariants",
            sc.name
        );
        for later in &FAULT_SCENARIOS[i + 1..] {
            assert_ne!(sc.name, later.name, "fault scenario names are unique");
        }
        // The fault registry is disjoint from the base scenario
        // registry — `--scenario` and `--fault` namespaces never
        // collide.
        for base in ALL_SCENARIOS {
            assert_ne!(sc.name, base.name, "fault name shadows a scenario");
        }
        assert!(fault::find(sc.name).is_some());
    }
    assert!(fault::find("bogus").is_none());
    assert_eq!(fault::names().len(), FAULT_SCENARIOS.len());
}

#[test]
fn fault_plans_are_deterministic_per_seed_and_differ_across_seeds() {
    for sc in FAULT_SCENARIOS {
        for seed in SEEDS {
            let (a, b) = (sc.plan(seed), sc.plan(seed));
            assert_eq!(a.workers, b.workers, "{} seed {seed}", sc.name);
            assert_eq!(a.max_pending, b.max_pending, "{} seed {seed}", sc.name);
            assert_eq!(a.drain_after, b.drain_after, "{} seed {seed}", sc.name);
            assert_eq!(a.jobs.len(), b.jobs.len(), "{} seed {seed}", sc.name);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.workload.name(), y.workload.name());
                assert_eq!((x.nb, x.bs, x.seed), (y.nb, y.bs, y.seed));
                assert_eq!(x.deps, y.deps);
                // The fault-layer knobs replay exactly: same fault at
                // the same coordinate, same retry budget, same
                // deadline, same cancellation flag.
                assert_eq!(x.fault, y.fault, "{} seed {seed}", sc.name);
                assert_eq!(x.fault_task, y.fault_task, "{} seed {seed}", sc.name);
                assert_eq!(x.retry, y.retry, "{} seed {seed}", sc.name);
                assert_eq!(x.deadline, y.deadline, "{} seed {seed}", sc.name);
                assert_eq!(x.cancel, y.cancel, "{} seed {seed}", sc.name);
            }
        }
        // Across the three seeds at least one pair of plans differs —
        // the generator really consults its seed.
        let plans: Vec<_> = SEEDS.iter().map(|&s| sc.plan(s)).collect();
        let differs = plans.windows(2).any(|w| {
            w[0].jobs.len() != w[1].jobs.len()
                || w[0].jobs.iter().zip(&w[1].jobs).any(|(x, y)| {
                    x.nb != y.nb
                        || x.fault != y.fault
                        || x.fault_task != y.fault_task
                        || x.workload.name() != y.workload.name()
                })
        });
        assert!(differs, "{}: plans identical across seeds", sc.name);
    }
}

#[test]
fn all_fault_scenarios_hold_their_invariants_on_both_host_modes() {
    for sc in FAULT_SCENARIOS {
        for seed in SEEDS {
            for mode in [ExecMode::Overlapped, ExecMode::Serial] {
                let (_, inv) = run_and_check(sc, seed, mode);
                for r in &inv {
                    assert!(
                        r.pass,
                        "{} seed {seed} {mode:?} [{}]: {}",
                        sc.name, r.invariant, r.detail
                    );
                }
                assert_eq!(
                    inv.len(),
                    sc.invariants.len(),
                    "{}: every declared invariant evaluated",
                    sc.name
                );
            }
        }
    }
}

#[test]
fn transient_retry_heals_bit_identically_end_to_end() {
    // The core recovery claim, probed directly rather than through
    // the invariant harness: a transient fault consumes extra
    // attempts, then the resubmitted job completes with output
    // bit-identical to the sequential reference.
    let sc = fault::find("transient-storm-with-retry").unwrap();
    let o = run_host(sc, SEEDS[0], ExecMode::Overlapped);
    let healed: Vec<usize> = o
        .jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| j.attempts >= 2 && j.result.is_ok())
        .map(|(i, _)| i)
        .collect();
    assert!(
        !healed.is_empty(),
        "the storm plan always contains a recoverable transient fault"
    );
    for &i in &healed {
        assert_eq!(
            o.jobs[i].bits,
            Some(Ok(())),
            "job {i}: retried output must match the sequential reference"
        );
    }
    // And the whole episode replays exactly: same attempt counts,
    // same pass/fail split, run after run.
    let again = run_host(sc, SEEDS[0], ExecMode::Overlapped);
    let fingerprint = |o: &ScenarioOutcome| {
        o.jobs
            .iter()
            .map(|j| (j.attempts, j.result.is_ok()))
            .collect::<Vec<_>>()
    };
    assert_eq!(fingerprint(&o), fingerprint(&again));
}

#[test]
fn deadline_misses_reproduce_with_exact_ticket_counts() {
    // A missed deadline is not "roughly d tasks ran": the ticket
    // protocol guarantees exactly `min(d, tasks)` kernels executed,
    // identically in both executor modes.
    let sc = fault::find("deadline-misses-under-churn").unwrap();
    for mode in [ExecMode::Overlapped, ExecMode::Serial] {
        let o = run_host(sc, SEEDS[1], mode);
        let mut missed = 0;
        for (i, j) in o.jobs.iter().enumerate() {
            let Some(d) = o.plan.jobs[i].deadline else { continue };
            if d < j.tasks {
                missed += 1;
                match &j.result {
                    Err(Error::Cancelled { ran }) => assert_eq!(
                        *ran, d,
                        "job {i} {mode:?}: ran differs from its deadline"
                    ),
                    other => panic!(
                        "job {i} {mode:?}: tight deadline produced {other:?}"
                    ),
                }
            }
        }
        assert!(missed >= 1, "{mode:?}: the churn plan plants a tight deadline");
    }
}

#[test]
fn drain_rejections_are_deterministic_in_both_modes() {
    // `Pool::drain` splits the stream at a planned index: everything
    // before it was admitted, everything at or after it carries
    // `SubmitError::Draining` — on every replay, in either mode.
    let sc = fault::find("cancel-mid-stream").unwrap();
    for mode in [ExecMode::Overlapped, ExecMode::Serial] {
        let o = run_host(sc, SEEDS[2], mode);
        let cut = o.plan.drain_after.expect("plan always drains");
        for (i, j) in o.jobs.iter().enumerate() {
            if i < cut {
                assert!(
                    j.admission.is_some(),
                    "job {i} {mode:?}: pre-drain submission was admitted"
                );
            } else {
                assert_eq!(
                    j.result,
                    Err(Error::Submit(SubmitError::Draining)),
                    "job {i} {mode:?}: post-drain submission not rejected"
                );
                assert_eq!(j.attempts, 0, "rejected jobs consume no attempts");
            }
        }
    }
}
