//! Cross-layer integration: the AOT-compiled JAX/Pallas artifacts,
//! executed through PJRT from rust, must agree with the pure-rust
//! `linalg` kernels on identical inputs.
//!
//! Requires a vendored `xla` crate (`runtime::PJRT_AVAILABLE`) plus
//! the artifacts from `python/compile/aot.py`; skips cleanly (with a
//! notice) when either is missing.

use gprm::linalg::dense::DenseMatrix;
use gprm::linalg::lu::{bdiv, bmod, fwd, lu0};
use gprm::runtime::{
    default_artifact_dir, BlockEngine, EngineService, PJRT_AVAILABLE,
};

/// `true` when PJRT is wired in *and* the AOT artifacts exist;
/// otherwise prints an explicit skip notice (once per test) so a
/// green suite is visibly a partial one. Checking `PJRT_AVAILABLE`
/// first keeps a present artifact directory from turning stubbed
/// builds (runtime/xla_stub.rs) into hard failures.
fn have_artifacts() -> bool {
    if !PJRT_AVAILABLE {
        eprintln!(
            "skipping PJRT test: built with the in-repo xla stub \
             (vendor the `xla` crate and flip runtime::PJRT_AVAILABLE \
             to exercise this path)"
        );
        return false;
    }
    let manifest = default_artifact_dir().join("manifest.json");
    if !manifest.exists() {
        eprintln!(
            "skipping PJRT test: {manifest:?} not found (compile the \
             JAX/Pallas kernels via python/compile/aot.py first)"
        );
        return false;
    }
    true
}

fn block(bs: usize, seed: u32) -> Vec<f32> {
    DenseMatrix::bots_random(bs, bs, seed).as_slice().to_vec()
}

fn dominant(bs: usize, seed: u32) -> Vec<f32> {
    let mut b = block(bs, seed);
    for i in 0..bs {
        b[i * bs + i] += bs as f32;
    }
    b
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn pjrt_block_ops_match_rust_kernels() {
    if !have_artifacts() {
        return;
    }
    let mut eng = BlockEngine::new(default_artifact_dir()).unwrap();
    println!("platform: {}", eng.platform());
    for &bs in &[8usize, 16, 40, 80] {
        // lu0
        let mut d_pjrt = dominant(bs, 1 + bs as u32);
        let mut d_rust = d_pjrt.clone();
        eng.lu0(bs, &mut d_pjrt).unwrap();
        lu0(&mut d_rust, bs);
        close(&d_pjrt, &d_rust, 1e-3, &format!("lu0 bs={bs}"));

        // fwd
        let mut c_pjrt = block(bs, 2 + bs as u32);
        let mut c_rust = c_pjrt.clone();
        eng.fwd(bs, &d_rust, &mut c_pjrt).unwrap();
        fwd(&d_rust, &mut c_rust, bs);
        close(&c_pjrt, &c_rust, 1e-3, &format!("fwd bs={bs}"));

        // bdiv
        let mut r_pjrt = block(bs, 3 + bs as u32);
        let mut r_rust = r_pjrt.clone();
        eng.bdiv(bs, &d_rust, &mut r_pjrt).unwrap();
        bdiv(&d_rust, &mut r_rust, bs);
        close(&r_pjrt, &r_rust, 1e-3, &format!("bdiv bs={bs}"));

        // bmod
        let row = block(bs, 4 + bs as u32);
        let col = block(bs, 5 + bs as u32);
        let mut i_pjrt = block(bs, 6 + bs as u32);
        let mut i_rust = i_pjrt.clone();
        eng.bmod(bs, &row, &col, &mut i_pjrt).unwrap();
        bmod(&row, &col, &mut i_rust, bs);
        close(&i_pjrt, &i_rust, 1e-3, &format!("bmod bs={bs}"));
    }
    // Executables are cached, not recompiled per call.
    let n = eng.compiled_count();
    let mut d = dominant(8, 99);
    eng.lu0(8, &mut d).unwrap();
    assert_eq!(eng.compiled_count(), n);
}

#[test]
fn pjrt_lustep_fused_matches_composition() {
    if !have_artifacts() {
        return;
    }
    let mut eng = BlockEngine::new(default_artifact_dir()).unwrap();
    let bs = 16;
    let diag = dominant(bs, 10);
    let row = block(bs, 11);
    let col = block(bs, 12);
    let inner = block(bs, 13);
    let (d, r, c, i) = eng.lustep(bs, &diag, &row, &col, &inner).unwrap();
    // Compose with the rust kernels.
    let mut d2 = diag.clone();
    lu0(&mut d2, bs);
    let mut r2 = row.clone();
    fwd(&d2, &mut r2, bs);
    let mut c2 = col.clone();
    bdiv(&d2, &mut c2, bs);
    let mut i2 = inner.clone();
    bmod(&c2, &r2, &mut i2, bs);
    close(&d, &d2, 1e-3, "lustep.d");
    close(&r, &r2, 1e-3, "lustep.r");
    close(&c, &c2, 1e-3, "lustep.c");
    close(&i, &i2, 1e-3, "lustep.i");
}

#[test]
fn pjrt_matmul_matches_dense() {
    if !have_artifacts() {
        return;
    }
    let mut eng = BlockEngine::new(default_artifact_dir()).unwrap();
    let n = 64;
    let a = DenseMatrix::bots_random(n, n, 20);
    let b = DenseMatrix::bots_random(n, n, 21);
    let c = eng.matmul(n, a.as_slice(), b.as_slice()).unwrap();
    let want = a.matmul_opt(&b);
    close(&c, want.as_slice(), 1e-3, "matmul n=64");
}

#[test]
fn engine_service_is_multithread_callable() {
    if !have_artifacts() {
        return;
    }
    let svc = std::sync::Arc::new(
        EngineService::start(default_artifact_dir()).unwrap(),
    );
    let mut handles = Vec::new();
    for t in 0..4u32 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let bs = 8;
            let row = block(bs, 30 + t);
            let col = block(bs, 40 + t);
            let mut inner = block(bs, 50 + t);
            let mut want = inner.clone();
            svc.bmod(bs, &row, &col, &mut inner).unwrap();
            bmod(&row, &col, &mut want, bs);
            close(&inner, &want, 1e-3, "service bmod");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    if !have_artifacts() {
        return;
    }
    let mut eng = BlockEngine::new(default_artifact_dir()).unwrap();
    // Wrong arity.
    assert!(eng.exec("bmod_bs8", 8, &[&[0.0; 64][..]]).is_err());
    // Wrong element count.
    let bad = vec![0.0f32; 9];
    assert!(eng
        .exec("bmod_bs8", 8, &[&bad, &bad, &bad])
        .is_err());
    // Unknown artifact.
    assert!(eng.exec("nope_bs8", 8, &[]).is_err());
}
