//! Conformance suite for the scenario engine (`sched::scenario`):
//! every named scenario × both host executor modes × three distinct
//! seeds, with every declared invariant machine-checked, plus
//! host/simulator agreement on completion structure. Nothing here
//! names a scenario beyond the poison determinism probe — a new
//! `ALL_SCENARIOS` entry is covered the moment it is declared.

use gprm::sched::scenario::{
    check_invariants, find, host_sim_agreement, names, run_and_check,
    run_host, run_sim, ExecMode, ALL_SCENARIOS,
};
use gprm::tilesim::SchedModel;

/// The acceptance bar's "3 distinct seeds" — deliberately not the
/// harness's pinned set, so the suite and the `scenario` experiment
/// cover six seeds between them.
const SEEDS: [u64; 3] = [11, 42, 1 << 40];

#[test]
fn every_scenario_declares_reason_and_two_invariants() {
    assert!(
        ALL_SCENARIOS.len() >= 6,
        "acceptance bar: at least six named scenarios, have {}",
        ALL_SCENARIOS.len()
    );
    for (i, sc) in ALL_SCENARIOS.iter().enumerate() {
        assert!(
            !sc.reason.is_empty(),
            "{}: every scenario states why it exists",
            sc.name
        );
        assert!(
            sc.invariants.len() >= 2,
            "{}: every scenario declares at least two invariants",
            sc.name
        );
        for later in &ALL_SCENARIOS[i + 1..] {
            assert_ne!(sc.name, later.name, "scenario names are unique");
        }
        assert!(find(sc.name).is_some());
    }
    assert!(find("bogus").is_none());
    assert_eq!(names().len(), ALL_SCENARIOS.len());
}

#[test]
fn plans_are_deterministic_per_seed_and_differ_across_seeds() {
    for sc in ALL_SCENARIOS {
        for seed in SEEDS {
            let (a, b) = (sc.plan(seed), sc.plan(seed));
            assert_eq!(a.workers, b.workers, "{} seed {seed}", sc.name);
            assert_eq!(a.capacity, b.capacity, "{} seed {seed}", sc.name);
            assert_eq!(a.pacing, b.pacing, "{} seed {seed}", sc.name);
            assert_eq!(a.jobs.len(), b.jobs.len(), "{} seed {seed}", sc.name);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.workload.name(), y.workload.name());
                assert_eq!((x.nb, x.bs, x.seed), (y.nb, y.bs, y.seed));
                assert_eq!(x.deps, y.deps);
                assert_eq!(
                    (x.poison, x.straggler, x.batch),
                    (y.poison, y.straggler, y.batch)
                );
            }
        }
        // Across the three seeds, at least one pair of plans differs —
        // the generator really consults its seed.
        let plans: Vec<_> = SEEDS.iter().map(|&s| sc.plan(s)).collect();
        let differs = plans.windows(2).any(|w| {
            w[0].workers != w[1].workers
                || w[0].jobs.len() != w[1].jobs.len()
                || w[0].jobs.iter().zip(&w[1].jobs).any(|(x, y)| {
                    x.nb != y.nb
                        || x.seed != y.seed
                        || x.workload.name() != y.workload.name()
                })
        });
        assert!(differs, "{}: plans identical across seeds", sc.name);
    }
}

#[test]
fn all_scenarios_hold_their_invariants_on_both_host_modes() {
    for sc in ALL_SCENARIOS {
        for seed in SEEDS {
            for mode in [ExecMode::Overlapped, ExecMode::Serial] {
                let (_, inv) = run_and_check(sc, seed, mode);
                for r in &inv {
                    assert!(
                        r.pass,
                        "{} seed {seed} {mode:?} [{}]: {}",
                        sc.name, r.invariant, r.detail
                    );
                }
                assert_eq!(
                    inv.len(),
                    sc.invariants.len(),
                    "{}: every declared invariant evaluated",
                    sc.name
                );
            }
        }
    }
}

#[test]
fn host_and_simulator_agree_on_completion_structure() {
    // One seed per scenario keeps the sweep fast; the invariant sweep
    // above already covers all three seeds on the host side.
    let seed = SEEDS[0];
    for sc in ALL_SCENARIOS {
        let o = run_host(sc, seed, ExecMode::Overlapped);
        for inv in check_invariants(sc, &o) {
            assert!(
                inv.pass,
                "{} [{}]: {}",
                sc.name, inv.invariant, inv.detail
            );
        }
        for sched in [SchedModel::WorkSteal, SchedModel::MutexScoreboard] {
            let s = run_sim(sc, seed, 8, sched);
            let agree = host_sim_agreement(&o, &s);
            assert!(agree.pass, "{} {sched:?}: {}", sc.name, agree.detail);
            // The simulator replay is fully deterministic: bit-equal
            // cycle counts on a re-run.
            let again = run_sim(sc, seed, 8, sched);
            assert_eq!(
                (s.pool_cycles, s.oneshot_cycles),
                (again.pool_cycles, again.oneshot_cycles),
                "{} {sched:?}: simulator replay not deterministic",
                sc.name
            );
        }
    }
}

#[test]
fn poison_replay_is_deterministic() {
    // The poisoned stream reproduces exactly: same failing job, same
    // sibling results, run after run — the property the CLI repro
    // path (`gprm exp scenario --scenario poison-mid-stream --seed N`)
    // depends on.
    let sc = find("poison-mid-stream").unwrap();
    let a = run_host(sc, SEEDS[1], ExecMode::Overlapped);
    let b = run_host(sc, SEEDS[1], ExecMode::Overlapped);
    let failed = |o: &gprm::sched::scenario::ScenarioOutcome| {
        o.jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.result.is_err())
            .map(|(i, _)| i)
            .collect::<Vec<_>>()
    };
    assert_eq!(failed(&a), failed(&b));
    assert_eq!(failed(&a).len(), 1, "exactly one poisoned job");
    assert!(
        a.plan.jobs[failed(&a)[0]].poison,
        "the failing job is the planned one"
    );
}
