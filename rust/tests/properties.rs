//! Property-based tests (testkit) over the invariants DESIGN.md §6
//! calls out: worksharing coverage/disjointness laws, schedule
//! equivalences, compiler task-description laws, simulator
//! conservation, and JSON round-trips.

use gprm::coordinator::worksharing::{
    contiguous_range, par_for, par_for_contiguous, par_for_indices,
    par_nested_for, par_nested_for_contiguous,
};
use gprm::omp::parallel_for::{static_range, DynamicSched};
use gprm::testkit::{check, Gen, Pair, Triple, UsizeRange};
use gprm::tilesim::sim_gprm::contiguous_index;
use gprm::util::json::Json;
use gprm::util::prng::SplitMix64;
use std::collections::BTreeSet;

#[test]
fn prop_par_for_exact_disjoint_cover() {
    check(
        "par_for-cover",
        300,
        &Triple(UsizeRange(0, 40), UsizeRange(0, 300), UsizeRange(1, 80)),
        |&(start, len, cl)| {
            let size = start + len;
            let mut seen = BTreeSet::new();
            for ind in 0..cl {
                par_for(start, size, ind, cl, |i| {
                    if !seen.insert(i) {
                        panic!("duplicate {i}");
                    }
                });
            }
            if seen.len() != len {
                return Err(format!(
                    "covered {} of {len} (start={start}, cl={cl})",
                    seen.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_par_for_matches_closed_form() {
    check(
        "par_for-closed-form",
        300,
        &Triple(UsizeRange(0, 30), UsizeRange(0, 200), UsizeRange(1, 70)),
        |&(start, len, cl)| {
            let size = start + len;
            for ind in 0..cl {
                let mut a = Vec::new();
                par_for(start, size, ind, cl, |i| a.push(i));
                let b: Vec<usize> =
                    par_for_indices(start, size, ind, cl).collect();
                if a != b {
                    return Err(format!("ind={ind}: {a:?} != {b:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nested_equals_flattened() {
    check(
        "nested-flattened",
        200,
        &Triple(UsizeRange(1, 20), UsizeRange(1, 20), UsizeRange(1, 40)),
        |&(rows, cols, cl)| {
            for ind in 0..cl {
                let mut nested = Vec::new();
                par_nested_for(0, rows, 0, cols, ind, cl, |i, j| {
                    nested.push(i * cols + j)
                });
                let mut flat = Vec::new();
                par_for(0, rows * cols, ind, cl, |g| flat.push(g));
                if nested != flat {
                    return Err(format!("ind={ind}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_contiguous_balance_law() {
    // Chunk sizes differ by ≤1, are non-increasing, and concatenate to
    // the full range (Fig 1b).
    check(
        "contiguous-balance",
        300,
        &Triple(UsizeRange(0, 50), UsizeRange(0, 400), UsizeRange(1, 80)),
        |&(start, len, cl)| {
            let size = start + len;
            let mut expected_lo = start;
            let mut prev = usize::MAX;
            for ind in 0..cl {
                let (lo, hi) = contiguous_range(start, size, ind, cl);
                if lo != expected_lo {
                    return Err(format!("gap at ind={ind}"));
                }
                let n = hi - lo;
                if n > prev {
                    return Err("chunk sizes increased".into());
                }
                prev = n;
                expected_lo = hi;
            }
            if expected_lo != size {
                return Err("chunks do not cover the range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_contiguous_index_agrees_with_range() {
    check(
        "contiguous-index",
        200,
        &Pair(UsizeRange(1, 300), UsizeRange(1, 80)),
        |&(total, cl)| {
            for ind in 0..cl {
                let (lo, hi) = contiguous_range(0, total, ind, cl);
                for i in lo..hi {
                    let got = contiguous_index(i as u64, total as u64, cl);
                    if got != ind {
                        return Err(format!("iter {i}: {got} != {ind}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nested_contiguous_cover() {
    check(
        "nested-contiguous-cover",
        150,
        &Triple(UsizeRange(1, 15), UsizeRange(1, 15), UsizeRange(1, 40)),
        |&(rows, cols, cl)| {
            let mut seen = BTreeSet::new();
            for ind in 0..cl {
                par_nested_for_contiguous(0, rows, 0, cols, ind, cl, |i, j| {
                    seen.insert((i, j));
                });
            }
            if seen.len() != rows * cols {
                return Err(format!("{} of {}", seen.len(), rows * cols));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_omp_static_vs_gprm_contiguous_identical() {
    // libgomp static partitioning == GPRM contiguous (both: m/n + one
    // extra for the foremost rem threads).
    check(
        "static-eq-contiguous",
        300,
        &Triple(UsizeRange(0, 40), UsizeRange(0, 300), UsizeRange(1, 64)),
        |&(start, len, n)| {
            let end = start + len;
            for tid in 0..n {
                let a = static_range(start, end, tid, n);
                let b = contiguous_range(start, end, tid, n);
                if a != b {
                    return Err(format!("tid={tid}: {a:?} != {b:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dynamic_sched_covers_any_chunk() {
    check(
        "dynamic-cover",
        150,
        &Triple(UsizeRange(0, 200), UsizeRange(1, 20), UsizeRange(0, 30)),
        |&(len, chunk, start)| {
            let s = DynamicSched::new(start, start + len, chunk);
            let mut seen = BTreeSet::new();
            while let Some((lo, hi)) = s.next_chunk() {
                for i in lo..hi {
                    if !seen.insert(i) {
                        return Err(format!("dup {i}"));
                    }
                }
            }
            if seen.len() != len {
                return Err(format!("{} of {len}", seen.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_worksharing_starvation_boundary() {
    // par_for starves exactly max(0, cl - domain) indices;
    // par_nested_for starves exactly max(0, cl - rows*cols).
    check(
        "starvation-count",
        200,
        &Triple(UsizeRange(0, 12), UsizeRange(0, 12), UsizeRange(1, 40)),
        |&(rows, cols, cl)| {
            let mut starved = 0;
            for ind in 0..cl {
                let mut n = 0;
                par_nested_for(0, rows, 0, cols, ind, cl, |_, _| n += 1);
                if n == 0 {
                    starved += 1;
                }
            }
            let expect = cl.saturating_sub(rows * cols);
            if starved != expect {
                return Err(format!("starved {starved}, expect {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_structures() {
    // Build random JSON values from a seeded generator, round-trip
    // through text.
    struct JsonGen;
    impl Gen for JsonGen {
        type Value = Json;
        fn generate(&self, rng: &mut SplitMix64) -> Json {
            fn go(rng: &mut SplitMix64, depth: usize) -> Json {
                match if depth > 2 { rng.range(0, 4) } else { rng.range(0, 6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.chance(0.5)),
                    2 => Json::Num((rng.range(0, 100000) as f64) / 8.0),
                    3 => Json::Str(format!("s{}-\"q\"\n", rng.range(0, 1000))),
                    4 => Json::Arr(
                        (0..rng.range(0, 4)).map(|_| go(rng, depth + 1)).collect(),
                    ),
                    _ => Json::Obj(
                        (0..rng.range(0, 4))
                            .map(|i| (format!("k{i}"), go(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            go(rng, 0)
        }
    }
    check("json-roundtrip", 300, &JsonGen, |v| {
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
        if &back != v {
            return Err(format!("{back:?} != {v:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_work_conservation_matmul() {
    use gprm::tilesim::{GprmSim, Workload};
    check(
        "sim-conservation",
        40,
        &Triple(UsizeRange(1, 2000), UsizeRange(1, 60), UsizeRange(1, 128)),
        |&(m, n, cl)| {
            let sim = GprmSim::tilepro(cl);
            let r = sim.run(
                std::iter::once(Workload::matmul_jobs(m, n, n, 1)),
                0,
                0,
            );
            if r.tasks != m as u64 {
                return Err(format!("{} tasks != {m}", r.tasks));
            }
            let busy: u64 = r.busy.iter().sum();
            let expect = m as u64 * sim.cost.work(2 * (n * n) as u64);
            if busy != expect {
                return Err(format!("busy {busy} != {expect}"));
            }
            if r.cycles < busy / 63 {
                return Err("makespan below work bound".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_genmat_structure_deterministic_and_banded() {
    use gprm::linalg::genmat::{bots_null_entry, genmat_pattern};
    check("genmat-band", 100, &UsizeRange(1, 120), |&nb| {
        let p = genmat_pattern(nb);
        for i in 0..nb {
            // Tridiagonal band always allocated.
            if !p[i * nb + i] {
                return Err(format!("diag {i} empty"));
            }
            if i + 1 < nb && (!p[i * nb + i + 1] || !p[(i + 1) * nb + i]) {
                return Err(format!("band {i} empty"));
            }
        }
        // Pattern symmetric in structure rule.
        for i in 0..nb.min(30) {
            for j in 0..nb.min(30) {
                if bots_null_entry(i, j) != (!p[i * nb + j]) {
                    return Err(format!("rule mismatch at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}
