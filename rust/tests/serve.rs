//! End-to-end loopback tests for the serving front-end: real
//! `Server`s on ephemeral ports, concurrent clients, a mixed
//! factorisation stream with injected faults and deadlines, digest
//! verification against the sequential references, typed refusals,
//! and graceful drain. The acceptance bar of the serve subsystem: a
//! failure or an overload is *always* answered with a typed frame on
//! a live socket, and an admitted job *always* delivers a terminal
//! frame whose success digest is f32-bit-identical to the sequential
//! reference.

use gprm::sched::workload::{self, Params};
use gprm::serve::frame::{read_frame, write_frame};
use gprm::serve::{
    loadgen, matrix_digest, Client, LoadConfig, Request, Response,
    ServeConfig, Server,
};

fn ref_digest(name: &str, nb: usize, bs: usize, seed: u32) -> u64 {
    let w = workload::find(name).expect("registry workload");
    let mut m = w.make_input(&Params::new(nb, bs), seed);
    w.reference_seq(&mut m);
    matrix_digest(&m)
}

/// The mixed stream's composition: the registry's factorisation
/// (phase-capable) entries, like the throughput experiment.
fn fact_names() -> Vec<&'static str> {
    let p = Params::new(8, 8);
    workload::registry()
        .iter()
        .filter(|w| w.phases(&p).is_some())
        .map(|w| w.name())
        .collect()
}

#[test]
fn four_concurrent_clients_mixed_stream_end_to_end() {
    let (nb, bs, seed) = (8usize, 8usize, 42u32);
    let names = fact_names();
    assert!(names.len() >= 2, "registry lost its mixed stream");
    let digests: Vec<u64> = names
        .iter()
        .map(|n| ref_digest(n, nb, bs, seed))
        .collect();
    let server =
        Server::bind("127.0.0.1:0", ServeConfig::new(4)).expect("bind");
    let addr = server.local_addr().expect("local addr");
    let run = std::thread::spawn(move || server.run());
    let names = &names;
    let digests = &digests;
    std::thread::scope(|ts| {
        for c in 0..4usize {
            ts.spawn(move || {
                let mut cl = Client::connect(addr).expect("connect");
                assert!(matches!(
                    cl.request(&Request::Ping),
                    Ok(Response::Pong)
                ));
                // Poll of a never-submitted id: typed, not an error.
                assert!(matches!(
                    cl.request(&Request::Poll { id: 999 }),
                    Ok(Response::Polled {
                        id: 999,
                        known: false,
                        done: false
                    })
                ));
                for j in 0..3usize {
                    let id = (c * 10 + j) as u64;
                    let wname = names[(c + j) % names.len()];
                    let want = digests[(c + j) % names.len()];
                    // One poisoned and one deadlined request ride the
                    // otherwise-clean mixed stream.
                    let poison = c == 0 && j == 1;
                    let dead = c == 1 && j == 1;
                    cl.send(&Request::Submit {
                        id,
                        workload: wname.to_string(),
                        nb: nb as u32,
                        bs: bs as u32,
                        seed,
                        poison_task: poison.then_some(0),
                        deadline: dead.then_some(0),
                    })
                    .expect("send submit");
                    match cl.recv().expect("accept frame") {
                        Response::Accepted { id: a } => {
                            assert_eq!(a, id)
                        }
                        other => panic!(
                            "client {c} job {j}: expected Accepted, \
                             got {other:?}"
                        ),
                    }
                    let terminal = cl.recv().expect("terminal frame");
                    match terminal {
                        Response::Done { id: d, digest, tasks, .. } => {
                            assert_eq!(d, id);
                            assert!(
                                !poison,
                                "poisoned job {id} reported success"
                            );
                            // A deadlined job may win the race and
                            // complete — then its digest must still
                            // be bit-identical.
                            assert_eq!(
                                digest, want,
                                "client {c} job {j} ({wname}): digest \
                                 differs from the sequential reference"
                            );
                            assert!(tasks > 0);
                        }
                        Response::Failed {
                            id: d,
                            attempts,
                            task,
                            ref op,
                            ref msg,
                        } => {
                            assert_eq!(d, id);
                            assert!(
                                poison,
                                "clean job {id} failed: {op} {msg}"
                            );
                            assert!(attempts >= 1);
                            assert_eq!(task, 0, "poison was on task 0");
                            assert!(!op.is_empty());
                        }
                        Response::Cancelled { id: d, .. } => {
                            assert_eq!(d, id);
                            assert!(
                                dead,
                                "job {id} cancelled without a deadline"
                            );
                        }
                        other => panic!(
                            "client {c} job {j}: unexpected terminal \
                             {other:?}"
                        ),
                    }
                    // Terminal frames retire the id: a later poll is
                    // typed and unknown.
                    assert!(matches!(
                        cl.request(&Request::Poll { id }),
                        Ok(Response::Polled { known: false, .. })
                    ));
                }
            });
        }
    });
    // All clients done: drain. The ack arrives only after every
    // admitted job has delivered its terminal frame.
    let mut cl = Client::connect(addr).expect("connect");
    assert!(matches!(
        cl.request(&Request::Shutdown),
        Ok(Response::ShuttingDown)
    ));
    drop(cl);
    let stats = run.join().expect("serve thread");
    assert_eq!(stats.accepted, 12);
    assert_eq!(stats.failed, 1, "exactly the poisoned request fails");
    // The deadlined request is Cancelled unless it won the race.
    assert_eq!(
        stats.completed + stats.failed + stats.cancelled,
        stats.accepted,
        "an admitted job vanished without a terminal frame: {stats:?}"
    );
    assert!(stats.cancelled <= 1);
}

#[test]
fn undecodable_frame_gets_typed_rejection_and_other_conns_survive() {
    let server =
        Server::bind("127.0.0.1:0", ServeConfig::new(2)).expect("bind");
    let addr = server.local_addr().expect("local addr");
    let stop = server.stop_flag();
    let run = std::thread::spawn(move || server.run());
    // A healthy connection before, during and after the poisoned one.
    let mut healthy = Client::connect(addr).expect("connect");
    assert!(matches!(
        healthy.request(&Request::Ping),
        Ok(Response::Pong)
    ));
    let mut raw =
        std::net::TcpStream::connect(addr).expect("raw connect");
    write_frame(&mut raw, &[0xFF, 1, 2, 3]).expect("garbage frame");
    match read_frame(&mut raw).expect("rejection frame") {
        Some(buf) => match Response::decode(&buf).expect("decodes") {
            Response::Rejected { id, msg } => {
                assert_eq!(id, u64::MAX, "no request id to echo");
                assert!(msg.contains("undecodable"), "{msg}");
            }
            other => panic!("expected Rejected, got {other:?}"),
        },
        None => panic!("connection dropped without a typed frame"),
    }
    // The stream is beyond resync: the server closes it...
    assert!(read_frame(&mut raw).expect("clean close").is_none());
    // ...but other connections are untouched.
    assert!(matches!(
        healthy.request(&Request::Ping),
        Ok(Response::Pong)
    ));
    // Unknown workloads and oversized grids are also typed, on a
    // socket that stays live.
    let bad = |workload: &str, nb: u32| Request::Submit {
        id: 5,
        workload: workload.to_string(),
        nb,
        bs: 4,
        seed: 1,
        poison_task: None,
        deadline: None,
    };
    assert!(matches!(
        healthy.request(&bad("no-such-workload", 4)),
        Ok(Response::Rejected { id: 5, .. })
    ));
    assert!(matches!(
        healthy.request(&bad(fact_names()[0], 65)),
        Ok(Response::Rejected { id: 5, .. })
    ));
    assert!(matches!(
        healthy.request(&Request::Ping),
        Ok(Response::Pong)
    ));
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    drop(healthy);
    drop(raw);
    let stats = run.join().expect("serve thread");
    assert_eq!(stats.accepted, 0);
    assert_eq!(stats.rejected, 3);
}

#[test]
fn loadgen_open_loop_clean_run_with_faults_and_shutdown() {
    let server =
        Server::bind("127.0.0.1:0", ServeConfig::new(4)).expect("bind");
    let addr = server.local_addr().expect("local addr");
    let run = std::thread::spawn(move || server.run());
    let cfg = LoadConfig {
        rate_per_sec: 300.0,
        requests: 60,
        conns: 4,
        nb: 6,
        bs: 4,
        seed: 3,
        verify: true,
        poison_every: 10,
        deadline_every: 7,
        shutdown: true,
        ..LoadConfig::new(&addr.to_string())
    };
    let r = loadgen::run(&cfg).expect("loadgen run");
    assert!(r.pass(), "loadgen must pass: {r:?}");
    assert_eq!(r.sent, 60);
    assert_eq!(r.lost, 0, "every request got a terminal frame");
    assert_eq!(
        r.done + r.failed + r.cancelled,
        r.accepted,
        "admitted vs terminal frames disagree: {r:?}"
    );
    assert_eq!(r.failed, 6, "poison every 10th of 60 requests");
    assert_eq!(r.digest_mismatches, 0);
    assert!(r.hist.count() > 0, "successful latencies were recorded");
    assert!(r.shutdown_acked);
    let stats = run.join().expect("serve thread");
    assert_eq!(stats.accepted, r.accepted);
    assert_eq!(
        stats.completed + stats.failed + stats.cancelled,
        stats.accepted
    );
}
