//! Property tests (testkit) for the dataflow scheduler: random
//! blocked-sparse structures (`genmat` over nb ∈ [2, 24]) must give a
//! DAG whose execution (a) always terminates, (b) respects every
//! dependence edge, and (c) reproduces the sequential factorisation on
//! both host runtimes.

use gprm::apps::sparselu::{sparselu_dataflow, DataflowRt, LuRunConfig};
use gprm::coordinator::GprmRuntime;
use gprm::linalg::genmat::{genmat, genmat_pattern};
use gprm::linalg::lu::sparselu_seq;
use gprm::linalg::verify::lu_residual_sparse;
use gprm::omp::OmpRuntime;
use gprm::sched::{check_event_ordering, execute_gprm, execute_omp, TaskGraph};
use gprm::testkit::{check, Pair, Triple, UsizeRange};
use std::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn prop_dataflow_executor_never_deadlocks_and_orders_edges_omp() {
    // (a) + (b) on the OmpRuntime backend: the executor must drain any
    // genmat-structured DAG and the event log must be edge-valid.
    check(
        "dataflow-omp-drains",
        25,
        &Pair(UsizeRange(2, 25), UsizeRange(1, 9)),
        |&(nb, workers)| {
            let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let rt = OmpRuntime::new(workers);
            let hits = AtomicUsize::new(0);
            let r = execute_omp(&rt, &g, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            rt.shutdown();
            let stats = r.map_err(|e| format!("executor failed: {e}"))?;
            if stats.executed != g.len() {
                return Err(format!(
                    "executed {} of {} tasks",
                    stats.executed,
                    g.len()
                ));
            }
            if hits.load(Ordering::Relaxed) != g.len() {
                return Err("kernel invocation count mismatch".into());
            }
            check_event_ordering(&g, &stats.events)
        },
    );
}

#[test]
fn prop_dataflow_executor_never_deadlocks_and_orders_edges_gprm() {
    // (a) + (b) on the GPRM coordinator backend.
    check(
        "dataflow-gprm-drains",
        15,
        &Pair(UsizeRange(2, 25), UsizeRange(1, 7)),
        |&(nb, tiles)| {
            let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let rt = GprmRuntime::with_tiles(tiles);
            let r = execute_gprm(&rt, &g, |_| {});
            rt.shutdown();
            let stats = r.map_err(|e| format!("executor failed: {e}"))?;
            if stats.executed != g.len() {
                return Err(format!(
                    "executed {} of {} tasks",
                    stats.executed,
                    g.len()
                ));
            }
            check_event_ordering(&g, &stats.events)
        },
    );
}

#[test]
fn prop_dataflow_matches_sequential_both_runtimes() {
    // (c): the dataflow factorisation must match sparselu_seq — same
    // structure, near-identical values, residual below 1e-4 — for
    // random (nb, bs, workers).
    check(
        "dataflow-matches-seq",
        10,
        &Triple(UsizeRange(2, 25), UsizeRange(2, 9), UsizeRange(1, 7)),
        |&(nb, bs, workers)| {
            let orig = genmat(nb, bs).to_dense();
            let mut want = genmat(nb, bs);
            sparselu_seq(&mut want);

            let omp = OmpRuntime::new(workers);
            let mut a_omp = genmat(nb, bs);
            sparselu_dataflow(
                &DataflowRt::Omp(&omp),
                &mut a_omp,
                &LuRunConfig::default(),
            );
            omp.shutdown();

            let gprm = GprmRuntime::with_tiles(workers);
            let mut a_gprm = genmat(nb, bs);
            sparselu_dataflow(
                &DataflowRt::Gprm(&gprm),
                &mut a_gprm,
                &LuRunConfig::default(),
            );
            gprm.shutdown();

            for (name, got) in [("omp", &a_omp), ("gprm", &a_gprm)] {
                if got.pattern() != want.pattern() {
                    return Err(format!("{name}: fill-in pattern differs"));
                }
                let diff = got.to_dense().max_abs_diff(&want.to_dense());
                if diff > 1e-4 {
                    return Err(format!("{name}: diff vs seq {diff}"));
                }
                let res = lu_residual_sparse(&orig, got);
                if res >= 1e-4 {
                    return Err(format!("{name}: residual {res}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_graph_edges_always_point_forward() {
    // Builder invariant: sequential registration order is topological.
    check("graph-forward-edges", 40, &UsizeRange(2, 25), |&nb| {
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            for &p in g.preds(gprm::sched::TaskId(t)) {
                if p >= t {
                    return Err(format!("edge {p} -> {t} not forward"));
                }
            }
        }
        // Exactly one root set: the step-0 lu0 plus nothing else that
        // reads/writes an untouched block before any writer… at
        // minimum the graph must have >= 1 root and no orphan cycles
        // (forward edges already preclude cycles).
        if g.roots().is_empty() {
            return Err("no roots".into());
        }
        Ok(())
    });
}
