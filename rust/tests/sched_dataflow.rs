//! Property tests (testkit) for the dataflow scheduler: random
//! blocked-sparse structures (`genmat` over nb ∈ [2, 24]) must give a
//! DAG whose execution (a) always terminates, (b) respects every
//! dependence edge, and (c) reproduces the sequential factorisation on
//! both host runtimes — under both the lock-free work-stealing
//! executor and the mutex-scoreboard baseline, plus randomized-spin /
//! real-kernel stress tests for the lock-free claim/release protocol
//! on both engine workloads (SparseLU and tiled Cholesky) and CSR
//! structural invariants over randomized sparsity patterns.

use gprm::apps::cholesky::cholesky_dataflow;
use gprm::apps::sparselu::{sparselu_dataflow, DataflowRt, LuRunConfig};
use gprm::coordinator::GprmRuntime;
use gprm::linalg::cholesky::{cholesky_seq, gen_spd};
use gprm::linalg::genmat::{genmat, genmat_pattern};
use gprm::linalg::lu::sparselu_seq;
use gprm::linalg::verify::lu_residual_sparse;
use gprm::omp::OmpRuntime;
use gprm::sched::{
    check_event_ordering, execute_gprm_opts, execute_omp_opts, ExecOpts,
    TaskGraph, TaskId,
};
use gprm::testkit::{check, Pair, Triple, UsizeRange};
use gprm::util::prng::SplitMix64;
use std::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn prop_dataflow_executor_never_deadlocks_and_orders_edges_omp() {
    // (a) + (b) on the OmpRuntime backend: both executors must drain
    // any genmat-structured DAG and the event log must be edge-valid.
    check(
        "dataflow-omp-drains",
        25,
        &Pair(UsizeRange(2, 25), UsizeRange(1, 9)),
        |&(nb, workers)| {
            let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let rt = OmpRuntime::new(workers);
            for opts in
                [ExecOpts::default(), ExecOpts::mutex_baseline()]
            {
                let hits = AtomicUsize::new(0);
                let r = execute_omp_opts(
                    &rt,
                    &g,
                    |_| {
                        hits.fetch_add(1, Ordering::Relaxed);
                    },
                    opts.with_events(),
                );
                let stats = match r {
                    Ok(s) => s,
                    Err(e) => {
                        rt.shutdown();
                        return Err(format!("executor failed: {e}"));
                    }
                };
                if stats.executed != g.len() {
                    rt.shutdown();
                    return Err(format!(
                        "executed {} of {} tasks (steal={})",
                        stats.executed,
                        g.len(),
                        opts.steal
                    ));
                }
                if hits.load(Ordering::Relaxed) != g.len() {
                    rt.shutdown();
                    return Err("kernel invocation count mismatch".into());
                }
                if let Err(e) = check_event_ordering(&g, &stats.events) {
                    rt.shutdown();
                    return Err(format!("steal={}: {e}", opts.steal));
                }
            }
            rt.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_dataflow_executor_never_deadlocks_and_orders_edges_gprm() {
    // (a) + (b) on the GPRM coordinator backend.
    check(
        "dataflow-gprm-drains",
        15,
        &Pair(UsizeRange(2, 25), UsizeRange(1, 7)),
        |&(nb, tiles)| {
            let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let rt = GprmRuntime::with_tiles(tiles);
            for opts in
                [ExecOpts::default(), ExecOpts::mutex_baseline()]
            {
                let r = execute_gprm_opts(&rt, &g, |_| {}, opts.with_events());
                let stats = match r {
                    Ok(s) => s,
                    Err(e) => {
                        rt.shutdown();
                        return Err(format!("executor failed: {e}"));
                    }
                };
                if stats.executed != g.len() {
                    rt.shutdown();
                    return Err(format!(
                        "executed {} of {} tasks (steal={})",
                        stats.executed,
                        g.len(),
                        opts.steal
                    ));
                }
                if let Err(e) = check_event_ordering(&g, &stats.events) {
                    rt.shutdown();
                    return Err(format!("steal={}: {e}", opts.steal));
                }
            }
            rt.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_dataflow_matches_sequential_both_runtimes() {
    // (c): the dataflow factorisation must match sparselu_seq — same
    // structure, near-identical values, residual below 1e-4 — for
    // random (nb, bs, workers).
    check(
        "dataflow-matches-seq",
        10,
        &Triple(UsizeRange(2, 25), UsizeRange(2, 9), UsizeRange(1, 7)),
        |&(nb, bs, workers)| {
            let orig = genmat(nb, bs).to_dense();
            let mut want = genmat(nb, bs);
            sparselu_seq(&mut want);

            let omp = OmpRuntime::new(workers);
            let mut a_omp = genmat(nb, bs);
            sparselu_dataflow(
                &DataflowRt::Omp(&omp),
                &mut a_omp,
                &LuRunConfig::default(),
            );
            omp.shutdown();

            let gprm = GprmRuntime::with_tiles(workers);
            let mut a_gprm = genmat(nb, bs);
            sparselu_dataflow(
                &DataflowRt::Gprm(&gprm),
                &mut a_gprm,
                &LuRunConfig::default(),
            );
            gprm.shutdown();

            for (name, got) in [("omp", &a_omp), ("gprm", &a_gprm)] {
                if got.pattern() != want.pattern() {
                    return Err(format!("{name}: fill-in pattern differs"));
                }
                let diff = got.to_dense().max_abs_diff(&want.to_dense());
                if diff > 1e-4 {
                    return Err(format!("{name}: diff vs seq {diff}"));
                }
                let res = lu_residual_sparse(&orig, got);
                if res >= 1e-4 {
                    return Err(format!("{name}: residual {res}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_graph_edges_always_point_forward() {
    // Builder invariant: sequential registration order is topological.
    check("graph-forward-edges", 40, &UsizeRange(2, 25), |&nb| {
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            for &p in g.preds(gprm::sched::TaskId(t)) {
                if p >= t {
                    return Err(format!("edge {p} -> {t} not forward"));
                }
            }
        }
        // Exactly one root set: the step-0 lu0 plus nothing else that
        // reads/writes an untouched block before any writer… at
        // minimum the graph must have >= 1 root and no orphan cycles
        // (forward edges already preclude cycles).
        if g.roots().is_empty() {
            return Err("no roots".into());
        }
        Ok(())
    });
}

/// Structural invariants of the CSR layout: `succs`/`preds` must be
/// mutual inverses (every edge present in exactly one slot on each
/// side) and the graph cycle-free (a Kahn drain starting from
/// `roots()`/`indegrees()` must consume every task).
fn check_csr_invariants(g: &TaskGraph) -> Result<(), String> {
    let n = g.len();
    let mut pred_edges = 0usize;
    for t in 0..n {
        for &p in g.preds(TaskId(t)) {
            if p >= t {
                return Err(format!("edge {p} -> {t} not forward"));
            }
            if !g.succs(TaskId(p)).contains(&t) {
                return Err(format!("pred edge {p}->{t} missing in succs"));
            }
            pred_edges += 1;
        }
        for &s in g.succs(TaskId(t)) {
            if !g.preds(TaskId(s)).contains(&t) {
                return Err(format!("succ edge {t}->{s} missing in preds"));
            }
        }
        if g.indegrees()[t] != g.preds(TaskId(t)).len() {
            return Err(format!("indegree of {t} disagrees with preds"));
        }
    }
    if pred_edges != g.n_edges() {
        return Err(format!(
            "edge count mismatch: preds {pred_edges} vs CSR {}",
            g.n_edges()
        ));
    }
    let want_roots: Vec<usize> =
        (0..n).filter(|&t| g.indegrees()[t] == 0).collect();
    if g.roots() != want_roots.as_slice() {
        return Err("roots disagree with zero in-degrees".into());
    }
    // Kahn drain: cycle-free iff everything pops.
    let mut indeg = g.indegrees().to_vec();
    let mut queue: Vec<usize> = g.roots().to_vec();
    let mut popped = 0usize;
    while let Some(t) = queue.pop() {
        popped += 1;
        for &s in g.succs(TaskId(t)) {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push(s);
            }
        }
    }
    if popped != n {
        return Err(format!("cycle: drained {popped} of {n}"));
    }
    Ok(())
}

#[test]
fn prop_csr_succs_preds_mutual_inverse_and_acyclic() {
    // Satellite: randomized sparsity patterns, nb ∈ [2, 24], for both
    // the SparseLU and the Cholesky graph constructors. The pattern
    // keeps the tridiagonal band allocated (like every BOTS input) and
    // flips the rest with a seeded coin.
    check(
        "csr-mutual-inverse",
        40,
        &Pair(UsizeRange(2, 25), UsizeRange(0, 1 << 16)),
        |&(nb, seed)| {
            let mut rng = SplitMix64::new(seed as u64 | 1);
            let mut pattern = vec![false; nb * nb];
            for ii in 0..nb {
                for jj in 0..nb {
                    pattern[ii * nb + jj] = ii.abs_diff(jj) <= 1
                        || rng.chance(0.4);
                }
            }
            check_csr_invariants(&TaskGraph::sparselu(&pattern, nb))
                .map_err(|e| format!("sparselu: {e}"))?;
            check_csr_invariants(&TaskGraph::cholesky(nb))
                .map_err(|e| format!("cholesky: {e}"))?;
            Ok(())
        },
    );
}

/// Cheap deterministic per-task spin: xorshift the task id with the
/// case seed into a busy-wait length, so claim/steal/park interleavings
/// vary wildly from case to case.
fn spin_for(task: usize, seed: usize) {
    let mut x = (task as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(seed as u64 | 1);
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    let iters = (x % 2_000) as u32;
    for _ in 0..iters {
        std::hint::spin_loop();
    }
}

#[test]
fn stress_steal_executor_randomized_spins_drain_and_stats() {
    // Satellite: 100 iterations of randomized per-task spin durations
    // over nb ∈ [2, 24] on both runtimes. The lock-free executor must
    // drain every graph, run every task exactly once, and keep the
    // `executed`/`peak_ready` stats coherent.
    check(
        "stress-steal-drains",
        100,
        &Triple(UsizeRange(2, 25), UsizeRange(1, 9), UsizeRange(0, 1 << 16)),
        |&(nb, workers, seed)| {
            let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
            let hits = AtomicUsize::new(0);
            let run = |id: gprm::sched::TaskId| {
                spin_for(id.0, seed);
                hits.fetch_add(1, Ordering::Relaxed);
            };
            let omp = OmpRuntime::new(workers);
            let s1 = execute_omp_opts(&omp, &g, &run, ExecOpts::default())
                .map_err(|e| format!("omp: {e}"))?;
            omp.shutdown();
            let gprm = GprmRuntime::with_tiles(workers);
            let s2 = execute_gprm_opts(&gprm, &g, &run, ExecOpts::default())
                .map_err(|e| format!("gprm: {e}"))?;
            gprm.shutdown();
            for (name, s) in [("omp", &s1), ("gprm", &s2)] {
                if s.executed != g.len() {
                    return Err(format!(
                        "{name}: executed {} of {}",
                        s.executed,
                        g.len()
                    ));
                }
                if s.peak_ready < 1 || s.peak_ready > g.len() {
                    return Err(format!(
                        "{name}: implausible peak_ready {}",
                        s.peak_ready
                    ));
                }
                if !s.events.is_empty() {
                    return Err(format!("{name}: log must stay opt-in"));
                }
            }
            if hits.load(Ordering::Relaxed) != 2 * g.len() {
                return Err("kernel invocation count mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn stress_steal_executor_bit_identical_factorisation() {
    // Satellite, part two: 100 random (nb, workers, bs) cases where
    // the dataflow factorisation — real kernels providing the load —
    // must remain *bit-identical* to `sparselu_seq` on both runtimes
    // (the DAG chains every per-block touch, and the executor's
    // release/acquire edges make each predecessor's writes visible —
    // any missing fence shows up here as a bit difference or a torn
    // block).
    check(
        "stress-steal-bit-identical",
        100,
        &Triple(UsizeRange(2, 25), UsizeRange(2, 9), UsizeRange(0, 1 << 16)),
        |&(nb, workers, seed)| {
            let bs = 4 + (seed % 5); // bs ∈ [4, 8]
            let mut want = genmat(nb, bs);
            sparselu_seq(&mut want);
            let want_dense = want.to_dense();

            let omp = OmpRuntime::new(workers);
            let mut a_omp = genmat(nb, bs);
            sparselu_dataflow(
                &DataflowRt::Omp(&omp),
                &mut a_omp,
                &LuRunConfig::default(),
            );
            omp.shutdown();

            let gprm = GprmRuntime::with_tiles(workers);
            let mut a_gprm = genmat(nb, bs);
            sparselu_dataflow(
                &DataflowRt::Gprm(&gprm),
                &mut a_gprm,
                &LuRunConfig::default(),
            );
            gprm.shutdown();

            for (name, got) in [("omp", a_omp), ("gprm", a_gprm)] {
                if got.pattern() != want.pattern() {
                    return Err(format!("{name}: fill-in pattern differs"));
                }
                if got.to_dense().as_slice() != want_dense.as_slice() {
                    return Err(format!(
                        "{name}: dataflow result not bit-identical to seq"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn stress_cholesky_dataflow_bit_identical_both_executors() {
    // The second workload's acceptance criterion, stress-tested: the
    // Cholesky dataflow factorisation must be *bit-identical* to
    // `cholesky_seq` under both executors (work stealing and the mutex
    // scoreboard) on the omp runtime, and under work stealing on the
    // gprm runtime, for random (nb, workers, bs). Run in release mode
    // by CI alongside the SparseLU stress tests.
    check(
        "stress-cholesky-bit-identical",
        50,
        &Triple(UsizeRange(2, 25), UsizeRange(2, 9), UsizeRange(0, 1 << 16)),
        |&(nb, workers, seed)| {
            let bs = 4 + (seed % 5); // bs ∈ [4, 8]
            let mut want = gen_spd(nb, bs);
            cholesky_seq(&mut want);
            let want_dense = want.to_dense();

            let omp = OmpRuntime::new(workers);
            let mut results: Vec<(String, _)> = Vec::new();
            for exec in [ExecOpts::default(), ExecOpts::mutex_baseline()] {
                let mut a = gen_spd(nb, bs);
                cholesky_dataflow(&DataflowRt::Omp(&omp), &mut a, exec);
                results.push((format!("omp steal={}", exec.steal), a));
            }
            omp.shutdown();

            let gprm = GprmRuntime::with_tiles(workers);
            let mut a = gen_spd(nb, bs);
            cholesky_dataflow(
                &DataflowRt::Gprm(&gprm),
                &mut a,
                ExecOpts::default(),
            );
            results.push(("gprm steal=true".into(), a));
            gprm.shutdown();

            for (name, got) in results {
                if got.to_dense().as_slice() != want_dense.as_slice() {
                    return Err(format!(
                        "{name}: cholesky dataflow not bit-identical to seq"
                    ));
                }
            }
            Ok(())
        },
    );
}
