//! Trait-conformance suite for the workload registry
//! (`sched::workload`): every test iterates
//! `registry()` — nothing here names a concrete workload beyond the
//! registry-completeness check — so workload #4 is covered the moment
//! it is registered.
//!
//! Covered per entry: graph acyclicity + CSR succ/pred mutual
//! inverse, kernel-table/op-table alignment, f32 bit-identity of
//! every host (both one-shot executors, in both executor modes, and
//! the persistent pool — flat and again split into 2 affinity
//! domains) against the declaration's own sequential reference, and
//! residual correctness. Plus the inter-job-dependency
//! stress: job B *reading job A's output* (both jobs over one
//! matrix) races 100 randomized schedules and must stay bit-identical
//! to the chained sequential reference every time.

use gprm::apps::dataflow::{run_workload, DataflowRt};
use gprm::coordinator::GprmRuntime;
use gprm::linalg::blocked::SharedBlocked;
use gprm::omp::OmpRuntime;
use gprm::sched::workload::{
    kernel_runner, registry, Matmul, Params, Workload,
};
use gprm::sched::{ExecOpts, Pool, TaskGraph, TaskId};
use gprm::testkit::{check, Triple, UsizeRange};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Structural invariants of a graph's CSR layout: `succs`/`preds`
/// mutual inverses, forward edges, in-degrees/roots consistent, and
/// cycle-freedom (a Kahn drain consumes every task).
fn check_csr_invariants(g: &TaskGraph) -> Result<(), String> {
    let n = g.len();
    let mut pred_edges = 0usize;
    for t in 0..n {
        for &p in g.preds(TaskId(t)) {
            if p >= t {
                return Err(format!("edge {p} -> {t} not forward"));
            }
            if !g.succs(TaskId(p)).contains(&t) {
                return Err(format!("pred edge {p}->{t} missing in succs"));
            }
            pred_edges += 1;
        }
        for &s in g.succs(TaskId(t)) {
            if !g.preds(TaskId(s)).contains(&t) {
                return Err(format!("succ edge {t}->{s} missing in preds"));
            }
        }
        if g.indegrees()[t] != g.preds(TaskId(t)).len() {
            return Err(format!("indegree of {t} disagrees with preds"));
        }
    }
    if pred_edges != g.n_edges() {
        return Err(format!(
            "edge count mismatch: preds {pred_edges} vs CSR {}",
            g.n_edges()
        ));
    }
    let want_roots: Vec<usize> =
        (0..n).filter(|&t| g.indegrees()[t] == 0).collect();
    if g.roots() != want_roots.as_slice() {
        return Err("roots disagree with zero in-degrees".into());
    }
    let mut indeg = g.indegrees().to_vec();
    let mut queue: Vec<usize> = g.roots().to_vec();
    let mut popped = 0usize;
    while let Some(t) = queue.pop() {
        popped += 1;
        for &s in g.succs(TaskId(t)) {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push(s);
            }
        }
    }
    if popped != n {
        return Err(format!("cycle: drained {popped} of {n}"));
    }
    Ok(())
}

#[test]
fn registry_is_complete_and_consistent() {
    let names: Vec<&str> =
        registry().iter().map(|w| w.name()).collect();
    for want in ["sparselu", "cholesky", "matmul"] {
        assert!(names.contains(&want), "registry lost {want}");
    }
    for (i, w) in registry().iter().enumerate() {
        assert!(!w.description().is_empty(), "{}", w.name());
        assert_eq!(
            w.kernels().len(),
            w.ops().len(),
            "{}: kernel table must cover the op vocabulary",
            w.name()
        );
        for later in &registry()[i + 1..] {
            assert_ne!(w.name(), later.name(), "duplicate name");
        }
        assert_eq!(
            gprm::sched::workload::find(w.name()).unwrap().name(),
            w.name()
        );
    }
}

#[test]
fn every_entry_graph_is_acyclic_with_mutual_inverse_csr() {
    for w in registry() {
        for nb in [1usize, 2, 5, 9, 14] {
            let p = Params::new(nb, 4);
            let g = w.graph(&p);
            assert!(!g.is_empty(), "{} nb={nb}: empty graph", w.name());
            check_csr_invariants(&g)
                .unwrap_or_else(|e| panic!("{} nb={nb}: {e}", w.name()));
            // The canonical input's graph must satisfy the same
            // invariants (SparseLU's pattern-derived form).
            let input = w.make_input(&p, 0);
            check_csr_invariants(&w.graph_for(&input))
                .unwrap_or_else(|e| panic!("{} nb={nb}: {e}", w.name()));
        }
    }
}

#[test]
fn every_entry_is_bit_identical_on_all_hosts() {
    // One-shot executors (both runtimes, both executor modes) and the
    // persistent pool: every registered workload's parallel result
    // must equal its own sequential reference bit-for-bit, and pass
    // the residual check against ground truth.
    let p = Params::new(7, 5);
    let omp = OmpRuntime::new(4);
    let gprm = GprmRuntime::with_tiles(4);
    let pool = Pool::new(4);
    for w in registry() {
        let input = w.make_input(&p, 0);
        let mut want = input.deep_clone();
        w.reference_seq(&mut want);
        let hosts: [(&str, DataflowRt); 3] = [
            ("omp", DataflowRt::Omp(&omp)),
            ("gprm", DataflowRt::Gprm(&gprm)),
            ("pool", DataflowRt::Pool(&pool)),
        ];
        for (host, rt) in hosts {
            let execs: Vec<ExecOpts> = if host == "pool" {
                vec![ExecOpts::default()]
            } else {
                vec![ExecOpts::default(), ExecOpts::mutex_baseline()]
            };
            for &exec in &execs {
                let mut a = input.deep_clone();
                let stats = run_workload(&rt, *w, &mut a, exec)
                    .unwrap_or_else(|e| {
                        panic!("{} on {host}: {e}", w.name())
                    });
                assert_eq!(
                    stats.executed,
                    w.graph_for(&input).len(),
                    "{} on {host}",
                    w.name()
                );
                w.verify_bits(&a, &want).unwrap_or_else(|e| {
                    panic!("{} on {host}: {e}", w.name())
                });
                let res = w.residual(&input, &a);
                assert!(
                    res < 1e-3,
                    "{} on {host}: residual {res}",
                    w.name()
                );
            }
        }
    }
    pool.shutdown();
    gprm.shutdown();
    omp.shutdown();
}

#[test]
fn every_entry_is_bit_identical_with_locality_domains() {
    // Locality-aware stealing must be a pure scheduling change: with
    // the team split into 2 affinity domains (nearest-first victim
    // orders on the one-shot executors, per-domain injectors +
    // home-domain seeding on the pool), every registered workload
    // must still match its sequential reference bit-for-bit — in both
    // executor modes, on all three hosts.
    use gprm::sched::PoolConfig;
    let p = Params::new(7, 5);
    let omp = OmpRuntime::new(4);
    let gprm = GprmRuntime::with_tiles(4);
    let pool = Pool::with_config(PoolConfig::new(4).with_domains(2));
    for w in registry() {
        let input = w.make_input(&p, 0);
        let mut want = input.deep_clone();
        w.reference_seq(&mut want);
        let hosts: [(&str, DataflowRt); 3] = [
            ("omp", DataflowRt::Omp(&omp)),
            ("gprm", DataflowRt::Gprm(&gprm)),
            ("pool", DataflowRt::Pool(&pool)),
        ];
        for (host, rt) in hosts {
            let execs: Vec<ExecOpts> = if host == "pool" {
                // The pool's domain split comes from its config.
                vec![ExecOpts::default()]
            } else {
                vec![
                    ExecOpts::default().with_domains(2),
                    ExecOpts::mutex_baseline().with_domains(2),
                ]
            };
            for &exec in &execs {
                let mut a = input.deep_clone();
                let stats = run_workload(&rt, *w, &mut a, exec)
                    .unwrap_or_else(|e| {
                        panic!("{} on {host} domains=2: {e}", w.name())
                    });
                assert_eq!(
                    stats.executed,
                    w.graph_for(&input).len(),
                    "{} on {host} domains=2",
                    w.name()
                );
                w.verify_bits(&a, &want).unwrap_or_else(|e| {
                    panic!("{} on {host} domains=2: {e}", w.name())
                });
                let res = w.residual(&input, &a);
                assert!(
                    res < 1e-3,
                    "{} on {host} domains=2: residual {res}",
                    w.name()
                );
            }
        }
    }
    pool.shutdown();
    gprm.shutdown();
    omp.shutdown();
}

/// Cheap deterministic spin: xorshift a counter with the case seed
/// into a busy-wait length, so schedules differ case to case.
fn spin_for(x: usize, seed: usize) {
    let mut v = (x as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(seed as u64 | 1);
    v ^= v >> 12;
    v ^= v << 25;
    v ^= v >> 27;
    for _ in 0..(v % 1_500) as u32 {
        std::hint::spin_loop();
    }
}

#[test]
fn interjob_dependency_chain_races_100_randomized_schedules() {
    // The new pool capability under stress: job B reads job A's
    // output — both jobs run the blocked-matmul graph over ONE shared
    // matrix (C += A·B twice), with B submitted `after` A. Across 100
    // randomized schedules (worker count, kernel spins, sizing), the
    // result must be bit-identical to applying the sequential
    // reference twice — any admission of B before A fully drained
    // would interleave same-block writes and break exactness.
    check(
        "interjob-dependency-stress",
        100,
        &Triple(UsizeRange(2, 6), UsizeRange(1, 9), UsizeRange(0, 1 << 16)),
        |&(nbc, workers, seed)| {
            let bs = 3 + (seed % 4); // bs ∈ [3, 6]
            let p = Params::new(nbc, bs);
            let input = Matmul.make_input(&p, (seed % 7) as u32);
            let mut want = input.deep_clone();
            Matmul.reference_seq(&mut want);
            Matmul.reference_seq(&mut want);
            let graph = Matmul.graph_for(&input);

            let pool = Pool::new(workers);
            let shared = SharedBlocked::new(input);
            let ctr = AtomicUsize::new(0);
            let a_done = AtomicUsize::new(0);
            let order_ok = AtomicBool::new(true);
            let base = kernel_runner(
                &graph,
                Matmul.kernels(),
                &shared,
                bs,
            );
            pool.scope(|s| {
                let run_a = |t: TaskId| {
                    spin_for(ctr.fetch_add(1, Ordering::Relaxed), seed);
                    base(t);
                    a_done.fetch_add(1, Ordering::SeqCst);
                };
                let run_b = |t: TaskId| {
                    if a_done.load(Ordering::SeqCst) != graph.len() {
                        order_ok.store(false, Ordering::SeqCst);
                    }
                    spin_for(ctr.fetch_add(1, Ordering::Relaxed), seed);
                    base(t);
                };
                let a = s.submit(&graph, run_a).map_err(|e| e.to_string())?;
                let b = s
                    .submit_after(&graph, run_b, &[&a])
                    .map_err(|e| e.to_string())?;
                let stats = b.wait().map_err(|e| e.to_string())?;
                if stats.executed != graph.len() {
                    return Err("job B did not drain".into());
                }
                Ok(())
            })?;
            let result = shared.into_inner();
            if !order_ok.load(Ordering::SeqCst) {
                return Err(format!(
                    "a task of B started before A drained \
                     (nbc={nbc} workers={workers} seed={seed})"
                ));
            }
            if result.to_dense().as_slice() != want.to_dense().as_slice()
            {
                return Err(format!(
                    "chained result not bit-identical to double \
                     reference (nbc={nbc} workers={workers} seed={seed})"
                ));
            }
            pool.shutdown();
            Ok(())
        },
    );
}

#[test]
fn take_output_misuse_is_typed_and_finish_accounts_for_the_rest() {
    use gprm::sched::workload::{Cholesky, Sparselu};
    use gprm::sched::{Error, Session};

    let pool = Pool::new(4);
    let mut s = Session::new(&pool);
    let _h1 = s.job(Sparselu::params(5, 4)).submit().unwrap();
    let h2 = s.job(Cholesky::params(5, 4)).submit().unwrap();
    let _h3 = s.job(Matmul::params(3, 4)).submit().unwrap();

    // A handle that was never submitted through this session (a raw
    // scope job on the same pool): typed error, no panic.
    let p = Params::new(3, 4);
    let foreign_graph = Matmul.graph(&p);
    let foreign_shared = SharedBlocked::new(Matmul.make_input(&p, 0));
    let base =
        kernel_runner(&foreign_graph, Matmul.kernels(), &foreign_shared, 4);
    let foreign =
        pool.scope(|sc| sc.submit(&foreign_graph, &base).unwrap());
    assert_eq!(
        s.take_output(&foreign).err(),
        Some(Error::UnknownJob),
        "foreign handle must be the typed error"
    );
    assert_eq!(s.len(), 3, "a failed take must not retire anything");

    // Retire one job mid-session; the second take of the same handle
    // is the typed already-retired error.
    let out2 = s.take_output(&h2).unwrap();
    assert_eq!(
        s.take_output(&h2).err(),
        Some(Error::UnknownJob),
        "second take must be the typed error"
    );
    assert_eq!(s.len(), 2);
    let pc = Params::new(5, 4);
    let mut want = Cholesky.make_input(&pc, 0);
    Cholesky.reference_seq(&mut want);
    Cholesky
        .verify_bits(&out2, &want)
        .expect("retired output is the real factorisation");

    // finish() after the partial take accounts for exactly the
    // remaining jobs, in submission order.
    let rest = s.finish().unwrap();
    assert_eq!(rest.len(), 2);
    assert_eq!(rest[0].workload.name(), "sparselu");
    assert_eq!(rest[1].workload.name(), "matmul");
    pool.shutdown();
}
