//! The GPRM thread pool: one tile per "core", created once before the
//! program starts (paper §II: "At the beginning, a pool of threads is
//! created before the actual program starts"), optionally pinned
//! (paper §VII-A).

use super::kernel::Registry;
use super::packet::Packet;
use super::stats::{StatsSnapshot, TileStats};
use super::tile::{tile_loop, TileContext};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

/// A running pool of tile threads.
pub struct Pool {
    senders: Arc<Vec<mpsc::Sender<Packet>>>,
    handles: Vec<JoinHandle<()>>,
    stats: Vec<Arc<TileStats>>,
}

impl Pool {
    /// Spawn `n_tiles` tile threads sharing `registry`. If `pin`, tile
    /// `i` is pinned to host core `i % available_cores` (on Linux).
    pub fn new(n_tiles: usize, registry: Registry, pin: bool) -> Self {
        assert!(n_tiles > 0);
        let mut txs = Vec::with_capacity(n_tiles);
        let mut rxs = Vec::with_capacity(n_tiles);
        for _ in 0..n_tiles {
            let (tx, rx) = mpsc::channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let senders = Arc::new(txs);
        let stats: Vec<Arc<TileStats>> =
            (0..n_tiles).map(|_| Arc::new(TileStats::default())).collect();
        let ncores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut handles = Vec::with_capacity(n_tiles);
        for (id, rx) in rxs.into_iter().enumerate() {
            let ctx = TileContext {
                id,
                senders: senders.clone(),
                registry: registry.clone(),
                stats: stats[id].clone(),
            };
            let handle = std::thread::Builder::new()
                .name(format!("gprm-tile-{id}"))
                .spawn(move || {
                    if pin {
                        pin_to_core(id % ncores);
                    }
                    tile_loop(ctx, rx);
                })
                .expect("failed to spawn tile thread");
            handles.push(handle);
        }
        Self { senders, handles, stats }
    }

    pub fn n_tiles(&self) -> usize {
        self.senders.len()
    }

    /// Send a packet to tile `t`'s FIFO.
    pub fn send(&self, t: usize, pkt: Packet) {
        self.senders[t].send(pkt).expect("tile FIFO closed");
    }

    /// Per-tile stats snapshots.
    pub fn stats(&self) -> Vec<StatsSnapshot> {
        self.stats.iter().map(|s| s.snapshot()).collect()
    }

    /// Aggregate stats over all tiles.
    pub fn stats_total(&self) -> StatsSnapshot {
        self.stats()
            .into_iter()
            .fold(StatsSnapshot::default(), StatsSnapshot::merge)
    }

    /// Orderly shutdown: stop every tile and join the threads.
    pub fn shutdown(mut self) {
        for t in self.senders.iter() {
            let _ = t.send(Packet::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for t in self.senders.iter() {
            let _ = t.send(Packet::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pin the calling thread to one core (Linux `sched_setaffinity`).
/// No-op elsewhere.
///
/// Hand-rolled FFI: the `libc` crate is not in the offline crate set,
/// and std already links the platform libc, so declaring the symbol
/// directly is enough. `cpu_set_t` is a 1024-bit mask on Linux.
#[cfg(target_os = "linux")]
pub fn pin_to_core(core: usize) {
    #[repr(C)]
    struct CpuSetT {
        bits: [u64; 16], // 1024 bits
    }
    extern "C" {
        fn sched_setaffinity(
            pid: i32,
            cpusetsize: usize,
            mask: *const CpuSetT,
        ) -> i32;
    }
    if core >= 1024 {
        return;
    }
    let mut set = CpuSetT { bits: [0; 16] };
    set.bits[core / 64] |= 1u64 << (core % 64);
    // 0 = current thread. Failure (e.g. restricted cpuset) is
    // non-fatal: pinning is a performance hint.
    unsafe {
        sched_setaffinity(0, std::mem::size_of::<CpuSetT>(), &set);
    }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_core: usize) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kernel::ClosureKernel;
    use crate::coordinator::packet::RetAddr;
    use crate::coordinator::program::Prog;
    use crate::coordinator::value::Value;

    fn registry() -> Registry {
        let mut r = Registry::new();
        r.register(Arc::new(ClosureKernel::new("id").method("of", |a| {
            a.first().cloned().unwrap_or(Value::Unit)
        })));
        r
    }

    #[test]
    fn pool_executes_request() {
        let pool = Pool::new(4, registry(), false);
        let prog = Arc::new(
            Prog::call("id", "of", vec![Prog::lit(42i64)])
                .compile(&registry(), 4)
                .unwrap(),
        );
        let (tx, rx) = mpsc::channel();
        pool.send(
            prog.nodes[prog.root].tile,
            Packet::Request { prog: prog.clone(), node: prog.root, ret: RetAddr::Root(tx) },
        );
        let v = rx.recv().unwrap().unwrap();
        assert_eq!(v, Value::Int(42));
        let total = pool.stats_total();
        assert_eq!(total.tasks, 1);
        assert!(total.packets >= 1);
        pool.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = Pool::new(8, registry(), false);
        assert_eq!(pool.n_tiles(), 8);
        pool.shutdown();
    }

    #[test]
    fn drop_also_shuts_down() {
        let _pool = Pool::new(2, registry(), false);
        // dropping must not hang
    }

    #[test]
    fn pinning_smoke() {
        // Must not crash even on a 1-core box.
        let pool = Pool::new(2, registry(), true);
        pool.shutdown();
        pin_to_core(0);
    }
}
