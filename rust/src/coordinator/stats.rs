//! Per-tile counters (benches and the §Perf iteration log read these).

use std::sync::atomic::{AtomicU64, Ordering};

/// Counters maintained by one tile's event loop. All relaxed — they
/// are diagnostics, not synchronisation.
#[derive(Default, Debug)]
pub struct TileStats {
    /// Packets dequeued (requests + responses).
    pub packets: AtomicU64,
    /// Task kernels fired (Call + Native nodes).
    pub tasks: AtomicU64,
    /// Nanoseconds spent inside task kernels.
    pub kernel_ns: AtomicU64,
    /// Activation records created.
    pub activations: AtomicU64,
}

impl TileStats {
    pub fn add_packet(&self) {
        self.packets.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_task(&self, kernel_ns: u64) {
        self.tasks.fetch_add(1, Ordering::Relaxed);
        self.kernel_ns.fetch_add(kernel_ns, Ordering::Relaxed);
    }

    pub fn add_activation(&self) {
        self.activations.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot as plain numbers.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            packets: self.packets.load(Ordering::Relaxed),
            tasks: self.tasks.load(Ordering::Relaxed),
            kernel_ns: self.kernel_ns.load(Ordering::Relaxed),
            activations: self.activations.load(Ordering::Relaxed),
        }
    }
}

/// Plain-number snapshot of [`TileStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub packets: u64,
    pub tasks: u64,
    pub kernel_ns: u64,
    pub activations: u64,
}

impl StatsSnapshot {
    pub fn merge(self, other: StatsSnapshot) -> StatsSnapshot {
        StatsSnapshot {
            packets: self.packets + other.packets,
            tasks: self.tasks + other.tasks,
            kernel_ns: self.kernel_ns + other.kernel_ns,
            activations: self.activations + other.activations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = TileStats::default();
        s.add_packet();
        s.add_packet();
        s.add_task(100);
        s.add_activation();
        let snap = s.snapshot();
        assert_eq!(snap.packets, 2);
        assert_eq!(snap.tasks, 1);
        assert_eq!(snap.kernel_ns, 100);
        assert_eq!(snap.activations, 1);
    }

    #[test]
    fn merge_adds() {
        let a = StatsSnapshot { packets: 1, tasks: 2, kernel_ns: 3, activations: 4 };
        let b = StatsSnapshot { packets: 10, tasks: 20, kernel_ns: 30, activations: 40 };
        let m = a.merge(b);
        assert_eq!(m.packets, 11);
        assert_eq!(m.tasks, 22);
        assert_eq!(m.kernel_ns, 33);
        assert_eq!(m.activations, 44);
    }
}
