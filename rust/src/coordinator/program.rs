//! Communication-code representation and its "bytecode" compilation
//! (paper §II).
//!
//! A GPRM task is an S-expression such as `(S1 (S2 10) 20)`; the
//! compiler flattens the tree into per-node *code packets* and assigns
//! every task node to a tile — the *task description*. The builder API
//! ([`Prog`]) constructs the same trees programmatically and is what
//! `#pragma gprm unroll` lowers to: loops over task spawns are
//! evaluated at **compile time** ([`Prog::unroll`]).

use super::kernel::Registry;
use super::value::Value;
use std::sync::Arc;

/// A native task body: a rust closure playing the role of a C++ task
/// kernel method bound to one index (the hybrid worksharing-tasking
/// fast path used by `GprmRuntime::par_invoke`).
pub type NativeFn = Arc<dyn Fn(usize) -> Value + Send + Sync>;

/// Builder-level expression (communication code AST).
#[derive(Clone)]
pub enum Prog {
    /// Literal constant.
    Const(Value),
    /// Kernel method call; `tile` optionally pins the task to a tile
    /// ("it is straightforward to specify which task to be run on
    /// which thread initially", §VII-B).
    Call {
        kernel: String,
        method: String,
        args: Vec<Prog>,
        tile: Option<usize>,
    },
    /// `#pragma gprm seq` — evaluate children one after another;
    /// value of the last child.
    Seq(Vec<Prog>),
    /// Default GPRM evaluation — children evaluated in parallel;
    /// value is the list of child values.
    Par(Vec<Prog>),
    /// Native closure task (see [`NativeFn`]).
    Native { f: NativeFn, ind: usize, tile: Option<usize> },
}

impl Prog {
    pub fn lit(v: impl Into<Value>) -> Prog {
        Prog::Const(v.into())
    }

    pub fn call(kernel: &str, method: &str, args: Vec<Prog>) -> Prog {
        Prog::Call {
            kernel: kernel.into(),
            method: method.into(),
            args,
            tile: None,
        }
    }

    /// Pin a `Call`/`Native` node to a tile.
    pub fn on_tile(self, t: usize) -> Prog {
        match self {
            Prog::Call { kernel, method, args, .. } => {
                Prog::Call { kernel, method, args, tile: Some(t) }
            }
            Prog::Native { f, ind, .. } => {
                Prog::Native { f, ind, tile: Some(t) }
            }
            other => other,
        }
    }

    pub fn seq(items: Vec<Prog>) -> Prog {
        Prog::Seq(items)
    }

    pub fn par(items: Vec<Prog>) -> Prog {
        Prog::Par(items)
    }

    /// `#pragma gprm unroll`: compile-time loop evaluation — the body
    /// closure is expanded for every index *now*, producing a `par`
    /// node of the spawned tasks (paper Listing 5).
    pub fn unroll(
        range: std::ops::Range<usize>,
        body: impl Fn(usize) -> Prog,
    ) -> Prog {
        Prog::Par(range.map(body).collect())
    }

    /// Native closure task with an index argument.
    pub fn native(ind: usize, f: NativeFn) -> Prog {
        Prog::Native { f, ind, tile: None }
    }

    /// Compile against a registry onto `n_tiles` tiles.
    pub fn compile(
        &self,
        registry: &Registry,
        n_tiles: usize,
    ) -> Result<Program, String> {
        assert!(n_tiles > 0);
        let mut c = Compiler { registry, n_tiles, next_tile: 0, nodes: Vec::new() };
        let root = c.lower(self)?;
        // Locality post-pass: control/const nodes live on the tile of
        // their first task child (falling back to 0), so reduction
        // traffic stays near the work.
        let mut prog = Program { nodes: c.nodes, root };
        fixup_control_tiles(&mut prog);
        Ok(prog)
    }
}

/// Compiled node operation.
pub enum NodeOp {
    Const(Value),
    Call { kernel: usize, method: usize },
    Native { f: NativeFn, ind: usize },
    Seq,
    Par,
}

/// One compiled code packet.
pub struct Node {
    pub op: NodeOp,
    /// Child node ids (arguments).
    pub args: Vec<usize>,
    /// Hosting tile (the task description entry for this node).
    pub tile: usize,
}

/// A compiled program: flat node store + root id.
pub struct Program {
    pub nodes: Vec<Node>,
    pub root: usize,
}

impl Program {
    /// Total number of task nodes (Call + Native), i.e. tasks the
    /// reduction engine will fire.
    pub fn task_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, NodeOp::Call { .. } | NodeOp::Native { .. }))
            .count()
    }

    /// The task→tile assignment restricted to task nodes, in node
    /// order. Used by tests to verify the round-robin description.
    pub fn task_tiles(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, NodeOp::Call { .. } | NodeOp::Native { .. }))
            .map(|n| n.tile)
            .collect()
    }
}

struct Compiler<'a> {
    registry: &'a Registry,
    n_tiles: usize,
    next_tile: usize,
    nodes: Vec<Node>,
}

impl<'a> Compiler<'a> {
    fn alloc_task_tile(&mut self, explicit: Option<usize>) -> usize {
        match explicit {
            Some(t) => t % self.n_tiles,
            None => {
                let t = self.next_tile % self.n_tiles;
                self.next_tile += 1;
                t
            }
        }
    }

    fn lower(&mut self, p: &Prog) -> Result<usize, String> {
        let node = match p {
            Prog::Const(v) => {
                Node { op: NodeOp::Const(v.clone()), args: vec![], tile: 0 }
            }
            Prog::Call { kernel, method, args, tile } => {
                let (ki, mi) = self
                    .registry
                    .resolve(kernel, method)
                    .ok_or_else(|| format!("unknown task {kernel}.{method}"))?;
                let mut arg_ids = Vec::with_capacity(args.len());
                for a in args {
                    arg_ids.push(self.lower(a)?);
                }
                let t = self.alloc_task_tile(*tile);
                Node {
                    op: NodeOp::Call { kernel: ki, method: mi },
                    args: arg_ids,
                    tile: t,
                }
            }
            Prog::Native { f, ind, tile } => {
                let t = self.alloc_task_tile(*tile);
                Node {
                    op: NodeOp::Native { f: f.clone(), ind: *ind },
                    args: vec![],
                    tile: t,
                }
            }
            Prog::Seq(items) | Prog::Par(items) => {
                let is_seq = matches!(p, Prog::Seq(_));
                let mut arg_ids = Vec::with_capacity(items.len());
                for a in items {
                    arg_ids.push(self.lower(a)?);
                }
                Node {
                    op: if is_seq { NodeOp::Seq } else { NodeOp::Par },
                    args: arg_ids,
                    tile: 0, // fixed up in the post-pass
                }
            }
        };
        self.nodes.push(node);
        Ok(self.nodes.len() - 1)
    }
}

fn fixup_control_tiles(prog: &mut Program) {
    // Children are lowered before parents, so one forward pass sees
    // children already fixed.
    for i in 0..prog.nodes.len() {
        if matches!(prog.nodes[i].op, NodeOp::Seq | NodeOp::Par) {
            let t = prog.nodes[i]
                .args
                .iter()
                .map(|&c| prog.nodes[c].tile)
                .next()
                .unwrap_or(0);
            prog.nodes[i].tile = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kernel::ClosureKernel;

    fn reg() -> Registry {
        let mut r = Registry::new();
        r.register(Arc::new(
            ClosureKernel::new("k")
                .method("f", |a| Value::Int(a.iter().map(|v| v.int()).sum()))
                .method("g", |_| Value::Unit),
        ));
        r
    }

    #[test]
    fn round_robin_task_description() {
        // (par (k.f) (k.f) (k.f) (k.f) (k.f)) on 3 tiles → 0 1 2 0 1.
        let p = Prog::par((0..5).map(|_| Prog::call("k", "f", vec![])).collect());
        let prog = p.compile(&reg(), 3).unwrap();
        assert_eq!(prog.task_tiles(), vec![0, 1, 2, 0, 1]);
        assert_eq!(prog.task_count(), 5);
    }

    #[test]
    fn explicit_pinning_wins() {
        let p = Prog::par(vec![
            Prog::call("k", "f", vec![]).on_tile(7),
            Prog::call("k", "f", vec![]),
        ]);
        let prog = p.compile(&reg(), 4).unwrap();
        assert_eq!(prog.task_tiles(), vec![3, 0]); // 7 % 4 = 3
    }

    #[test]
    fn unknown_task_rejected() {
        let p = Prog::call("k", "nope", vec![]);
        assert!(p.compile(&reg(), 2).is_err());
        let p2 = Prog::call("zzz", "f", vec![]);
        assert!(p2.compile(&reg(), 2).is_err());
    }

    #[test]
    fn unroll_is_compile_time() {
        let p = Prog::unroll(0..4, |i| {
            Prog::call("k", "f", vec![Prog::lit(i as i64)])
        });
        let prog = p.compile(&reg(), 63).unwrap();
        assert_eq!(prog.task_count(), 4);
        // Each unrolled task got consecutive tiles.
        assert_eq!(prog.task_tiles(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn control_nodes_follow_first_child() {
        let p = Prog::seq(vec![
            Prog::call("k", "f", vec![]).on_tile(5),
            Prog::call("k", "g", vec![]),
        ]);
        let prog = p.compile(&reg(), 8).unwrap();
        let root = &prog.nodes[prog.root];
        assert!(matches!(root.op, NodeOp::Seq));
        assert_eq!(root.tile, 5);
    }

    #[test]
    fn nested_args_compile() {
        // (k.f (k.f 10) 20) — the paper's canonical example shape.
        let p = Prog::call(
            "k",
            "f",
            vec![
                Prog::call("k", "f", vec![Prog::lit(10i64)]),
                Prog::lit(20i64),
            ],
        );
        let prog = p.compile(&reg(), 2).unwrap();
        assert_eq!(prog.task_count(), 2);
        // Root call has two args: a call node and a const node.
        let root = &prog.nodes[prog.root];
        assert_eq!(root.args.len(), 2);
        assert!(matches!(
            prog.nodes[root.args[0]].op,
            NodeOp::Call { .. }
        ));
        assert!(matches!(prog.nodes[root.args[1]].op, NodeOp::Const(_)));
    }
}
