//! GPRM parallel-loop (worksharing) constructs — paper §III.
//!
//! "In GPRM, multiple instances of the same task — normally as many as
//! the concurrency level — are generated, each with a different index
//! (similar to the global_id in OpenCL). Each of these tasks calls the
//! parallel loop passing in their own index to specify which parts of
//! the work should be performed by their host thread."
//!
//! * [`par_for`] — round-robin single loop (Listing 1, Fig 1a).
//! * [`par_nested_for`] — a nested loop treated as one flattened loop
//!   with the same round-robin pattern (Listing 2).
//! * [`par_for_contiguous`] / [`par_nested_for_contiguous`] — the
//!   *contiguous* method (Fig 1b): every thread gets an `m/n` chunk and
//!   the remainder `m%n` is handed one-by-one to the foremost threads.
//!
//! The faithful loop from Listing 1 and a closed-form strided iterator
//! are both provided; a property test pins their equivalence.

/// Faithful port of paper Listing 1. Calls `work(i)` for every
/// iteration `i ∈ [start, size)` owned by task index `ind` out of `cl`
/// (concurrency level), in round-robin order with step 1 (Fig 1a).
pub fn par_for(
    start: usize,
    size: usize,
    ind: usize,
    cl: usize,
    mut work: impl FnMut(usize),
) {
    assert!(cl > 0 && ind < cl, "index {ind} out of concurrency level {cl}");
    // Listing 1 verbatim: `turn` only advances while skipping; once
    // `turn % CL == ind` the task strides by CL.
    let mut turn = 0usize;
    let mut i = start;
    while i < size {
        if turn % cl == ind {
            work(i);
            i += cl;
        } else {
            i += 1;
            turn += 1;
        }
    }
}

/// Closed form of [`par_for`]: the iterations owned by `ind` are
/// `start+ind, start+ind+cl, start+ind+2cl, …` (proved equivalent by a
/// property test).
pub fn par_for_indices(
    start: usize,
    size: usize,
    ind: usize,
    cl: usize,
) -> impl Iterator<Item = usize> {
    assert!(cl > 0 && ind < cl);
    (start + ind..size).step_by(cl.max(1)).take_while(move |&i| i < size)
}

/// Paper Listing 2: a nested `(i, j)` loop treated as a single
/// flattened loop of `(size1-start1)·(size2-start2)` iterations,
/// distributed round-robin. Iteration `g` (row-major over `(i, j)`)
/// belongs to task `ind` iff `g % cl == ind`.
///
/// (The listing in the paper carries `turn` across rows so the
/// round-robin pattern continues seamlessly at row boundaries — i.e.
/// exactly the flattened-loop semantics implemented here; §III: "A
/// par_nested_for treats a nested loop as a single loop and follows
/// the same pattern".)
#[allow(clippy::too_many_arguments)]
pub fn par_nested_for(
    start1: usize,
    size1: usize,
    start2: usize,
    size2: usize,
    ind: usize,
    cl: usize,
    mut work: impl FnMut(usize, usize),
) {
    assert!(cl > 0 && ind < cl);
    if size1 <= start1 || size2 <= start2 {
        return;
    }
    let inner = size2 - start2;
    let total = (size1 - start1) * inner;
    let mut g = ind;
    while g < total {
        let i = start1 + g / inner;
        let j = start2 + g % inner;
        work(i, j);
        g += cl;
    }
}

/// Contiguous partitioning (Fig 1b): thread `ind` gets a block of
/// `m/n` iterations, and the remainder `m % n` is distributed
/// one-by-one to the foremost threads. Returns the owned subrange
/// `[lo, hi)` of `[start, size)`.
pub fn contiguous_range(
    start: usize,
    size: usize,
    ind: usize,
    cl: usize,
) -> (usize, usize) {
    assert!(cl > 0 && ind < cl);
    let m = size.saturating_sub(start);
    let base = m / cl;
    let rem = m % cl;
    let extra_before = ind.min(rem);
    let lo = start + ind * base + extra_before;
    let len = base + usize::from(ind < rem);
    (lo, lo + len)
}

/// Contiguous single loop (Fig 1b).
pub fn par_for_contiguous(
    start: usize,
    size: usize,
    ind: usize,
    cl: usize,
    mut work: impl FnMut(usize),
) {
    let (lo, hi) = contiguous_range(start, size, ind, cl);
    for i in lo..hi {
        work(i);
    }
}

/// Contiguous nested loop: flatten, chunk, un-flatten.
#[allow(clippy::too_many_arguments)]
pub fn par_nested_for_contiguous(
    start1: usize,
    size1: usize,
    start2: usize,
    size2: usize,
    ind: usize,
    cl: usize,
    mut work: impl FnMut(usize, usize),
) {
    assert!(cl > 0 && ind < cl);
    if size1 <= start1 || size2 <= start2 {
        return;
    }
    let inner = size2 - start2;
    let total = (size1 - start1) * inner;
    let (lo, hi) = contiguous_range(0, total, ind, cl);
    for g in lo..hi {
        work(start1 + g / inner, start2 + g % inner);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    /// Collect iterations from a worksharing run for all indices.
    fn collect_all(
        start: usize,
        size: usize,
        cl: usize,
        f: impl Fn(usize, &mut Vec<usize>),
    ) -> (Vec<usize>, Vec<Vec<usize>>) {
        let mut per = Vec::new();
        let mut all = Vec::new();
        for ind in 0..cl {
            let mut v = Vec::new();
            f(ind, &mut v);
            all.extend(v.iter().copied());
            per.push(v);
        }
        all.sort_unstable();
        let _ = (start, size);
        (all, per)
    }

    #[test]
    fn par_for_fig1a_example() {
        // Paper Fig 1: m=9 iterations over n=4 threads, step size 1:
        // t0:{0,4,8} t1:{1,5} t2:{2,6} t3:{3,7}.
        let mut per = Vec::new();
        for ind in 0..4 {
            let mut v = Vec::new();
            par_for(0, 9, ind, 4, |i| v.push(i));
            per.push(v);
        }
        assert_eq!(per[0], vec![0, 4, 8]);
        assert_eq!(per[1], vec![1, 5]);
        assert_eq!(per[2], vec![2, 6]);
        assert_eq!(per[3], vec![3, 7]);
    }

    #[test]
    fn par_for_contiguous_fig1b_example() {
        // Fig 1b: contiguous m=9, n=4 → chunks 3,2,2,2.
        let mut sizes = Vec::new();
        for ind in 0..4 {
            let (lo, hi) = contiguous_range(0, 9, ind, 4);
            sizes.push(hi - lo);
        }
        assert_eq!(sizes, vec![3, 2, 2, 2]);
        assert_eq!(contiguous_range(0, 9, 0, 4), (0, 3));
        assert_eq!(contiguous_range(0, 9, 3, 4), (7, 9));
    }

    #[test]
    fn par_for_covers_exactly_once() {
        for &(start, size, cl) in
            &[(0, 100, 7), (3, 50, 4), (0, 5, 8), (10, 10, 3), (0, 1, 1)]
        {
            let (all, _) = collect_all(start, size, cl, |ind, v| {
                par_for(start, size, ind, cl, |i| v.push(i))
            });
            let expect: Vec<usize> = (start..size).collect();
            assert_eq!(all, expect, "start={start} size={size} cl={cl}");
        }
    }

    #[test]
    fn par_for_matches_closed_form() {
        for &(start, size, cl) in &[(0, 37, 5), (2, 100, 63), (0, 9, 4)] {
            for ind in 0..cl {
                let mut v = Vec::new();
                par_for(start, size, ind, cl, |i| v.push(i));
                let w: Vec<usize> =
                    par_for_indices(start, size, ind, cl).collect();
                assert_eq!(v, w);
            }
        }
    }

    #[test]
    fn nested_equals_flattened_single() {
        // par_nested_for over (i, j) must equal par_for over the
        // flattened index space.
        let (s1, z1, s2, z2, cl) = (1usize, 5usize, 2usize, 9usize, 4usize);
        let inner = z2 - s2;
        for ind in 0..cl {
            let mut nested = Vec::new();
            par_nested_for(s1, z1, s2, z2, ind, cl, |i, j| {
                nested.push((i - s1) * inner + (j - s2))
            });
            let mut flat = Vec::new();
            par_for(0, (z1 - s1) * inner, ind, cl, |g| flat.push(g));
            assert_eq!(nested, flat, "ind={ind}");
        }
    }

    #[test]
    fn nested_disjoint_cover() {
        let (s1, z1, s2, z2, cl) = (0usize, 7usize, 0usize, 11usize, 5usize);
        let mut seen = BTreeSet::new();
        let mut count = 0usize;
        for ind in 0..cl {
            par_nested_for(s1, z1, s2, z2, ind, cl, |i, j| {
                assert!(seen.insert((i, j)), "duplicate ({i},{j})");
                count += 1;
            });
        }
        assert_eq!(count, 7 * 11);
    }

    #[test]
    fn contiguous_cover_and_balance() {
        for &(start, size, cl) in &[(0, 100, 7), (5, 64, 63), (0, 3, 8)] {
            let mut seen = BTreeSet::new();
            let mut sizes = Vec::new();
            for ind in 0..cl {
                let mut n = 0;
                par_for_contiguous(start, size, ind, cl, |i| {
                    assert!(seen.insert(i));
                    n += 1;
                });
                sizes.push(n);
            }
            assert_eq!(seen.len(), size - start);
            // Balance: sizes differ by at most 1 and are non-increasing
            // ("remainder … one-by-one to the foremost threads").
            let max = *sizes.iter().max().unwrap();
            let min = *sizes.iter().min().unwrap();
            assert!(max - min <= 1);
            for w in sizes.windows(2) {
                assert!(w[0] >= w[1]);
            }
        }
    }

    #[test]
    fn nested_contiguous_cover() {
        let mut seen = BTreeSet::new();
        for ind in 0..6 {
            par_nested_for_contiguous(2, 6, 1, 8, ind, 6, |i, j| {
                assert!(seen.insert((i, j)));
                assert!((2..6).contains(&i) && (1..8).contains(&j));
            });
        }
        assert_eq!(seen.len(), 4 * 7);
    }

    #[test]
    fn empty_ranges_are_noops() {
        par_for(5, 5, 0, 4, |_| panic!("no work expected"));
        par_nested_for(3, 3, 0, 9, 0, 2, |_, _| panic!("no work"));
        par_nested_for(0, 9, 4, 4, 0, 2, |_, _| panic!("no work"));
        par_for_contiguous(7, 7, 1, 2, |_| panic!("no work"));
    }

    #[test]
    fn starvation_shape_paper_motivation() {
        // §VI: with par_for over a shrinking outer loop, once
        // outer_iters < CL some threads starve; par_nested_for keeps
        // threads busy while outer*inner > CL. Verify that claim.
        let cl = 8;
        let outer = 3; // < cl
        let inner = 5; // outer*inner = 15 > cl
        let mut starved_par_for = 0;
        let mut starved_nested = 0;
        for ind in 0..cl {
            let mut n = 0;
            par_for(0, outer, ind, cl, |_| n += 1);
            if n == 0 {
                starved_par_for += 1;
            }
            let mut m = 0;
            par_nested_for(0, outer, 0, inner, ind, cl, |_, _| m += 1);
            if m == 0 {
                starved_nested += 1;
            }
        }
        assert_eq!(starved_par_for, cl - outer); // 5 threads idle
        assert_eq!(starved_nested, 0); // everyone works
    }
}
