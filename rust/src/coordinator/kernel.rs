//! Task kernels — the `GPRM::Kernel` namespace analogue (paper §II).
//!
//! "A task kernel is typically a complex, self-contained entity
//! offering a specific functionality to the system, which on its own
//! is not aware of the rest of the system." Kernels are registered
//! with the runtime by name; the communication code calls their
//! methods. Method dispatch is resolved to indices at program compile
//! time, so the hot path never touches strings.

use super::value::Value;
use std::sync::Arc;

/// A task kernel: a named object exposing methods callable from
/// communication code. Implementations must be `Send + Sync` because
/// any tile may host any of the kernel's task instances.
pub trait TaskKernel: Send + Sync {
    /// Kernel name, as referenced from communication code
    /// (`name.method`).
    fn name(&self) -> &str;

    /// Method names in index order.
    fn methods(&self) -> &[&'static str];

    /// Invoke method `idx` (an index into [`Self::methods`]).
    /// Run-to-completion semantics: the hosting tile thread executes
    /// this synchronously.
    fn call(&self, idx: usize, args: &[Value]) -> Value;
}

/// A kernel assembled from named closures — convenient for tests,
/// examples and ad-hoc task code.
pub struct ClosureKernel {
    name: String,
    method_names: Vec<&'static str>,
    bodies: Vec<Box<dyn Fn(&[Value]) -> Value + Send + Sync>>,
}

impl ClosureKernel {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            method_names: Vec::new(),
            bodies: Vec::new(),
        }
    }

    /// Add a method.
    pub fn method(
        mut self,
        name: &'static str,
        f: impl Fn(&[Value]) -> Value + Send + Sync + 'static,
    ) -> Self {
        self.method_names.push(name);
        self.bodies.push(Box::new(f));
        self
    }
}

impl TaskKernel for ClosureKernel {
    fn name(&self) -> &str {
        &self.name
    }

    fn methods(&self) -> &[&'static str] {
        &self.method_names
    }

    fn call(&self, idx: usize, args: &[Value]) -> Value {
        (self.bodies[idx])(args)
    }
}

/// The kernel registry: fixed at runtime construction (kernels are
/// "created before the actual program starts", like the GPRM thread
/// pool).
#[derive(Clone, Default)]
pub struct Registry {
    kernels: Vec<Arc<dyn TaskKernel>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, k: Arc<dyn TaskKernel>) {
        assert!(
            self.lookup_kernel(k.name()).is_none(),
            "duplicate kernel name {:?}",
            k.name()
        );
        self.kernels.push(k);
    }

    pub fn lookup_kernel(&self, name: &str) -> Option<usize> {
        self.kernels.iter().position(|k| k.name() == name)
    }

    /// Resolve `kernel.method` to `(kernel_idx, method_idx)`.
    pub fn resolve(&self, kernel: &str, method: &str) -> Option<(usize, usize)> {
        let ki = self.lookup_kernel(kernel)?;
        let mi = self.kernels[ki].methods().iter().position(|m| *m == method)?;
        Some((ki, mi))
    }

    pub fn get(&self, idx: usize) -> &Arc<dyn TaskKernel> {
        &self.kernels[idx]
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arith() -> Arc<dyn TaskKernel> {
        Arc::new(
            ClosureKernel::new("arith")
                .method("add", |a| {
                    Value::Int(a.iter().map(|v| v.int()).sum())
                })
                .method("mul", |a| {
                    Value::Int(a.iter().map(|v| v.int()).product())
                }),
        )
    }

    #[test]
    fn closure_kernel_dispatch() {
        let k = arith();
        assert_eq!(k.name(), "arith");
        assert_eq!(k.methods(), &["add", "mul"]);
        assert_eq!(k.call(0, &[Value::Int(2), Value::Int(3)]), Value::Int(5));
        assert_eq!(k.call(1, &[Value::Int(2), Value::Int(3)]), Value::Int(6));
    }

    #[test]
    fn registry_resolution() {
        let mut r = Registry::new();
        r.register(arith());
        assert_eq!(r.resolve("arith", "mul"), Some((0, 1)));
        assert_eq!(r.resolve("arith", "nope"), None);
        assert_eq!(r.resolve("nope", "add"), None);
        assert_eq!(r.len(), 1);
    }

    #[test]
    #[should_panic(expected = "duplicate kernel")]
    fn duplicate_names_rejected() {
        let mut r = Registry::new();
        r.register(arith());
        r.register(arith());
    }
}
