//! The tile event loop — task manager + reduction engine (paper §II).
//!
//! "Each tile consists of a task node and a FIFO queue for incoming
//! packets. Every tile runs in its own thread and blocks on the FIFO.
//! … The reduction engine, i.e. the task manager, evaluates the
//! bytecode via parallel dispatch of packets requesting computations
//! to other tiles."
//!
//! Evaluation protocol per node kind:
//!
//! * `Const`  — replied immediately (constants live in the bytecode).
//! * `Native` — the closure runs to completion on this tile.
//! * `Call`   — an *activation record* is created; request packets for
//!   all non-constant arguments are dispatched **in parallel**; when
//!   the last response arrives the kernel fires.
//! * `Par`    — like `Call` but the value is the list of child values.
//! * `Seq`    — children are dispatched one at a time (`#pragma gprm
//!   seq`).
//!
//! Task-kernel panics are caught and propagated as `Err` results; a
//! failed activation still waits for its outstanding children before
//! replying, so borrowed data (see `GprmRuntime::par_invoke`) is never
//! released while a task can still touch it.

use super::kernel::Registry;
use super::packet::{Packet, RetAddr, TaskResult};
use super::program::{NodeOp, Program};
use super::stats::TileStats;
use super::value::Value;
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Everything a tile thread needs.
pub struct TileContext {
    pub id: usize,
    pub senders: Arc<Vec<mpsc::Sender<Packet>>>,
    pub registry: Registry,
    pub stats: Arc<TileStats>,
}

/// Evaluation mode of an activation.
enum Mode {
    /// All children dispatched at once; result = kernel(args).
    Call { kernel: usize, method: usize },
    /// All children dispatched at once; result = list of child values.
    Par,
    /// Children dispatched one at a time; result = last child value.
    Seq { next: usize },
}

/// An in-flight node evaluation.
struct Activation {
    prog: Arc<Program>,
    node: usize,
    ret: RetAddr,
    mode: Mode,
    slots: Vec<Option<Value>>,
    /// Child requests dispatched but not yet responded.
    outstanding: usize,
    /// First error seen (kernel panic in some descendant).
    failed: Option<String>,
}

/// The tile event loop. Runs until a `Shutdown` packet arrives.
pub fn tile_loop(ctx: TileContext, rx: mpsc::Receiver<Packet>) {
    let mut tile = Tile {
        ctx,
        slab: Vec::new(),
        free: Vec::new(),
    };
    while let Ok(pkt) = rx.recv() {
        tile.ctx.stats.add_packet();
        match pkt {
            Packet::Shutdown => break,
            Packet::Request { prog, node, ret } => tile.on_request(prog, node, ret),
            Packet::Response { act, slot, value } => tile.on_response(act, slot, value),
        }
    }
}

struct Tile {
    ctx: TileContext,
    slab: Vec<Option<Activation>>,
    free: Vec<usize>,
}

impl Tile {
    fn send(&self, tile: usize, pkt: Packet) {
        // A send can only fail if the destination tile already shut
        // down, which the runtime's shutdown ordering prevents.
        self.ctx.senders[tile]
            .send(pkt)
            .expect("destination tile FIFO closed");
    }

    fn reply(&self, ret: RetAddr, value: TaskResult) {
        match ret {
            RetAddr::Root(tx) => {
                // The root may have gone away on error paths; ignore.
                let _ = tx.send(value);
            }
            RetAddr::Tile { tile, act, slot } => {
                self.send(tile, Packet::Response { act, slot, value });
            }
        }
    }

    /// Execute a task kernel with panic isolation.
    fn fire_kernel(&self, kernel: usize, method: usize, args: &[Value]) -> TaskResult {
        let k = self.ctx.registry.get(kernel).clone();
        let t0 = Instant::now();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            k.call(method, args)
        }));
        self.ctx.stats.add_task(t0.elapsed().as_nanos() as u64);
        r.map_err(|e| panic_message(e.as_ref()))
    }

    fn fire_native(
        &self,
        f: &super::program::NativeFn,
        ind: usize,
    ) -> TaskResult {
        let t0 = Instant::now();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ind)));
        self.ctx.stats.add_task(t0.elapsed().as_nanos() as u64);
        r.map_err(|e| panic_message(e.as_ref()))
    }

    fn alloc_act(&mut self, a: Activation) -> usize {
        self.ctx.stats.add_activation();
        if let Some(i) = self.free.pop() {
            self.slab[i] = Some(a);
            i
        } else {
            self.slab.push(Some(a));
            self.slab.len() - 1
        }
    }

    fn on_request(&mut self, prog: Arc<Program>, node: usize, ret: RetAddr) {
        match &prog.nodes[node].op {
            NodeOp::Const(v) => {
                let v = v.clone();
                self.reply(ret, Ok(v));
            }
            NodeOp::Native { f, ind } => {
                let r = self.fire_native(&f.clone(), *ind);
                self.reply(ret, r);
            }
            NodeOp::Call { kernel, method } => {
                let (kernel, method) = (*kernel, *method);
                let args = prog.nodes[node].args.clone();
                let act = self.alloc_act(Activation {
                    prog: prog.clone(),
                    node,
                    ret,
                    mode: Mode::Call { kernel, method },
                    slots: vec![None; args.len()],
                    outstanding: 0,
                    failed: None,
                });
                self.dispatch_all(act);
                self.try_complete(act);
            }
            NodeOp::Par => {
                let n = prog.nodes[node].args.len();
                let act = self.alloc_act(Activation {
                    prog: prog.clone(),
                    node,
                    ret,
                    mode: Mode::Par,
                    slots: vec![None; n],
                    outstanding: 0,
                    failed: None,
                });
                self.dispatch_all(act);
                self.try_complete(act);
            }
            NodeOp::Seq => {
                let n = prog.nodes[node].args.len();
                let act = self.alloc_act(Activation {
                    prog: prog.clone(),
                    node,
                    ret,
                    mode: Mode::Seq { next: 0 },
                    slots: vec![None; n],
                    outstanding: 0,
                    failed: None,
                });
                self.dispatch_seq_next(act);
                self.try_complete(act);
            }
        }
    }

    /// Parallel dispatch of every argument (Call / Par): constants are
    /// filled inline from the bytecode; each non-constant child gets a
    /// request packet sent to its hosting tile — all before any
    /// response is waited on.
    fn dispatch_all(&mut self, act_id: usize) {
        let (prog, children) = {
            let a = self.slab[act_id].as_ref().unwrap();
            (a.prog.clone(), a.prog.nodes[a.node].args.clone())
        };
        for (slot, &child) in children.iter().enumerate() {
            if let NodeOp::Const(v) = &prog.nodes[child].op {
                let v = v.clone();
                let a = self.slab[act_id].as_mut().unwrap();
                a.slots[slot] = Some(v);
            } else {
                {
                    let a = self.slab[act_id].as_mut().unwrap();
                    a.outstanding += 1;
                }
                let dest = prog.nodes[child].tile;
                self.send(
                    dest,
                    Packet::Request {
                        prog: prog.clone(),
                        node: child,
                        ret: RetAddr::Tile { tile: self.ctx.id, act: act_id, slot },
                    },
                );
            }
        }
    }

    /// Sequential dispatch (`seq` pragma): advance past constants,
    /// dispatch the first non-constant child, stop.
    fn dispatch_seq_next(&mut self, act_id: usize) {
        loop {
            let (prog, node, next, failed) = {
                let a = self.slab[act_id].as_ref().unwrap();
                let next = match a.mode {
                    Mode::Seq { next } => next,
                    _ => unreachable!("dispatch_seq_next on non-seq"),
                };
                (a.prog.clone(), a.node, next, a.failed.is_some())
            };
            let children = &prog.nodes[node].args;
            if failed || next >= children.len() {
                return;
            }
            let child = children[next];
            {
                let a = self.slab[act_id].as_mut().unwrap();
                a.mode = Mode::Seq { next: next + 1 };
            }
            if let NodeOp::Const(v) = &prog.nodes[child].op {
                let v = v.clone();
                let a = self.slab[act_id].as_mut().unwrap();
                a.slots[next] = Some(v);
                continue; // advance to the next child inline
            }
            {
                let a = self.slab[act_id].as_mut().unwrap();
                a.outstanding += 1;
            }
            let dest = prog.nodes[child].tile;
            self.send(
                dest,
                Packet::Request {
                    prog,
                    node: child,
                    ret: RetAddr::Tile { tile: self.ctx.id, act: act_id, slot: next },
                },
            );
            return;
        }
    }

    fn on_response(&mut self, act: usize, slot: usize, value: TaskResult) {
        {
            let a = self.slab[act]
                .as_mut()
                .unwrap_or_else(|| panic!("response for dead activation {act}"));
            a.outstanding -= 1;
            match value {
                Ok(v) => a.slots[slot] = Some(v),
                Err(e) => {
                    if a.failed.is_none() {
                        a.failed = Some(e);
                    }
                }
            }
        }
        // Seq: dispatch the next child (unless failed).
        if matches!(
            self.slab[act].as_ref().unwrap().mode,
            Mode::Seq { .. }
        ) {
            self.dispatch_seq_next(act);
        }
        self.try_complete(act);
    }

    /// If the activation has no outstanding children and nothing left
    /// to dispatch, produce its value, reply, and free the record.
    fn try_complete(&mut self, act_id: usize) {
        let ready = {
            let a = self.slab[act_id].as_ref().unwrap();
            if a.outstanding > 0 {
                false
            } else {
                match a.mode {
                    Mode::Seq { next } => {
                        a.failed.is_some() || next >= a.prog.nodes[a.node].args.len()
                    }
                    _ => true,
                }
            }
        };
        if !ready {
            return;
        }
        let a = self.slab[act_id].take().unwrap();
        self.free.push(act_id);
        let result: TaskResult = if let Some(e) = a.failed {
            Err(e)
        } else {
            match a.mode {
                Mode::Call { kernel, method } => {
                    let args: Vec<Value> =
                        a.slots.into_iter().map(|s| s.expect("slot unfilled")).collect();
                    self.fire_kernel(kernel, method, &args)
                }
                Mode::Par => Ok(Value::List(
                    a.slots.into_iter().map(|s| s.expect("slot unfilled")).collect(),
                )),
                Mode::Seq { .. } => Ok(a
                    .slots
                    .into_iter()
                    .flatten()
                    .last()
                    .unwrap_or(Value::Unit)),
            }
        };
        self.reply(a.ret, result);
    }
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("task kernel panicked: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("task kernel panicked: {s}")
    } else {
        "task kernel panicked".to_string()
    }
}
