//! Textual S-expression front-end for communication code (paper §II:
//! "a task is a list of bytecodes representing an S-expression, e.g.
//! `(S1 (S2 10) 20)`").
//!
//! Grammar:
//!
//! ```text
//! expr   := atom | list
//! list   := '(' head expr* ')'
//! head   := 'seq' | 'par' | kernel '.' method
//! atom   := integer | float | string
//! ```
//!
//! `(seq e1 e2 …)` and `(par e1 e2 …)` map to the `seq` pragma and the
//! default parallel evaluation respectively.

use super::program::Prog;
use super::value::Value;

/// Parse a single S-expression into a [`Prog`].
pub fn parse(src: &str) -> Result<Prog, String> {
    let mut toks = tokenize(src)?;
    toks.reverse(); // pop from the back
    let e = parse_expr(&mut toks)?;
    if !toks.is_empty() {
        return Err(format!("trailing tokens: {:?}", toks.last().unwrap()));
    }
    Ok(e)
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    LParen,
    RParen,
    Atom(String),
    Str(String),
}

fn tokenize(src: &str) -> Result<Vec<Tok>, String> {
    let mut out = Vec::new();
    let mut chars = src.chars().peekable();
    while let Some(&c) = chars.peek() {
        match c {
            '(' => {
                chars.next();
                out.push(Tok::LParen);
            }
            ')' => {
                chars.next();
                out.push(Tok::RParen);
            }
            ';' => {
                // comment to end of line
                for c in chars.by_ref() {
                    if c == '\n' {
                        break;
                    }
                }
            }
            '"' => {
                chars.next();
                let mut s = String::new();
                loop {
                    match chars.next() {
                        None => return Err("unterminated string".into()),
                        Some('"') => break,
                        Some(c) => s.push(c),
                    }
                }
                out.push(Tok::Str(s));
            }
            c if c.is_whitespace() => {
                chars.next();
            }
            _ => {
                let mut s = String::new();
                while let Some(&c) = chars.peek() {
                    if c.is_whitespace() || c == '(' || c == ')' || c == ';' {
                        break;
                    }
                    s.push(c);
                    chars.next();
                }
                out.push(Tok::Atom(s));
            }
        }
    }
    Ok(out)
}

fn parse_expr(toks: &mut Vec<Tok>) -> Result<Prog, String> {
    match toks.pop() {
        None => Err("unexpected end of input".into()),
        Some(Tok::RParen) => Err("unexpected ')'".into()),
        Some(Tok::Str(s)) => Ok(Prog::lit(Value::Str(s))),
        Some(Tok::Atom(a)) => atom_to_lit(&a),
        Some(Tok::LParen) => {
            let head = match toks.pop() {
                Some(Tok::Atom(a)) => a,
                other => {
                    return Err(format!("expected operator, got {other:?}"))
                }
            };
            let mut items = Vec::new();
            loop {
                match toks.last() {
                    None => return Err("missing ')'".into()),
                    Some(Tok::RParen) => {
                        toks.pop();
                        break;
                    }
                    _ => items.push(parse_expr(toks)?),
                }
            }
            match head.as_str() {
                "seq" => Ok(Prog::seq(items)),
                "par" => Ok(Prog::par(items)),
                _ => {
                    let (kernel, method) = head.split_once('.').ok_or(
                        format!("operator {head:?} is not kernel.method"),
                    )?;
                    Ok(Prog::call(kernel, method, items))
                }
            }
        }
    }
}

fn atom_to_lit(a: &str) -> Result<Prog, String> {
    if let Ok(i) = a.parse::<i64>() {
        return Ok(Prog::lit(i));
    }
    if let Ok(f) = a.parse::<f64>() {
        return Ok(Prog::lit(f));
    }
    Err(format!("bare symbol {a:?} outside operator position"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kernel::{ClosureKernel, Registry};
    use std::sync::Arc;

    fn reg() -> Registry {
        let mut r = Registry::new();
        r.register(Arc::new(
            ClosureKernel::new("S1").method("run", |a| {
                Value::Int(a.iter().map(|v| v.int()).sum())
            }),
        ));
        r
    }

    #[test]
    fn parses_paper_example_shape() {
        // (S1.run (S1.run 10) 20)
        let p = parse("(S1.run (S1.run 10) 20)").unwrap();
        let prog = p.compile(&reg(), 4).unwrap();
        assert_eq!(prog.task_count(), 2);
    }

    #[test]
    fn seq_par_forms() {
        let p = parse("(seq (par (S1.run 1) (S1.run 2)) (S1.run 3))").unwrap();
        assert!(matches!(p, Prog::Seq(_)));
    }

    #[test]
    fn comments_and_strings() {
        let p = parse("(S1.run \"hi\" 2) ; trailing comment\n").unwrap();
        match p {
            Prog::Call { args, .. } => assert_eq!(args.len(), 2),
            _ => panic!("expected call"),
        }
    }

    #[test]
    fn floats() {
        match parse("(S1.run 2.5)").unwrap() {
            Prog::Call { args, .. } => {
                assert!(matches!(args[0], Prog::Const(Value::Float(f)) if f == 2.5))
            }
            _ => panic!(),
        }
    }

    #[test]
    fn errors() {
        assert!(parse("(S1.run").is_err()); // missing )
        assert!(parse(")").is_err());
        assert!(parse("(noDot 1)").is_err());
        assert!(parse("sym").is_err());
        assert!(parse("(S1.run 1) extra").is_err());
        assert!(parse("(S1.run \"open").is_err());
    }
}
