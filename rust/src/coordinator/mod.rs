//! The GPRM runtime — the paper's core contribution (§II–III).
//!
//! GPRM structures a program as **task code** (kernel classes offering
//! methods) plus **communication code** (S-expressions compiled to
//! bytecode). Conceptually the machine is a set of *tiles*, one per
//! core, each a *task node* (task kernel + task manager) fed by a FIFO
//! of packets. The task manager is a reduction engine: it evaluates a
//! node's bytecode by **parallel dispatch** of request packets for its
//! argument subexpressions to their owning tiles, and fires the kernel
//! once all argument results have arrived (run-to-completion).
//!
//! Module map:
//!
//! * [`value`] — dynamic values flowing through packets.
//! * [`kernel`] — the `TaskKernel` trait (the `GPRM::Kernel` namespace
//!   analogue) and the kernel registry.
//! * [`program`] — the expression/"bytecode" representation, the
//!   builder API (with `seq` / `par` / compile-time `unroll`), and the
//!   task→tile assignment (the *task description*).
//! * [`sexpr`] — textual S-expression front-end.
//! * [`packet`] — request/result packets.
//! * [`tile`] — the tile event loop + activation records.
//! * [`pool`] — the pinned thread pool (one thread per core).
//! * [`runtime`] — [`runtime::GprmRuntime`], the public entry point.
//! * [`worksharing`] — `par_for` / `par_nested_for` and contiguous
//!   variants (paper §III, Listings 1–2).
//! * [`stats`] — per-tile counters used by benches and tests.

pub mod value;
pub mod kernel;
pub mod program;
pub mod sexpr;
pub mod packet;
pub mod stats;
pub mod tile;
pub mod pool;
pub mod runtime;
pub mod worksharing;

pub use kernel::{ClosureKernel, TaskKernel};
pub use program::{Prog, Program};
pub use runtime::{GprmConfig, GprmRuntime};
pub use value::Value;
pub use worksharing::{
    par_for, par_for_contiguous, par_nested_for, par_nested_for_contiguous,
};
