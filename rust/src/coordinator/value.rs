//! Dynamic values carried by GPRM packets (the "numeric constants and
//! results" of the paper's S-expressions, §II).

use std::fmt;

/// A value flowing through the reduction machine.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// No value (side-effecting task kernels return this).
    Unit,
    /// Signed integer (loop indices, block ids, concurrency level).
    Int(i64),
    /// Floating point scalar.
    Float(f64),
    /// String (mostly diagnostics).
    Str(String),
    /// A list — e.g. the collected results of a `par` node.
    List(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Integer accessor that panics with the kernel-author-facing
    /// message (kernels are internal code; a wrong arity/type is a
    /// programming error, matching GPRM's C++ static typing).
    pub fn int(&self) -> i64 {
        self.as_int().unwrap_or_else(|| panic!("expected Int, got {self:?}"))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Unit => write!(f, "()"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::List(l) => {
                write!(f, "(")?;
                for (i, v) in l.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}

impl From<usize> for Value {
    fn from(i: usize) -> Self {
        Value::Int(i as i64)
    }
}

impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::Float(f)
    }
}

impl From<()> for Value {
    fn from(_: ()) -> Self {
        Value::Unit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        assert_eq!(Value::Int(3).as_int(), Some(3));
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_float(), Some(2.5));
        assert_eq!(Value::Unit.as_int(), None);
    }

    #[test]
    fn display_sexpr_style() {
        let v = Value::List(vec![Value::Int(1), Value::Str("a".into())]);
        assert_eq!(v.to_string(), "(1 a)");
    }

    #[test]
    #[should_panic(expected = "expected Int")]
    fn int_panics_on_type_error() {
        Value::Unit.int();
    }
}
