//! [`GprmRuntime`] — the public entry point to the GPRM machine.
//!
//! Construction spawns the tile pool (one thread per core, paper §II);
//! [`GprmRuntime::run`] evaluates communication code; and
//! [`GprmRuntime::par_invoke`] is the hybrid worksharing-tasking fast
//! path: it spawns exactly *CL* tasks, "each of which with their own
//! indices", which the caller combines with the [`super::worksharing`]
//! constructs (paper §II–III).

use super::kernel::Registry;
use super::packet::{Packet, RetAddr, TaskResult};
use super::pool::Pool;
use super::program::{NativeFn, Prog, Program};
use super::stats::StatsSnapshot;
use super::value::Value;
use std::sync::{mpsc, Arc};

/// Runtime configuration.
#[derive(Clone, Debug)]
pub struct GprmConfig {
    /// Number of tiles = threads = "cores". The paper's default on the
    /// TILEPro64 is 63 (one tile reserved for PCI).
    pub n_tiles: usize,
    /// Pin tile threads to host cores (paper §VII-A).
    pub pin: bool,
}

impl Default for GprmConfig {
    fn default() -> Self {
        Self { n_tiles: 63, pin: false }
    }
}

impl GprmConfig {
    pub fn with_tiles(n_tiles: usize) -> Self {
        Self { n_tiles, ..Self::default() }
    }
}

/// The Glasgow Parallel Reduction Machine.
pub struct GprmRuntime {
    pool: Pool,
    registry: Registry,
    config: GprmConfig,
}

impl GprmRuntime {
    /// Spawn the machine: `config.n_tiles` tile threads hosting
    /// `registry`'s task kernels.
    pub fn new(config: GprmConfig, registry: Registry) -> Self {
        let pool = Pool::new(config.n_tiles, registry.clone(), config.pin);
        Self { pool, registry, config }
    }

    /// Convenience: default config, no kernels (native tasks only).
    pub fn with_tiles(n_tiles: usize) -> Self {
        Self::new(GprmConfig::with_tiles(n_tiles), Registry::new())
    }

    pub fn n_tiles(&self) -> usize {
        self.config.n_tiles
    }

    /// The concurrency level — "normally … the same as the number of
    /// threads, which is itself … the number of cores in GPRM" (§II).
    pub fn concurrency_level(&self) -> usize {
        self.config.n_tiles
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Compile communication code against this machine.
    pub fn compile(&self, prog: &Prog) -> Result<Arc<Program>, String> {
        Ok(Arc::new(prog.compile(&self.registry, self.config.n_tiles)?))
    }

    /// Compile and evaluate communication code; blocks until the root
    /// task completes. Errors carry the panic message of a failed task
    /// kernel.
    pub fn run(&self, prog: &Prog) -> TaskResult {
        let compiled = self.compile(prog).map_err(|e| format!("compile: {e}"))?;
        self.run_compiled(&compiled)
    }

    /// Evaluate an already-compiled program (hot loops compile once).
    pub fn run_compiled(&self, prog: &Arc<Program>) -> TaskResult {
        let (tx, rx) = mpsc::channel();
        let root_tile = prog.nodes[prog.root].tile;
        self.pool.send(
            root_tile,
            Packet::Request {
                prog: prog.clone(),
                node: prog.root,
                ret: RetAddr::Root(tx),
            },
        );
        rx.recv().map_err(|_| "machine shut down".to_string())?
    }

    /// The hybrid worksharing-tasking entry point: spawn exactly `cl`
    /// tasks, task `ind` initially hosted on tile `ind % n_tiles`, each
    /// running `f(ind)`; block until all complete.
    ///
    /// This is GPRM's remedy for fine-grained tasks (§II): "instead of
    /// creating tasks in a loop … one can create as many tasks as the
    /// concurrency level, each of which with their own indices",
    /// combined with `par_for`-style constructs inside `f`.
    ///
    /// Panics inside `f` are reported as `Err`.
    pub fn par_invoke<'env, F>(&self, cl: usize, f: F) -> Result<(), String>
    where
        F: Fn(usize) + Send + Sync + 'env,
    {
        assert!(cl > 0, "concurrency level must be positive");
        // SAFETY (lifetime erasure): `run` blocks until the root `par`
        // node's result arrives, and a `par` activation replies only
        // after *all* children have responded — including failed ones
        // (see tile.rs). Hence no task can run `f` after this frame
        // returns, and extending the closure's lifetime to 'static for
        // the duration of the blocking call is sound.
        let f_arc: Arc<dyn Fn(usize) -> Value + Send + Sync + 'env> =
            Arc::new(move |i| {
                f(i);
                Value::Unit
            });
        let f_static: NativeFn = unsafe {
            std::mem::transmute::<
                Arc<dyn Fn(usize) -> Value + Send + Sync + 'env>,
                Arc<dyn Fn(usize) -> Value + Send + Sync + 'static>,
            >(f_arc)
        };
        let prog = Prog::par(
            (0..cl)
                .map(|i| Prog::native(i, f_static.clone()).on_tile(i))
                .collect(),
        );
        self.run(&prog).map(|_| ())
    }

    /// Per-tile statistics snapshots.
    pub fn stats(&self) -> Vec<StatsSnapshot> {
        self.pool.stats()
    }

    /// Aggregate statistics.
    pub fn stats_total(&self) -> StatsSnapshot {
        self.pool.stats_total()
    }

    /// Stop all tiles and join threads.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kernel::ClosureKernel;
    use crate::coordinator::sexpr;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn arith_runtime(n_tiles: usize) -> GprmRuntime {
        let mut r = Registry::new();
        r.register(Arc::new(
            ClosureKernel::new("a")
                .method("add", |v| Value::Int(v.iter().map(|x| x.int()).sum()))
                .method("mul", |v| {
                    Value::Int(v.iter().map(|x| x.int()).product())
                })
                .method("boom", |_| panic!("deliberate failure")),
        ));
        GprmRuntime::new(GprmConfig { n_tiles, pin: false }, r)
    }

    #[test]
    fn evaluates_nested_sexpr() {
        let rt = arith_runtime(4);
        // (a.add (a.mul 6 7) 100) = 142
        let p = sexpr::parse("(a.add (a.mul 6 7) 100)").unwrap();
        assert_eq!(rt.run(&p).unwrap(), Value::Int(142));
        rt.shutdown();
    }

    #[test]
    fn parallel_arguments_all_evaluate() {
        let rt = arith_runtime(8);
        // add of 20 parallel muls
        let args: Vec<Prog> = (1..=20)
            .map(|i| Prog::call("a", "mul", vec![Prog::lit(i as i64), Prog::lit(2i64)]))
            .collect();
        let p = Prog::call("a", "add", args);
        assert_eq!(rt.run(&p).unwrap(), Value::Int(2 * (1..=21).sum::<i64>() - 42));
        // simpler: 2*(1+..+20) = 420
        rt.shutdown();
    }

    #[test]
    fn seq_returns_last() {
        let rt = arith_runtime(2);
        let p = sexpr::parse("(seq (a.add 1 2) (a.add 3 4))").unwrap();
        assert_eq!(rt.run(&p).unwrap(), Value::Int(7));
        rt.shutdown();
    }

    #[test]
    fn par_returns_list() {
        let rt = arith_runtime(2);
        let p = sexpr::parse("(par (a.add 1 2) (a.mul 3 4))").unwrap();
        assert_eq!(
            rt.run(&p).unwrap(),
            Value::List(vec![Value::Int(3), Value::Int(12)])
        );
        rt.shutdown();
    }

    #[test]
    fn kernel_panic_propagates() {
        let rt = arith_runtime(3);
        let p = sexpr::parse("(a.add (a.boom) 1)").unwrap();
        let e = rt.run(&p).unwrap_err();
        assert!(e.contains("deliberate failure"), "{e}");
        // Machine still usable afterwards.
        let p2 = sexpr::parse("(a.add 1 1)").unwrap();
        assert_eq!(rt.run(&p2).unwrap(), Value::Int(2));
        rt.shutdown();
    }

    #[test]
    fn par_invoke_runs_all_indices() {
        let rt = GprmRuntime::with_tiles(7);
        let hits: Vec<AtomicUsize> = (0..7).map(|_| AtomicUsize::new(0)).collect();
        rt.par_invoke(7, |ind| {
            hits[ind].fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
        rt.shutdown();
    }

    #[test]
    fn par_invoke_borrows_stack_data() {
        let rt = GprmRuntime::with_tiles(4);
        let data: Vec<u64> = (0..100).collect();
        let sums = std::sync::Mutex::new(vec![0u64; 4]);
        rt.par_invoke(4, |ind| {
            let mut s = 0;
            let mut i = ind;
            while i < data.len() {
                s += data[i];
                i += 4;
            }
            sums.lock().unwrap()[ind] = s;
        })
        .unwrap();
        let total: u64 = sums.lock().unwrap().iter().sum();
        assert_eq!(total, (0..100).sum::<u64>());
        rt.shutdown();
    }

    #[test]
    fn par_invoke_propagates_panic() {
        let rt = GprmRuntime::with_tiles(4);
        let e = rt
            .par_invoke(4, |ind| {
                if ind == 2 {
                    panic!("task 2 died");
                }
            })
            .unwrap_err();
        assert!(e.contains("task 2 died"), "{e}");
        rt.shutdown();
    }

    #[test]
    fn cl_larger_than_tiles_wraps() {
        let rt = GprmRuntime::with_tiles(3);
        let hits = AtomicUsize::new(0);
        rt.par_invoke(9, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 9);
        rt.shutdown();
    }

    #[test]
    fn run_compiled_reuse() {
        let rt = arith_runtime(2);
        let p = sexpr::parse("(a.add 20 22)").unwrap();
        let compiled = rt.compile(&p).unwrap();
        for _ in 0..10 {
            assert_eq!(rt.run_compiled(&compiled).unwrap(), Value::Int(42));
        }
        // 10 runs × 1 task each.
        assert_eq!(rt.stats_total().tasks, 10);
        rt.shutdown();
    }

    #[test]
    fn unroll_pragma_spawns_tasks() {
        let rt = arith_runtime(8);
        // #pragma gprm unroll over n: add(mul(n, n)) for n in 1..=5
        let p = Prog::call(
            "a",
            "add",
            (1..=5i64)
                .map(|n| Prog::call("a", "mul", vec![Prog::lit(n), Prog::lit(n)]))
                .collect(),
        );
        assert_eq!(rt.run(&p).unwrap(), Value::Int(55));
        rt.shutdown();
    }
}
