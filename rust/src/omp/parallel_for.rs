//! `omp for` loop schedules (paper §V compares *static* — the default
//! — and *dynamic with chunk_size 1* against GPRM's `par_for`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Loop schedule selector, mirroring `schedule(...)` clauses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// `schedule(static)` — one contiguous chunk per thread.
    Static,
    /// `schedule(static, chunk)` — chunks dealt round-robin.
    StaticChunk(usize),
    /// `schedule(dynamic, chunk)` — first-come first-served chunks.
    Dynamic(usize),
    /// `schedule(guided, min_chunk)` — exponentially shrinking chunks.
    Guided(usize),
}

/// The contiguous iteration range thread `tid` owns under
/// `schedule(static)`: same partitioning rule as GPRM's *contiguous*
/// method (`m/n` each, remainder to the foremost threads), which is
/// what libgomp does.
pub fn static_range(
    start: usize,
    end: usize,
    tid: usize,
    nthreads: usize,
) -> (usize, usize) {
    assert!(nthreads > 0 && tid < nthreads);
    let m = end.saturating_sub(start);
    let base = m / nthreads;
    let rem = m % nthreads;
    let lo = start + tid * base + tid.min(rem);
    let len = base + usize::from(tid < rem);
    (lo, lo + len)
}

/// Iterate the chunks thread `tid` owns under `schedule(static,
/// chunk)`: chunk `c` belongs to thread `c % nthreads`.
pub fn static_chunked(
    start: usize,
    end: usize,
    tid: usize,
    nthreads: usize,
    chunk: usize,
) -> impl Iterator<Item = (usize, usize)> {
    assert!(nthreads > 0 && tid < nthreads && chunk > 0);
    let first = start + tid * chunk;
    (0..)
        .map(move |round| first + round * nthreads * chunk)
        .take_while(move |&lo| lo < end)
        .map(move |lo| (lo, (lo + chunk).min(end)))
}

/// `schedule(dynamic, chunk)`: a shared atomic cursor; every
/// `next_chunk` claims the next `chunk` iterations. One instance is
/// shared by the whole team for one loop.
pub struct DynamicSched {
    next: AtomicUsize,
    end: usize,
    chunk: usize,
}

impl DynamicSched {
    pub fn new(start: usize, end: usize, chunk: usize) -> Self {
        assert!(chunk > 0);
        Self { next: AtomicUsize::new(start), end, chunk }
    }

    /// Claim the next chunk, or `None` when the loop is exhausted.
    pub fn next_chunk(&self) -> Option<(usize, usize)> {
        let lo = self.next.fetch_add(self.chunk, Ordering::Relaxed);
        if lo >= self.end {
            None
        } else {
            Some((lo, (lo + self.chunk).min(self.end)))
        }
    }

    /// Drain the schedule from one thread: `work(i)` per iteration.
    pub fn drain(&self, mut work: impl FnMut(usize)) {
        while let Some((lo, hi)) = self.next_chunk() {
            for i in lo..hi {
                work(i);
            }
        }
    }
}

/// `schedule(guided, min_chunk)`: chunk = remaining / nthreads,
/// floored at `min_chunk`.
pub struct GuidedSched {
    next: AtomicUsize,
    end: usize,
    nthreads: usize,
    min_chunk: usize,
}

impl GuidedSched {
    pub fn new(start: usize, end: usize, nthreads: usize, min_chunk: usize) -> Self {
        assert!(nthreads > 0 && min_chunk > 0);
        Self { next: AtomicUsize::new(start), end, nthreads, min_chunk }
    }

    pub fn next_chunk(&self) -> Option<(usize, usize)> {
        loop {
            let lo = self.next.load(Ordering::Relaxed);
            if lo >= self.end {
                return None;
            }
            let remaining = self.end - lo;
            let size = (remaining / self.nthreads).max(self.min_chunk).min(remaining);
            if self
                .next
                .compare_exchange_weak(
                    lo,
                    lo + size,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                return Some((lo, lo + size));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn static_range_partitions() {
        // 10 iters over 4 threads → 3,3,2,2 contiguous.
        let parts: Vec<(usize, usize)> =
            (0..4).map(|t| static_range(0, 10, t, 4)).collect();
        assert_eq!(parts, vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        // Full disjoint cover for assorted shapes.
        for &(s, e, n) in &[(0, 100, 7), (5, 6, 3), (0, 0, 4), (2, 65, 63)] {
            let mut seen = BTreeSet::new();
            for t in 0..n {
                let (lo, hi) = static_range(s, e, t, n);
                for i in lo..hi {
                    assert!(seen.insert(i));
                }
            }
            assert_eq!(seen.len(), e - s);
        }
    }

    #[test]
    fn static_chunked_round_robin() {
        // chunk=2, 3 threads, 14 iters: t0 gets [0,2) [6,8) [12,14).
        let t0: Vec<_> = static_chunked(0, 14, 0, 3, 2).collect();
        assert_eq!(t0, vec![(0, 2), (6, 8), (12, 14)]);
        let mut seen = BTreeSet::new();
        for t in 0..3 {
            for (lo, hi) in static_chunked(0, 14, t, 3, 2) {
                for i in lo..hi {
                    assert!(seen.insert(i));
                }
            }
        }
        assert_eq!(seen.len(), 14);
    }

    #[test]
    fn dynamic_covers_exactly_once() {
        let s = DynamicSched::new(3, 40, 4);
        let mut seen = BTreeSet::new();
        while let Some((lo, hi)) = s.next_chunk() {
            for i in lo..hi {
                assert!(seen.insert(i));
            }
        }
        assert_eq!(seen, (3..40).collect());
        assert_eq!(s.next_chunk(), None);
    }

    #[test]
    fn dynamic_concurrent_cover() {
        let s = std::sync::Arc::new(DynamicSched::new(0, 1000, 1));
        let claimed = std::sync::Arc::new(
            (0..1000).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>(),
        );
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            let c = claimed.clone();
            handles.push(std::thread::spawn(move || {
                while let Some((lo, hi)) = s.next_chunk() {
                    for i in lo..hi {
                        c[i].fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for (i, c) in claimed.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "iter {i}");
        }
    }

    #[test]
    fn guided_shrinks_and_covers() {
        let s = GuidedSched::new(0, 100, 4, 2);
        let mut chunks = Vec::new();
        let mut seen = BTreeSet::new();
        while let Some((lo, hi)) = s.next_chunk() {
            chunks.push(hi - lo);
            for i in lo..hi {
                assert!(seen.insert(i));
            }
        }
        assert_eq!(seen.len(), 100);
        assert_eq!(chunks[0], 25); // 100/4
        // Non-increasing until the floor.
        for w in chunks.windows(2) {
            assert!(w[0] >= w[1] || w[1] == 2);
        }
        assert!(*chunks.last().unwrap() >= 1);
    }
}
