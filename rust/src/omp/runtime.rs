//! The OpenMP-style team runtime: persistent thread team, parallel
//! regions, `single`, `task` / `taskwait`, and barriers — all built
//! around a **central mutex-protected task queue** (the libgomp
//! design the paper benchmarked against).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type RegionFn = Box<dyn Fn(&TeamCtx) + Send + Sync>;
type TaskFn = Box<dyn FnOnce(&TeamCtx) + Send>;

/// Tasks spawned by one generating task (children awaited by
/// `taskwait`).
pub struct TaskGroup {
    remaining: AtomicUsize,
}

struct TaskItem {
    f: TaskFn,
    group: Arc<TaskGroup>,
}

/// Counters for one parallel region.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegionStats {
    /// Tasks pushed to the central queue.
    pub tasks_spawned: u64,
    /// Tasks executed (== spawned when the region exits cleanly).
    pub tasks_executed: u64,
    /// Largest queue length observed at spawn time — the paper's
    /// "single thread explores the whole matrix and creates relatively
    /// small tasks" shows up here.
    pub peak_queue: u64,
}

struct JobState {
    queue: VecDeque<TaskItem>,
    running_tasks: usize,
    arrived: usize,
    complete: bool,
    barrier_gen: u64,
    barrier_count: usize,
}

struct Job {
    f: RegionFn,
    n_threads: usize,
    st: Mutex<JobState>,
    cv: Condvar,
    single_claim: AtomicUsize,
    tasks_spawned: AtomicU64,
    tasks_executed: AtomicU64,
    peak_queue: AtomicU64,
    panicked: Mutex<Option<String>>,
}

struct Ctrl {
    generation: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    ctrl_cv: Condvar,
}

/// A persistent OpenMP-like thread team.
pub struct OmpRuntime {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    n_threads: usize,
}

/// Per-thread view of a running parallel region (the `omp_get_*`
/// surface plus task constructs).
pub struct TeamCtx<'j> {
    tid: usize,
    job: &'j Arc<Job>,
    /// Children of the currently-executing task are registered here.
    group: std::cell::RefCell<Arc<TaskGroup>>,
    /// Singles encountered so far by this thread (claim index).
    single_seen: std::cell::Cell<usize>,
}

impl OmpRuntime {
    /// Spawn a team of `n_threads` workers (pinned never — the paper's
    /// OpenMP baseline runs unpinned by default; see §VII-A).
    pub fn new(n_threads: usize) -> Self {
        assert!(n_threads > 0);
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl { generation: 0, job: None, shutdown: false }),
            ctrl_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(n_threads);
        for tid in 0..n_threads {
            let sh = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("omp-worker-{tid}"))
                    .spawn(move || worker_loop(tid, sh))
                    .expect("spawn omp worker"),
            );
        }
        Self { shared, handles, n_threads }
    }

    pub fn num_threads(&self) -> usize {
        self.n_threads
    }

    /// Run a parallel region: `f` executes on every team thread;
    /// returns when all threads and all tasks have finished (the
    /// implicit barrier at the end of an OpenMP parallel region).
    ///
    /// A panic in `f` or in any task is caught and returned as `Err`.
    pub fn parallel<'env, F>(&self, f: F) -> Result<RegionStats, String>
    where
        F: Fn(&TeamCtx) + Sync + 'env,
    {
        // SAFETY (lifetime erasure): this function blocks until the
        // region is complete — every worker has finished `f` and the
        // task queue is fully drained — so no code can touch `f` or
        // anything it borrows after we return.
        let boxed: Box<dyn Fn(&TeamCtx) + Sync + 'env> = Box::new(f);
        let boxed: RegionFn = unsafe {
            std::mem::transmute::<
                Box<dyn Fn(&TeamCtx) + Sync + 'env>,
                Box<dyn Fn(&TeamCtx) + Send + Sync + 'static>,
            >(boxed)
        };
        let job = Arc::new(Job {
            f: boxed,
            n_threads: self.n_threads,
            st: Mutex::new(JobState {
                queue: VecDeque::new(),
                running_tasks: 0,
                arrived: 0,
                complete: false,
                barrier_gen: 0,
                barrier_count: 0,
            }),
            cv: Condvar::new(),
            single_claim: AtomicUsize::new(0),
            tasks_spawned: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            peak_queue: AtomicU64::new(0),
            panicked: Mutex::new(None),
        });
        {
            let mut c = self.shared.ctrl.lock().unwrap();
            c.generation += 1;
            c.job = Some(job.clone());
            self.shared.ctrl_cv.notify_all();
        }
        // Wait for completion.
        {
            let mut st = job.st.lock().unwrap();
            while !st.complete {
                st = job.cv.wait(st).unwrap();
            }
        }
        let panicked = job.panicked.lock().unwrap().take();
        match panicked {
            Some(msg) => Err(msg),
            None => Ok(RegionStats {
                tasks_spawned: job.tasks_spawned.load(Ordering::Relaxed),
                tasks_executed: job.tasks_executed.load(Ordering::Relaxed),
                peak_queue: job.peak_queue.load(Ordering::Relaxed),
            }),
        }
    }

    /// Stop and join all workers.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        {
            let mut c = self.shared.ctrl.lock().unwrap();
            c.shutdown = true;
            self.shared.ctrl_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for OmpRuntime {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.stop();
        }
    }
}

fn worker_loop(tid: usize, shared: Arc<Shared>) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut c = shared.ctrl.lock().unwrap();
            loop {
                if c.shutdown {
                    return;
                }
                if c.generation != last_gen {
                    last_gen = c.generation;
                    break c.job.clone().expect("generation without job");
                }
                c = shared.ctrl_cv.wait(c).unwrap();
            }
        };
        run_region(tid, &job);
    }
}

fn record_panic(job: &Job, e: Box<dyn std::any::Any + Send>) {
    let msg = if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    };
    let mut p = job.panicked.lock().unwrap();
    if p.is_none() {
        *p = Some(msg);
    }
}

fn run_region(tid: usize, job: &Arc<Job>) {
    let ctx = TeamCtx {
        tid,
        job,
        group: std::cell::RefCell::new(Arc::new(TaskGroup {
            remaining: AtomicUsize::new(0),
        })),
        single_seen: std::cell::Cell::new(0),
    };
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        (job.f)(&ctx)
    }));
    if let Err(e) = r {
        record_panic(job, e);
    }
    // Implicit end-of-region barrier, draining tasks while waiting.
    let mut st = job.st.lock().unwrap();
    st.arrived += 1;
    job.cv.notify_all();
    loop {
        if let Some(item) = st.queue.pop_front() {
            st.running_tasks += 1;
            drop(st);
            exec_task(tid, job, item);
            st = job.st.lock().unwrap();
            st.running_tasks -= 1;
            job.cv.notify_all();
            continue;
        }
        if st.arrived == job.n_threads && st.running_tasks == 0 {
            if !st.complete {
                st.complete = true;
            }
            job.cv.notify_all();
            return;
        }
        st = job.cv.wait(st).unwrap();
    }
}

/// Execute one task item: fresh child-group context, panic isolation,
/// parent-group decrement (under the job lock so waiters can't miss
/// the wakeup).
fn exec_task(tid: usize, job: &Arc<Job>, item: TaskItem) {
    let ctx = TeamCtx {
        tid,
        job,
        group: std::cell::RefCell::new(Arc::new(TaskGroup {
            remaining: AtomicUsize::new(0),
        })),
        single_seen: std::cell::Cell::new(usize::MAX / 2), // tasks see no singles
    };
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        (item.f)(&ctx)
    }));
    if let Err(e) = r {
        record_panic(job, e);
    }
    job.tasks_executed.fetch_add(1, Ordering::Relaxed);
    // Decrement under the lock, then notify taskwaiters.
    let _st = job.st.lock().unwrap();
    item.group.remaining.fetch_sub(1, Ordering::Relaxed);
    job.cv.notify_all();
}

impl<'j> TeamCtx<'j> {
    /// `omp_get_thread_num()`.
    pub fn thread_num(&self) -> usize {
        self.tid
    }

    /// `omp_get_num_threads()`.
    pub fn num_threads(&self) -> usize {
        self.job.n_threads
    }

    /// `#pragma omp single nowait`: the first thread to arrive runs
    /// `f`; returns whether this thread was it.
    pub fn single(&self, f: impl FnOnce()) -> bool {
        let idx = self.single_seen.get();
        self.single_seen.set(idx + 1);
        if self
            .job
            .single_claim
            .compare_exchange(idx, idx + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            f();
            true
        } else {
            false
        }
    }

    /// `#pragma omp task`: push a deferred task to the central queue.
    /// The task becomes a child of the current task for `taskwait`.
    pub fn task<'t>(&self, f: impl FnOnce(&TeamCtx) + Send + 't) {
        // SAFETY (lifetime erasure): tasks are guaranteed to finish
        // before the enclosing `parallel` returns (end-of-region
        // barrier drains the queue), and `parallel`'s caller keeps all
        // borrowed data alive until then.
        let boxed: Box<dyn FnOnce(&TeamCtx) + Send + 't> = Box::new(f);
        let boxed: TaskFn = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce(&TeamCtx) + Send + 't>,
                Box<dyn FnOnce(&TeamCtx) + Send + 'static>,
            >(boxed)
        };
        let group = self.group.borrow().clone();
        group.remaining.fetch_add(1, Ordering::Relaxed);
        let mut st = self.job.st.lock().unwrap();
        st.queue.push_back(TaskItem { f: boxed, group });
        let qlen = st.queue.len() as u64;
        self.job.peak_queue.fetch_max(qlen, Ordering::Relaxed);
        self.job.tasks_spawned.fetch_add(1, Ordering::Relaxed);
        self.job.cv.notify_one();
    }

    /// `#pragma omp task if(cond)`: `cond == false` gives an
    /// *undeferred* task — executed immediately, inline (the standard
    /// cutoff mechanism, paper §V).
    pub fn task_if<'t>(&self, cond: bool, f: impl FnOnce(&TeamCtx) + Send + 't) {
        if cond {
            self.task(f);
        } else {
            f(self);
        }
    }

    /// `#pragma omp taskwait`: wait for all children of the current
    /// task, executing queued tasks meanwhile (a task scheduling
    /// point).
    pub fn taskwait(&self) {
        let group = self.group.borrow().clone();
        let mut st = self.job.st.lock().unwrap();
        loop {
            if group.remaining.load(Ordering::Acquire) == 0 {
                return;
            }
            if let Some(item) = st.queue.pop_front() {
                st.running_tasks += 1;
                drop(st);
                exec_task(self.tid, self.job, item);
                st = self.job.st.lock().unwrap();
                st.running_tasks -= 1;
                self.job.cv.notify_all();
                continue;
            }
            st = self.job.cv.wait(st).unwrap();
        }
    }

    /// Team barrier (also a task scheduling point).
    pub fn barrier(&self) {
        let mut st = self.job.st.lock().unwrap();
        let gen = st.barrier_gen;
        st.barrier_count += 1;
        if st.barrier_count == self.job.n_threads {
            st.barrier_count = 0;
            st.barrier_gen += 1;
            self.job.cv.notify_all();
            return;
        }
        loop {
            if st.barrier_gen != gen {
                return;
            }
            if let Some(item) = st.queue.pop_front() {
                st.running_tasks += 1;
                drop(st);
                exec_task(self.tid, self.job, item);
                st = self.job.st.lock().unwrap();
                st.running_tasks -= 1;
                self.job.cv.notify_all();
                continue;
            }
            st = self.job.cv.wait(st).unwrap();
        }
    }

    /// `#pragma omp for schedule(static)`: this thread's contiguous
    /// share of `[start, end)`. No implied barrier (`nowait`); call
    /// [`Self::barrier`] for the default behaviour.
    pub fn for_static(&self, start: usize, end: usize, mut work: impl FnMut(usize)) {
        let (lo, hi) = super::parallel_for::static_range(
            start,
            end,
            self.tid,
            self.job.n_threads,
        );
        for i in lo..hi {
            work(i);
        }
    }

    /// `#pragma omp for schedule(dynamic, chunk)` over a shared
    /// schedule object.
    pub fn for_dynamic(
        &self,
        sched: &super::parallel_for::DynamicSched,
        mut work: impl FnMut(usize),
    ) {
        while let Some((lo, hi)) = sched.next_chunk() {
            for i in lo..hi {
                work(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::parallel_for::DynamicSched;
    use std::sync::atomic::AtomicU64 as TestAtomicU64;

    #[test]
    fn region_runs_on_all_threads() {
        let rt = OmpRuntime::new(4);
        let hits: Vec<TestAtomicU64> =
            (0..4).map(|_| TestAtomicU64::new(0)).collect();
        rt.parallel(|ctx| {
            hits[ctx.thread_num()].fetch_add(1, Ordering::Relaxed);
            assert_eq!(ctx.num_threads(), 4);
        })
        .unwrap();
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
        rt.shutdown();
    }

    #[test]
    fn single_executes_once_per_region() {
        let rt = OmpRuntime::new(6);
        let count = TestAtomicU64::new(0);
        for _ in 0..3 {
            rt.parallel(|ctx| {
                ctx.single(|| {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            })
            .unwrap();
        }
        assert_eq!(count.load(Ordering::Relaxed), 3);
        rt.shutdown();
    }

    #[test]
    fn tasks_all_execute() {
        let rt = OmpRuntime::new(4);
        let sum = TestAtomicU64::new(0);
        let sum_ref = &sum;
        let stats = rt
            .parallel(|ctx| {
                ctx.single(|| {
                    for i in 1..=100u64 {
                        ctx.task(move |_| {
                            sum_ref.fetch_add(i, Ordering::Relaxed);
                        });
                    }
                });
            })
            .unwrap();
        assert_eq!(sum.load(Ordering::Relaxed), 5050);
        assert_eq!(stats.tasks_spawned, 100);
        assert_eq!(stats.tasks_executed, 100);
        assert!(stats.peak_queue >= 1);
        rt.shutdown();
    }

    #[test]
    fn taskwait_orders_phases() {
        // Phase 1 tasks must all complete before phase 2 begins —
        // exactly the SparseLU fwd/bdiv → bmod dependency.
        let rt = OmpRuntime::new(8);
        let phase1 = TestAtomicU64::new(0);
        let violations = TestAtomicU64::new(0);
        rt.parallel(|ctx| {
            ctx.single(|| {
                for _ in 0..50 {
                    ctx.task(|_| {
                        phase1.fetch_add(1, Ordering::SeqCst);
                    });
                }
                ctx.taskwait();
                if phase1.load(Ordering::SeqCst) != 50 {
                    violations.fetch_add(1, Ordering::SeqCst);
                }
                for _ in 0..50 {
                    ctx.task(|_| {
                        if phase1.load(Ordering::SeqCst) != 50 {
                            violations.fetch_add(1, Ordering::SeqCst);
                        }
                    });
                }
            });
        })
        .unwrap();
        assert_eq!(violations.load(Ordering::SeqCst), 0);
        rt.shutdown();
    }

    #[test]
    fn nested_tasks_and_taskwait() {
        let rt = OmpRuntime::new(4);
        let leaf = TestAtomicU64::new(0);
        rt.parallel(|ctx| {
            ctx.single(|| {
                for _ in 0..5 {
                    ctx.task(|tctx| {
                        for _ in 0..4 {
                            tctx.task(|_| {
                                leaf.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                        tctx.taskwait(); // waits only own children
                    });
                }
            });
        })
        .unwrap();
        assert_eq!(leaf.load(Ordering::Relaxed), 20);
        rt.shutdown();
    }

    #[test]
    fn task_if_false_is_inline() {
        let rt = OmpRuntime::new(2);
        let stats = rt
            .parallel(|ctx| {
                ctx.single(|| {
                    let marker = std::sync::atomic::AtomicBool::new(false);
                    ctx.task_if(false, |_| {
                        marker.store(true, Ordering::Relaxed)
                    });
                    assert!(
                        marker.load(Ordering::Relaxed),
                        "undeferred task must run inline"
                    );
                });
            })
            .unwrap();
        assert_eq!(stats.tasks_spawned, 0);
        rt.shutdown();
    }

    #[test]
    fn for_static_covers() {
        let rt = OmpRuntime::new(3);
        let hits: Vec<TestAtomicU64> =
            (0..100).map(|_| TestAtomicU64::new(0)).collect();
        rt.parallel(|ctx| {
            ctx.for_static(0, 100, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        rt.shutdown();
    }

    #[test]
    fn for_dynamic_covers() {
        let rt = OmpRuntime::new(5);
        let hits: Vec<TestAtomicU64> =
            (0..97).map(|_| TestAtomicU64::new(0)).collect();
        let sched = DynamicSched::new(0, 97, 1);
        rt.parallel(|ctx| {
            ctx.for_dynamic(&sched, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        rt.shutdown();
    }

    #[test]
    fn barrier_synchronises() {
        let rt = OmpRuntime::new(4);
        let before = TestAtomicU64::new(0);
        let errors = TestAtomicU64::new(0);
        rt.parallel(|ctx| {
            before.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            if before.load(Ordering::SeqCst) != 4 {
                errors.fetch_add(1, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert_eq!(errors.load(Ordering::SeqCst), 0);
        rt.shutdown();
    }

    #[test]
    fn panic_in_task_propagates() {
        let rt = OmpRuntime::new(3);
        let e = rt
            .parallel(|ctx| {
                ctx.single(|| {
                    ctx.task(|_| panic!("omp task exploded"));
                });
            })
            .unwrap_err();
        assert!(e.contains("omp task exploded"), "{e}");
        // Runtime survives for the next region.
        rt.parallel(|_| {}).unwrap();
        rt.shutdown();
    }

    #[test]
    fn borrows_environment() {
        let rt = OmpRuntime::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let total = TestAtomicU64::new(0);
        rt.parallel(|ctx| {
            ctx.for_static(0, data.len(), |i| {
                total.fetch_add(data[i], Ordering::Relaxed);
            });
        })
        .unwrap();
        assert_eq!(total.load(Ordering::Relaxed), (0..1000).sum::<u64>());
        rt.shutdown();
    }
}
