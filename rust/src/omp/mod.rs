//! An OpenMP-3.0-style tasking/worksharing runtime — the paper's
//! baseline (§V–VI).
//!
//! Modeled on the runtime the paper compared against (GCC 4.4.3
//! libgomp on the TILEPro64): a persistent thread team, a **central
//! task queue protected by one mutex**, breadth-first task execution
//! with scheduling points at `task`/`taskwait`/barriers, and
//! `omp for` worksharing with *static* and *dynamic(chunk)* schedules.
//!
//! The centralised queue is deliberate fidelity, not laziness: the
//! paper's measured phenomena — task-creation overhead on a single
//! producer and queue contention growing with thread count and task
//! granularity — are properties of exactly this design.
//!
//! * [`runtime`] — [`runtime::OmpRuntime`] (the team), parallel
//!   regions, `single`, `task`, `taskwait`, barriers.
//! * [`parallel_for`] — static / dynamic / guided loop schedules.

pub mod parallel_for;
pub mod runtime;

pub use parallel_for::{static_range, DynamicSched, GuidedSched, Schedule};
pub use runtime::{OmpRuntime, RegionStats, TeamCtx};
