//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the rust request
//! path (Python is never involved at runtime).
//!
//! * [`manifest`] — `artifacts/manifest.json` description of every
//!   compiled op.
//! * [`client`] — [`client::BlockEngine`]: PJRT CPU client + compiled
//!   executable cache + typed block-op entry points.
//! * [`service`] — [`service::EngineService`]: a dedicated executor
//!   thread owning the engine, callable from any tile thread through a
//!   cloneable handle (the `xla` crate's wrappers are not `Send`, and
//!   funnelling block ops through an executor keeps the unsafe surface
//!   zero).

pub mod client;
pub mod manifest;
pub mod service;
pub mod xla_stub;

pub use client::BlockEngine;
pub use manifest::{ArtifactOp, Manifest};
pub use service::EngineService;

/// Whether PJRT execution is actually wired in. `false` while
/// `client.rs` aliases the in-repo [`xla_stub`] (the offline crate
/// set has no `xla` crate); flip to `true` when vendoring the real
/// crate and replacing the alias. Tests gate on this so a present
/// artifact directory doesn't turn stubbed builds into hard failures.
pub const PJRT_AVAILABLE: bool = false;

/// Default artifact directory (relative to the repo root / cwd).
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("GPRM_ARTIFACTS") {
        return dir.into();
    }
    "artifacts".into()
}
