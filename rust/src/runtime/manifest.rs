//! The artifact manifest written by `python/compile/aot.py`.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactOp {
    /// Unique name, e.g. `bmod_bs16`.
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Operation kind: `lu0` / `fwd` / `bdiv` / `bmod` / `lustep` /
    /// `matmul`.
    pub op: String,
    /// Block size (matmul: matrix edge).
    pub bs: usize,
    /// Number of `bs×bs` f32 inputs.
    pub arity: usize,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub ops: Vec<ArtifactOp>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {path:?}: {e} (run `make artifacts` first)"
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (dir recorded for file resolution).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or("manifest missing version")?;
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }
        let ops = v
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or("manifest missing ops")?
            .iter()
            .map(|o| {
                let s = |k: &str| -> Result<String, String> {
                    o.get(k)
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| format!("op missing {k}"))
                };
                let n = |k: &str| -> Result<usize, String> {
                    o.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| format!("op missing {k}"))
                };
                Ok(ArtifactOp {
                    name: s("name")?,
                    file: s("file")?,
                    op: s("op")?,
                    bs: n("bs")?,
                    arity: n("arity")?,
                    outputs: n("outputs")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Self { dir, ops })
    }

    /// Find the artifact for `(op, bs)`.
    pub fn find(&self, op: &str, bs: usize) -> Option<&ArtifactOp> {
        self.ops.iter().find(|o| o.op == op && o.bs == bs)
    }

    /// Block sizes available for a given op kind, sorted.
    pub fn block_sizes(&self, op: &str) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.ops.iter().filter(|o| o.op == op).map(|o| o.bs).collect();
        v.sort_unstable();
        v
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, op: &ArtifactOp) -> PathBuf {
        self.dir.join(&op.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1, "dtype": "f32",
        "ops": [
            {"name":"bmod_bs8","file":"bmod_bs8.hlo.txt","op":"bmod","bs":8,"arity":3,"outputs":1},
            {"name":"bmod_bs16","file":"bmod_bs16.hlo.txt","op":"bmod","bs":16,"arity":3,"outputs":1},
            {"name":"lu0_bs8","file":"lu0_bs8.hlo.txt","op":"lu0","bs":8,"arity":1,"outputs":1}
        ]
    }"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE, "arts".into()).unwrap();
        assert_eq!(m.ops.len(), 3);
        let op = m.find("bmod", 16).unwrap();
        assert_eq!(op.arity, 3);
        assert_eq!(m.path_of(op), PathBuf::from("arts/bmod_bs16.hlo.txt"));
        assert!(m.find("bmod", 99).is_none());
        assert_eq!(m.block_sizes("bmod"), vec![8, 16]);
    }

    #[test]
    fn rejects_bad_versions_and_shapes() {
        assert!(Manifest::parse(r#"{"version":2,"ops":[]}"#, ".".into())
            .is_err());
        assert!(Manifest::parse(r#"{"ops":[]}"#, ".".into()).is_err());
        assert!(Manifest::parse(
            r#"{"version":1,"ops":[{"name":"x"}]}"#,
            ".".into()
        )
        .is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // Integration sanity when `make artifacts` has run.
        if let Ok(m) = Manifest::load(crate::runtime::default_artifact_dir())
        {
            assert!(m.find("bmod", 8).is_some());
            assert!(m.find("lustep", 80).is_some());
            assert!(!m.block_sizes("matmul").is_empty());
        }
    }
}
