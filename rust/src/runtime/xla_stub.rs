//! Offline stub of the `xla` crate surface [`super::client`] uses.
//!
//! The real `xla` crate (PJRT CPU client over the C API) is not in
//! the offline crate set, so [`super::client`] aliases this module as
//! `xla`. Every entry point type-checks against the real API but
//! fails at runtime with an explicit "PJRT unavailable" error, which
//! surfaces through [`crate::runtime::EngineService::start`] /
//! `BlockEngine::new` long before any kernel executes. The pure-rust
//! kernel path ([`crate::apps::sparselu::LuBackend::Rust`]) — the
//! default everywhere — is unaffected.
//!
//! To enable real artifact execution, vendor the `xla` crate and
//! replace the alias in `client.rs` with `use xla;`.

use std::fmt;
use std::path::Path;

/// Stub error: always "PJRT unavailable".
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: PJRT unavailable — built with the in-repo xla stub \
         (vendor the `xla` crate to execute AOT artifacts)"
    )))
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of `xla::ElementType` (only the variant the client uses).
pub enum ElementType {
    F32,
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"), "{e}");
    }
}
