//! [`BlockEngine`]: the PJRT CPU client plus a cache of compiled
//! executables, with typed entry points for the block ops.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per artifact,
//! compiled lazily on first use and cached for the life of the engine.

use super::manifest::Manifest;
// The real `xla` crate is not in the offline crate set; the in-repo
// stub type-checks the same surface and fails fast at runtime (see
// xla_stub.rs). Swap this alias for `use xla;` once vendored.
use super::xla_stub as xla;
use crate::util::anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// PJRT-backed executor of the AOT block kernels.
pub struct BlockEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl BlockEngine {
    /// Create a CPU PJRT client over the artifacts in `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let op = self
                .manifest
                .ops
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            let path = self.manifest.path_of(op);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Eagerly compile every artifact matching `bs` (all, if `None`).
    /// First-use compilation costs ~50 ms per artifact on the CPU
    /// client — precompiling keeps it off the measured hot path
    /// (§Perf L3#1).
    pub fn precompile(&mut self, bs: Option<usize>) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .ops
            .iter()
            .filter(|o| bs.is_none_or(|b| o.bs == b))
            .map(|o| o.name.clone())
            .collect();
        let mut n = 0;
        for name in names {
            self.executable(&name)?;
            n += 1;
        }
        Ok(n)
    }

    /// Execute artifact `name` on square `edge×edge` f32 inputs;
    /// returns the flattened outputs of the result tuple.
    pub fn exec(
        &mut self,
        name: &str,
        edge: usize,
        inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let (arity, outputs) = {
            let op = self
                .manifest
                .ops
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            (op.arity, op.outputs)
        };
        if inputs.len() != arity {
            bail!("{name}: expected {arity} inputs, got {}", inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            if data.len() != edge * edge {
                bail!(
                    "{name}: input {i} has {} elems, expected {}",
                    data.len(),
                    edge * edge
                );
            }
            // Build the 2-D literal in one shot (vec1 + reshape costs
            // an extra copy + C round trip per argument — §Perf L3#2).
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    data.as_ptr() as *const u8,
                    data.len() * 4,
                )
            };
            literals.push(
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &[edge, edge],
                    bytes,
                )
                .context("creating input literal")?,
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result")?
            .to_tuple()
            .context("decomposing result tuple")?;
        if tuple.len() != outputs {
            bail!("{name}: expected {outputs} outputs, got {}", tuple.len());
        }
        tuple
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("reading output"))
            .collect()
    }

    // --- typed block ops ------------------------------------------------

    /// `diag ← LU(diag)` in place.
    pub fn lu0(&mut self, bs: usize, diag: &mut [f32]) -> Result<()> {
        let out = self.exec(&format!("lu0_bs{bs}"), bs, &[diag])?;
        diag.copy_from_slice(&out[0]);
        Ok(())
    }

    /// `col ← L(diag)⁻¹ col` in place.
    pub fn fwd(&mut self, bs: usize, diag: &[f32], col: &mut [f32]) -> Result<()> {
        let out = self.exec(&format!("fwd_bs{bs}"), bs, &[diag, col])?;
        col.copy_from_slice(&out[0]);
        Ok(())
    }

    /// `row ← row · U(diag)⁻¹` in place.
    pub fn bdiv(&mut self, bs: usize, diag: &[f32], row: &mut [f32]) -> Result<()> {
        let out = self.exec(&format!("bdiv_bs{bs}"), bs, &[diag, row])?;
        row.copy_from_slice(&out[0]);
        Ok(())
    }

    /// `inner ← inner − row·col` in place.
    pub fn bmod(
        &mut self,
        bs: usize,
        row: &[f32],
        col: &[f32],
        inner: &mut [f32],
    ) -> Result<()> {
        let out = self.exec(&format!("bmod_bs{bs}"), bs, &[row, col, inner])?;
        inner.copy_from_slice(&out[0]);
        Ok(())
    }

    /// Fused 2×2-quadrant elimination step (see `model.lu_step`).
    #[allow(clippy::type_complexity)]
    pub fn lustep(
        &mut self,
        bs: usize,
        diag: &[f32],
        row: &[f32],
        col: &[f32],
        inner: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut out =
            self.exec(&format!("lustep_bs{bs}"), bs, &[diag, row, col, inner])?;
        let i = out.pop().unwrap();
        let c = out.pop().unwrap();
        let r = out.pop().unwrap();
        let d = out.pop().unwrap();
        Ok((d, r, c, i))
    }

    /// `C = A·B` for `n×n` matrices (micro-benchmark artifact).
    pub fn matmul(&mut self, n: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.exec(&format!("matmul_n{n}"), n, &[a, b])?;
        Ok(out.pop().unwrap())
    }
}
