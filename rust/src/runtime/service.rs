//! [`EngineService`]: a dedicated executor thread owning the
//! [`BlockEngine`], callable from any tile/worker thread through a
//! cloneable, `Send + Sync` handle.
//!
//! The `xla` crate's wrapper types are raw-pointer-backed and not
//! `Send`; rather than asserting thread-safety of the C++ objects, all
//! PJRT execution funnels through one service thread via channels.
//! (On this testbed the CPU PJRT client is single-threaded anyway; the
//! GPRM/OMP schedulers overlap their own coordination with the
//! engine's compute.)

use super::client::BlockEngine;
use crate::util::anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Mutex;

enum Request {
    Exec {
        name: String,
        edge: usize,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>, String>>,
    },
    Precompile {
        bs: Option<usize>,
        reply: mpsc::Sender<Result<usize, String>>,
    },
    Platform {
        reply: mpsc::Sender<String>,
    },
    Shutdown,
}

/// Cloneable handle to the engine thread.
pub struct EngineService {
    tx: Mutex<mpsc::Sender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl EngineService {
    /// Spawn the service over the artifacts in `dir`. Fails fast if
    /// the manifest or the PJRT client cannot be created.
    pub fn start(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let mut engine = match BlockEngine::new(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Shutdown => break,
                        Request::Platform { reply } => {
                            let _ = reply.send(engine.platform());
                        }
                        Request::Precompile { bs, reply } => {
                            let r = engine
                                .precompile(bs)
                                .map_err(|e| format!("{e:#}"));
                            let _ = reply.send(r);
                        }
                        Request::Exec { name, edge, inputs, reply } => {
                            let refs: Vec<&[f32]> =
                                inputs.iter().map(|v| v.as_slice()).collect();
                            let r = engine
                                .exec(&name, edge, &refs)
                                .map_err(|e| format!("{e:#}"));
                            let _ = reply.send(r);
                        }
                    }
                }
            })
            .expect("spawn pjrt-engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Self { tx: Mutex::new(tx), handle: Some(handle) })
    }

    fn send(&self, req: Request) {
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .expect("pjrt-engine thread gone");
    }

    /// Execute an artifact (see [`BlockEngine::exec`]); callable from
    /// any thread.
    pub fn exec(
        &self,
        name: &str,
        edge: usize,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Exec {
            name: name.to_string(),
            edge,
            inputs,
            reply,
        });
        rx.recv()
            .map_err(|_| anyhow!("engine thread dropped request"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn platform(&self) -> String {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".into())
    }

    /// Eagerly compile artifacts for block size `bs` (all if `None`),
    /// keeping first-use PJRT compilation off the measured hot path.
    pub fn precompile(&self, bs: Option<usize>) -> Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Precompile { bs, reply });
        rx.recv()
            .map_err(|_| anyhow!("engine thread dropped request"))?
            .map_err(|e| anyhow!(e))
    }

    // Typed helpers mirroring BlockEngine's.

    pub fn lu0(&self, bs: usize, diag: &mut [f32]) -> Result<()> {
        let out = self.exec(&format!("lu0_bs{bs}"), bs, vec![diag.to_vec()])?;
        diag.copy_from_slice(&out[0]);
        Ok(())
    }

    pub fn fwd(&self, bs: usize, diag: &[f32], col: &mut [f32]) -> Result<()> {
        let out = self.exec(
            &format!("fwd_bs{bs}"),
            bs,
            vec![diag.to_vec(), col.to_vec()],
        )?;
        col.copy_from_slice(&out[0]);
        Ok(())
    }

    pub fn bdiv(&self, bs: usize, diag: &[f32], row: &mut [f32]) -> Result<()> {
        let out = self.exec(
            &format!("bdiv_bs{bs}"),
            bs,
            vec![diag.to_vec(), row.to_vec()],
        )?;
        row.copy_from_slice(&out[0]);
        Ok(())
    }

    pub fn bmod(
        &self,
        bs: usize,
        row: &[f32],
        col: &[f32],
        inner: &mut [f32],
    ) -> Result<()> {
        let out = self.exec(
            &format!("bmod_bs{bs}"),
            bs,
            vec![row.to_vec(), col.to_vec(), inner.to_vec()],
        )?;
        inner.copy_from_slice(&out[0]);
        Ok(())
    }

    pub fn matmul(&self, n: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.exec(
            &format!("matmul_n{n}"),
            n,
            vec![a.to_vec(), b.to_vec()],
        )?;
        Ok(out.pop().unwrap())
    }
}

impl Drop for EngineService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
