//! The Matrix-Multiplication micro-benchmark (paper §V, Listings 3–4).
//!
//! `A: m×n`, `B: n×p` (the paper fixes `p = n`), parallelised over the
//! first loop: `m` jobs of `p·n` dot-product work each.
//!
//! Besides the paper's four row-parallel approaches, the workload is
//! also ported onto the kernel-agnostic dataflow engine
//! ([`matmul_dataflow`], graph [`TaskGraph::matmul`]): a *blocked*
//! `C = A·B` whose per-`C`-block accumulation chains are derived by
//! the same access-set machinery as SparseLU/Cholesky, so all three
//! workloads share one scheduling path and can be mixed in a
//! persistent-pool job stream.

use super::dataflow::{run_dataflow, DataflowRt};
use crate::coordinator::{worksharing, GprmRuntime};
use crate::linalg::blocked::BlockedSparseMatrix;
use crate::linalg::dense::{matmul_rows_into, DenseMatrix};
use crate::omp::{DynamicSched, OmpRuntime};
use crate::sched::workload::{Matmul, Workload as _};
use crate::sched::{Error, ExecOpts, ExecStats, Pool, TaskGraph};

/// The four approaches of Fig 2, plus the cutoff variant of Fig 4
/// (Listing 4: only `m/cutoff` tasks are created).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatmulApproach {
    /// Single-threaded Listing 3 (the speedup baseline).
    Sequential,
    /// I: `omp for` (static schedule).
    OmpForStatic,
    /// II: `omp for schedule(dynamic, 1)`.
    OmpForDynamic,
    /// III: one `omp task` per `cutoff` rows (`cutoff = 1` is the
    /// untuned tasking of Fig 2/3).
    OmpTask { cutoff: usize },
    /// IV: GPRM `par_for` over CL worksharing task instances.
    GprmParFor,
}

impl std::fmt::Display for MatmulApproach {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MatmulApproach::Sequential => write!(f, "sequential"),
            MatmulApproach::OmpForStatic => write!(f, "omp-for-static"),
            MatmulApproach::OmpForDynamic => write!(f, "omp-for-dynamic1"),
            MatmulApproach::OmpTask { cutoff } => {
                write!(f, "omp-task(cutoff={cutoff})")
            }
            MatmulApproach::GprmParFor => write!(f, "gprm-par-for"),
        }
    }
}

/// Run one approach on pre-built inputs, writing into `c` (must be
/// zeroed by the caller). The runtimes are borrowed so benchmarks can
/// reuse warm thread pools (both the GPRM pool and an OpenMP team are
/// created once per process in the originals).
pub struct MatmulExec<'rt> {
    pub gprm: Option<&'rt GprmRuntime>,
    pub omp: Option<&'rt OmpRuntime>,
}

impl<'rt> MatmulExec<'rt> {
    pub fn run(
        &self,
        approach: MatmulApproach,
        a: &DenseMatrix,
        b: &DenseMatrix,
        c: &mut DenseMatrix,
    ) {
        let (m, n, p) = (a.rows(), a.cols(), b.cols());
        assert_eq!(b.rows(), n);
        assert_eq!((c.rows(), c.cols()), (m, p));
        let (av, bv) = (a.as_slice(), b.as_slice());
        match approach {
            MatmulApproach::Sequential => {
                matmul_rows_into(av, bv, c.as_mut_slice(), 0, m, n, p);
            }
            MatmulApproach::OmpForStatic => {
                let rt = self.omp.expect("omp runtime required");
                let cc = CPtr(c.as_mut_slice().as_mut_ptr());
                rt.parallel(|ctx| {
                    ctx.for_static(0, m, |i| unsafe {
                        row_job(av, bv, &cc, i, n, p);
                    });
                })
                .expect("omp region failed");
            }
            MatmulApproach::OmpForDynamic => {
                let rt = self.omp.expect("omp runtime required");
                let cc = CPtr(c.as_mut_slice().as_mut_ptr());
                let sched = DynamicSched::new(0, m, 1);
                rt.parallel(|ctx| {
                    ctx.for_dynamic(&sched, |i| unsafe {
                        row_job(av, bv, &cc, i, n, p);
                    });
                })
                .expect("omp region failed");
            }
            MatmulApproach::OmpTask { cutoff } => {
                let rt = self.omp.expect("omp runtime required");
                let cutoff = cutoff.max(1);
                let cc = CPtr(c.as_mut_slice().as_mut_ptr());
                let ccref = &cc;
                rt.parallel(|ctx| {
                    // Listing 4: the generating thread creates
                    // m/cutoff tasks, each covering `cutoff` rows.
                    ctx.single(|| {
                        let mut i = 0;
                        while i < m {
                            let hi = (i + cutoff).min(m);
                            ctx.task(move |_| unsafe {
                                for row in i..hi {
                                    row_job(av, bv, ccref, row, n, p);
                                }
                            });
                            i = hi;
                        }
                    });
                })
                .expect("omp region failed");
            }
            MatmulApproach::GprmParFor => {
                let rt = self.gprm.expect("gprm runtime required");
                let cl = rt.concurrency_level();
                let cc = CPtr(c.as_mut_slice().as_mut_ptr());
                let ccref = &cc;
                rt.par_invoke(cl, |ind| {
                    worksharing::par_for(0, m, ind, cl, |i| unsafe {
                        row_job(av, bv, ccref, i, n, p);
                    });
                })
                .expect("gprm par_invoke failed");
            }
        }
    }
}

/// One micro-benchmark job: row `i` of `C += A·B` (Listing 3 body).
///
/// SAFETY: callers partition rows disjointly (each `i` is owned by
/// exactly one thread under every schedule above), so the row slices
/// never alias.
unsafe fn row_job(a: &[f32], b: &[f32], c: &CPtr, i: usize, n: usize, p: usize) {
    let row = std::slice::from_raw_parts_mut(c.0.add(i * p), p);
    for (j, cij) in row.iter_mut().enumerate() {
        let mut acc = *cij;
        for k in 0..n {
            acc += a[i * n + k] * b[k * p + j];
        }
        *cij = acc;
    }
}

/// Shareable raw pointer to C's storage (disjoint row writes).
struct CPtr(*mut f32);
unsafe impl Sync for CPtr {}
unsafe impl Send for CPtr {}

/// Convenience: build inputs, run, verify against the sequential
/// result, return (wall-time, max-abs-error).
pub fn run_matmul(
    approach: MatmulApproach,
    m: usize,
    n: usize,
    exec: &MatmulExec,
) -> (std::time::Duration, f32) {
    let a = DenseMatrix::bots_random(m, n, 11);
    let b = DenseMatrix::bots_random(n, n, 22);
    let mut c = DenseMatrix::zeros(m, n);
    let t0 = std::time::Instant::now();
    exec.run(approach, &a, &b, &mut c);
    let dt = t0.elapsed();
    let mut want = DenseMatrix::zeros(m, n);
    MatmulExec { gprm: None, omp: None }.run(
        MatmulApproach::Sequential,
        &a,
        &b,
        &mut want,
    );
    (dt, c.max_abs_diff(&want))
}

// ---------------------------------------------------------------------
// Blocked matmul on the dataflow engine
// ---------------------------------------------------------------------

/// The blocked-matmul kernels, embedding/extraction helpers and
/// sequential reference — declared once by the [`Matmul`] registry
/// entry ([`crate::sched::workload`]) and re-exported here for the
/// existing call sites.
pub use crate::sched::workload::{
    madd, matmul_blocked_input, matmul_blocked_seq, matmul_extract_c,
    MATMUL_RUST_KERNELS,
};

/// Blocked `C = A·B` on the dataflow engine (any host, including the
/// persistent pool): builds the embedded blocked input, schedules
/// [`TaskGraph::matmul`], and returns `C` plus the executor stats.
/// Bit-identical (f32) to [`matmul_blocked_seq`].
pub fn matmul_dataflow(
    rt: &DataflowRt,
    a: &DenseMatrix,
    b: &DenseMatrix,
    nbc: usize,
    bs: usize,
    exec: ExecOpts,
) -> (DenseMatrix, ExecStats) {
    let graph = TaskGraph::matmul(nbc);
    let mut m = matmul_blocked_input(a, b, nbc, bs);
    let stats =
        run_dataflow(rt, &mut m, &graph, &MATMUL_RUST_KERNELS, exec)
            .expect("matmul dataflow failed");
    (matmul_extract_c(&m, nbc), stats)
}

/// Batched blocked matmul on the persistent pool: all products are
/// submitted into one [`Pool::scope`] and overlap on the shared
/// worker team. Returns each `C` plus its executor stats, in
/// submission order (the same shape as the factorisation batch APIs).
///
/// The matmul graph is sizing-only (independent of the operand
/// values), so — unlike the pattern-dependent SparseLU batch — one
/// [`TaskGraph::matmul`] is shared by every job; graph and kernels
/// still come from the [`Matmul`] declaration.
pub fn matmul_dataflow_batch(
    pool: &Pool,
    pairs: &[(&DenseMatrix, &DenseMatrix)],
    nbc: usize,
    bs: usize,
) -> Result<(Vec<DenseMatrix>, Vec<ExecStats>), Error> {
    use super::dataflow::{run_dataflow_batch, PoolJob};
    let graph = TaskGraph::matmul(nbc);
    let mut mats: Vec<BlockedSparseMatrix> = pairs
        .iter()
        .map(|&(a, b)| matmul_blocked_input(a, b, nbc, bs))
        .collect();
    let mut jobs: Vec<PoolJob> = mats
        .iter_mut()
        .map(|a| PoolJob { a, graph: &graph, kernels: Matmul.kernels() })
        .collect();
    let stats = run_dataflow_batch(pool, &mut jobs)?;
    drop(jobs);
    let cs = mats.iter().map(|m| matmul_extract_c(m, nbc)).collect();
    Ok((cs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GprmConfig;
    use crate::coordinator::kernel::Registry;

    fn rigs() -> (GprmRuntime, OmpRuntime) {
        (
            GprmRuntime::new(
                GprmConfig { n_tiles: 4, pin: false },
                Registry::new(),
            ),
            OmpRuntime::new(4),
        )
    }

    #[test]
    fn all_approaches_agree() {
        let (gprm, omp) = rigs();
        let exec = MatmulExec { gprm: Some(&gprm), omp: Some(&omp) };
        for approach in [
            MatmulApproach::Sequential,
            MatmulApproach::OmpForStatic,
            MatmulApproach::OmpForDynamic,
            MatmulApproach::OmpTask { cutoff: 1 },
            MatmulApproach::OmpTask { cutoff: 7 },
            MatmulApproach::GprmParFor,
        ] {
            let (_dt, err) = run_matmul(approach, 33, 17, &exec);
            assert_eq!(err, 0.0, "{approach} diverged");
        }
        gprm.shutdown();
        omp.shutdown();
    }

    #[test]
    fn blocked_seq_matches_dense_matmul() {
        let (nbc, bs) = (4usize, 5usize);
        let n = nbc * bs;
        let a = DenseMatrix::bots_random(n, n, 7);
        let b = DenseMatrix::bots_random(n, n, 8);
        let blocked = matmul_blocked_seq(&a, &b, nbc, bs);
        let dense = a.matmul(&b);
        // Different summation order: close, not bit-equal.
        assert!(blocked.max_abs_diff(&dense) < 1e-3);
    }

    #[test]
    fn dataflow_matmul_bit_identical_to_blocked_seq() {
        let (nbc, bs) = (4usize, 5usize);
        let n = nbc * bs;
        let a = DenseMatrix::bots_random(n, n, 31);
        let b = DenseMatrix::bots_random(n, n, 32);
        let want = matmul_blocked_seq(&a, &b, nbc, bs);
        let omp = OmpRuntime::new(4);
        for exec in [ExecOpts::default(), ExecOpts::mutex_baseline()] {
            let (c, stats) = matmul_dataflow(
                &DataflowRt::Omp(&omp),
                &a,
                &b,
                nbc,
                bs,
                exec,
            );
            assert_eq!(stats.executed, nbc * nbc * nbc);
            assert_eq!(
                c.as_slice(),
                want.as_slice(),
                "dataflow matmul differs from blocked seq"
            );
        }
        omp.shutdown();
        // And on the persistent pool.
        let pool = Pool::new(4);
        let (c, _) = matmul_dataflow(
            &DataflowRt::Pool(&pool),
            &a,
            &b,
            nbc,
            bs,
            ExecOpts::default(),
        );
        assert_eq!(c.as_slice(), want.as_slice());
        let (cs, stats) =
            matmul_dataflow_batch(&pool, &[(&a, &b), (&a, &b)], nbc, bs)
                .unwrap();
        for c in cs {
            assert_eq!(c.as_slice(), want.as_slice());
        }
        for s in stats {
            assert_eq!(s.executed, nbc * nbc * nbc);
        }
        pool.shutdown();
    }

    #[test]
    fn display_names() {
        assert_eq!(MatmulApproach::GprmParFor.to_string(), "gprm-par-for");
        assert_eq!(
            MatmulApproach::OmpTask { cutoff: 5 }.to_string(),
            "omp-task(cutoff=5)"
        );
    }
}
