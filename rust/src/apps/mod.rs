//! The paper's two evaluation workloads, runnable on every runtime
//! this crate provides (host threads, real kernels) — the simulator
//! counterparts live in [`crate::tilesim`].
//!
//! * [`matmul`] — the §V micro-benchmark: `C = A·B` as `m` row-jobs,
//!   under the four approaches of Fig 2 (+ cutoff variant of Fig 4),
//!   plus the blocked dataflow port (`matmul_dataflow`) sharing the
//!   engine with the factorisations.
//! * [`dataflow`] — the generic kernel-table driver: runs any
//!   [`crate::sched::TaskGraph`] over a blocked matrix by dispatching
//!   tasks through a per-workload kernel table, on a one-shot host or
//!   the persistent [`crate::sched::Pool`]
//!   (`run_dataflow_batch` overlaps whole job streams). The
//!   registry-generic forms (`run_workload`, `run_workload_batch`)
//!   take a [`crate::sched::workload::Workload`] and derive graph and
//!   kernels from the declaration.
//! * [`sparselu`] — the §VI SparseLU factorisation: sequential
//!   (BOTS reference), OpenMP tasking (Fig 5 port), GPRM hybrid
//!   worksharing-tasking (Listings 5–6 port), and the barrier-free
//!   dataflow driver over the [`crate::sched`] DAG executor,
//!   optionally executing block kernels through the PJRT artifacts.
//! * [`cholesky`] — tiled dense Cholesky (sequential + dataflow), the
//!   second workload on the same engine (see DIVERGENCES.md).

pub mod cholesky;
pub mod dataflow;
pub mod matmul;
pub mod sparselu;

pub use cholesky::{
    cholesky_dataflow, cholesky_dataflow_batch, CHOLESKY_RUST_KERNELS,
};
pub use dataflow::{
    run_dataflow, run_dataflow_batch, run_workload, run_workload_batch,
    BlockKernel, DataflowRt, PoolJob,
};
pub use matmul::{
    matmul_dataflow, matmul_dataflow_batch, run_matmul, MatmulApproach,
    MATMUL_RUST_KERNELS,
};
pub use sparselu::{
    sparselu_dataflow, sparselu_dataflow_batch, sparselu_gprm,
    sparselu_omp, LuBackend, LuRunConfig, LU_RUST_KERNELS,
};
