//! The paper's two evaluation workloads, runnable on every runtime
//! this crate provides (host threads, real kernels) — the simulator
//! counterparts live in [`crate::tilesim`].
//!
//! * [`matmul`] — the §V micro-benchmark: `C = A·B` as `m` row-jobs,
//!   under the four approaches of Fig 2 (+ cutoff variant of Fig 4).
//! * [`dataflow`] — the generic kernel-table driver: runs any
//!   [`crate::sched::TaskGraph`] over a blocked matrix by dispatching
//!   tasks through a per-workload kernel table.
//! * [`sparselu`] — the §VI SparseLU factorisation: sequential
//!   (BOTS reference), OpenMP tasking (Fig 5 port), GPRM hybrid
//!   worksharing-tasking (Listings 5–6 port), and the barrier-free
//!   dataflow driver over the [`crate::sched`] DAG executor,
//!   optionally executing block kernels through the PJRT artifacts.
//! * [`cholesky`] — tiled dense Cholesky (sequential + dataflow), the
//!   second workload on the same engine (see DIVERGENCES.md).

pub mod cholesky;
pub mod dataflow;
pub mod matmul;
pub mod sparselu;

pub use cholesky::cholesky_dataflow;
pub use dataflow::{run_dataflow, BlockKernel, DataflowRt};
pub use matmul::{run_matmul, MatmulApproach};
pub use sparselu::{
    sparselu_dataflow, sparselu_gprm, sparselu_omp, LuBackend, LuRunConfig,
};
