//! Tiled dense Cholesky on the real runtimes — the second workload on
//! the kernel-agnostic dataflow engine (not in the source paper; see
//! DIVERGENCES.md).
//!
//! Two implementations over the same lower-triangle
//! [`BlockedSparseMatrix`]:
//!
//! * sequential — [`crate::linalg::cholesky::cholesky_seq`] (the
//!   reference every parallel schedule is compared against);
//! * dataflow — [`cholesky_dataflow`]: the [`crate::sched`] DAG
//!   executor fires each POTRF/TRSM/SYRK/GEMM block kernel the moment
//!   its data dependencies are satisfied, on either host runtime,
//!   through the same generic kernel-table driver SparseLU uses
//!   ([`super::dataflow::run_dataflow`]) — proving the engine needs no
//!   per-workload executor changes.
//!
//! Kernels are pure rust (there are no AOT/PJRT artifacts for the
//! Cholesky ops; the PJRT path remains SparseLU-only).

use super::dataflow::{run_dataflow, run_workload_batch, DataflowRt};
use crate::linalg::blocked::BlockedSparseMatrix;
use crate::sched::workload::Cholesky;
use crate::sched::{Error, ExecOpts, ExecStats, Pool, TaskGraph};

/// The tiled-Cholesky kernel table — declared once by the
/// [`Cholesky`] registry entry ([`crate::sched::workload`]) and
/// re-exported here for the existing call sites.
pub use crate::sched::workload::CHOLESKY_RUST_KERNELS;

/// Dataflow (DAG-scheduled) tiled Cholesky: factorises `a` (SPD,
/// lower-triangle blocks allocated, e.g. from
/// [`crate::linalg::cholesky::gen_spd`]) in place and returns the
/// executor's statistics. `exec` selects the executor (lock-free work
/// stealing by default, mutex scoreboard as the baseline) exactly as
/// for SparseLU.
///
/// Results are bit-identical (f32) to
/// [`cholesky_seq`](crate::linalg::cholesky::cholesky_seq): the DAG
/// chains every touch of a block in sequential program order.
pub fn cholesky_dataflow(
    rt: &DataflowRt,
    a: &mut BlockedSparseMatrix,
    exec: ExecOpts,
) -> ExecStats {
    let graph = TaskGraph::cholesky(a.nb());
    run_dataflow(rt, a, &graph, &CHOLESKY_RUST_KERNELS, exec)
        .expect("cholesky dataflow failed")
}

/// Batched tiled Cholesky on the persistent pool — a thin call into
/// the registry-generic
/// [`run_workload_batch`](super::dataflow::run_workload_batch):
/// every matrix's DAG is submitted into one [`Pool::scope`] before
/// any wait, so the factorisations overlap on the shared worker team.
/// Each job's result stays bit-identical (f32) to
/// [`cholesky_seq`](crate::linalg::cholesky::cholesky_seq) on its
/// matrix alone.
pub fn cholesky_dataflow_batch(
    pool: &Pool,
    mats: &mut [BlockedSparseMatrix],
) -> Result<Vec<ExecStats>, Error> {
    run_workload_batch(pool, &Cholesky, mats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GprmRuntime;
    use crate::linalg::cholesky::{cholesky_seq, gen_spd, sym_dense};
    use crate::linalg::verify::chol_residual_sparse;
    use crate::omp::OmpRuntime;
    use crate::sched::check_event_ordering;

    fn check_bit_identical(
        factorise: impl FnOnce(&mut BlockedSparseMatrix),
    ) {
        let nb = 8;
        let bs = 6;
        let mut a = gen_spd(nb, bs);
        let orig = sym_dense(&a);
        let mut want = a.deep_clone();
        cholesky_seq(&mut want);
        factorise(&mut a);
        // Bit-identical: same kernels in the same per-block order.
        assert_eq!(a.pattern(), want.pattern());
        assert_eq!(
            a.to_dense().as_slice(),
            want.to_dense().as_slice(),
            "dataflow cholesky differs from sequential"
        );
        // And mathematically correct.
        let res = chol_residual_sparse(&orig, &a);
        assert!(res < 1e-5, "residual {res}");
    }

    #[test]
    fn dataflow_omp_bit_identical_to_seq() {
        let rt = OmpRuntime::new(4);
        check_bit_identical(|a| {
            cholesky_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                ExecOpts::default(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_omp_mutex_baseline_bit_identical_to_seq() {
        let rt = OmpRuntime::new(4);
        check_bit_identical(|a| {
            cholesky_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                ExecOpts::mutex_baseline(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_gprm_bit_identical_to_seq() {
        let rt = GprmRuntime::with_tiles(6);
        check_bit_identical(|a| {
            cholesky_dataflow(
                &DataflowRt::Gprm(&rt),
                a,
                ExecOpts::default(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_single_worker_degenerate() {
        let rt = OmpRuntime::new(1);
        check_bit_identical(|a| {
            cholesky_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                ExecOpts::default(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_pool_bit_identical_to_seq() {
        let pool = Pool::new(4);
        check_bit_identical(|a| {
            cholesky_dataflow(
                &DataflowRt::Pool(&pool),
                a,
                ExecOpts::default(),
            );
        });
        pool.shutdown();
    }

    #[test]
    fn dataflow_batch_every_job_bit_identical_to_seq() {
        let pool = Pool::new(4);
        let (nb, bs) = (8usize, 6usize);
        let n_tasks = TaskGraph::cholesky(nb).len();
        let mut want = gen_spd(nb, bs);
        cholesky_seq(&mut want);
        let want_dense = want.to_dense();
        let mut mats: Vec<BlockedSparseMatrix> =
            (0..4).map(|_| gen_spd(nb, bs)).collect();
        let stats = cholesky_dataflow_batch(&pool, &mut mats).unwrap();
        assert_eq!(stats.len(), 4);
        for (m, s) in mats.iter().zip(&stats) {
            assert_eq!(s.executed, n_tasks);
            assert_eq!(
                m.to_dense().as_slice(),
                want_dense.as_slice(),
                "batched cholesky job diverged from sequential"
            );
        }
        pool.shutdown();
    }

    #[test]
    fn dataflow_schedule_is_edge_valid() {
        let rt = OmpRuntime::new(8);
        for exec in [ExecOpts::default(), ExecOpts::mutex_baseline()] {
            let nb = 10;
            let mut a = gen_spd(nb, 4);
            let graph = TaskGraph::cholesky(nb);
            let stats = cholesky_dataflow(
                &DataflowRt::Omp(&rt),
                &mut a,
                exec.with_events(),
            );
            assert_eq!(stats.executed, graph.len());
            check_event_ordering(&graph, &stats.events).unwrap();
        }
        rt.shutdown();
    }
}
