//! SparseLU factorisation on the real runtimes (paper §VI).
//!
//! Four implementations over the same [`BlockedSparseMatrix`]:
//!
//! * sequential — `linalg::lu::sparselu_seq` (BOTS reference);
//! * OpenMP tasking — a faithful port of the paper's Fig 5: one
//!   `single` producer walks the blocks, spawning a task per non-empty
//!   block, with `taskwait` barriers between phases;
//! * GPRM hybrid worksharing-tasking — the port of Listings 5–6:
//!   per elimination step, `CL/2 + CL/2` worksharing task instances
//!   run `par_for` over the fwd/bdiv domains and `CL` instances run
//!   `par_nested_for` (or the contiguous variants) over the bmod
//!   domain;
//! * dataflow — [`sparselu_dataflow`]: no phase barriers at all; the
//!   [`crate::sched`] DAG executor runs each block kernel the moment
//!   its data dependencies are satisfied, on either host runtime,
//!   dispatching through the generic kernel table of
//!   [`super::dataflow::run_dataflow`] (see DIVERGENCES.md for the
//!   departure from the paper).
//!
//! Block kernels execute either in-process (pure rust, [`LuBackend::Rust`])
//! or through the AOT-compiled JAX/Pallas artifacts via PJRT
//! ([`LuBackend::Pjrt`]).

use super::dataflow::{run_dataflow, run_workload_batch, BlockKernel};
pub use super::dataflow::DataflowRt;
use crate::coordinator::{worksharing, GprmRuntime};
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use crate::linalg::lu::{bdiv, bmod, fwd, lu0};
use crate::omp::OmpRuntime;
use crate::runtime::EngineService;
use crate::sched::workload::Sparselu;
use crate::sched::{Error, ExecOpts, ExecStats, Pool, TaskGraph};

/// How block kernels execute.
pub enum LuBackend<'e> {
    /// Pure-rust kernels (default; what the simulator models).
    Rust,
    /// The PJRT executables compiled from the Pallas kernels.
    Pjrt(&'e EngineService),
}

impl<'e> LuBackend<'e> {
    fn lu0(&self, d: &mut [f32], bs: usize) {
        match self {
            LuBackend::Rust => lu0(d, bs),
            LuBackend::Pjrt(svc) => svc.lu0(bs, d).expect("pjrt lu0"),
        }
    }

    fn fwd(&self, d: &[f32], c: &mut [f32], bs: usize) {
        match self {
            LuBackend::Rust => fwd(d, c, bs),
            LuBackend::Pjrt(svc) => svc.fwd(bs, d, c).expect("pjrt fwd"),
        }
    }

    fn bdiv(&self, d: &[f32], r: &mut [f32], bs: usize) {
        match self {
            LuBackend::Rust => bdiv(d, r, bs),
            LuBackend::Pjrt(svc) => svc.bdiv(bs, d, r).expect("pjrt bdiv"),
        }
    }

    fn bmod(&self, r: &[f32], c: &[f32], i: &mut [f32], bs: usize) {
        match self {
            LuBackend::Rust => bmod(r, c, i, bs),
            LuBackend::Pjrt(svc) => {
                svc.bmod(bs, r, c, i).expect("pjrt bmod")
            }
        }
    }
}

/// The plain-rust SparseLU kernel table — now declared once by the
/// [`Sparselu`] registry entry ([`crate::sched::workload`]) and
/// re-exported here for the existing call sites. The
/// backend-dispatching drivers below build closure tables instead
/// (they must capture the [`LuBackend`]).
pub use crate::sched::workload::LU_RUST_KERNELS;

/// Options shared by the parallel drivers.
pub struct LuRunConfig<'e> {
    pub backend: LuBackend<'e>,
    /// Contiguous instead of round-robin worksharing (GPRM only).
    pub contiguous: bool,
    /// Dataflow executor options (dataflow drivers only): work
    /// stealing vs the mutex-scoreboard baseline, event-log opt-in.
    pub exec: ExecOpts,
}

impl Default for LuRunConfig<'static> {
    fn default() -> Self {
        Self {
            backend: LuBackend::Rust,
            contiguous: false,
            exec: ExecOpts::default(),
        }
    }
}

/// OpenMP-tasking SparseLU — paper Fig 5, using our `omp` runtime.
/// Factorises `a` in place.
pub fn sparselu_omp(rt: &OmpRuntime, a: &mut BlockedSparseMatrix, cfg: &LuRunConfig) {
    let nb = a.nb();
    let bs = a.bs();
    let shared = SharedBlocked::new(std::mem::replace(
        a,
        BlockedSparseMatrix::empty(1, 1),
    ));
    let sh = &shared;
    let backend = &cfg.backend;
    rt.parallel(|ctx| {
        ctx.single(|| {
            for kk in 0..nb {
                // lu0: executed by the generating thread (Fig 5 calls
                // it inline, not as a task).
                {
                    // SAFETY: single producer, no tasks in flight yet.
                    let m = unsafe { sh.get_mut() };
                    backend.lu0(m.block_mut(kk, kk).unwrap(), bs);
                }
                // fwd phase over row kk.
                for jj in kk + 1..nb {
                    if sh.get().is_allocated(kk, jj) {
                        ctx.task(move |_| {
                            // SAFETY: tasks write disjoint (kk,jj)
                            // blocks; diag finalised before spawn.
                            let m = unsafe { sh.get_mut() };
                            let (diag, col) = m
                                .block_and_mut((kk, kk), (kk, jj))
                                .unwrap();
                            backend.fwd(diag, col, bs);
                        });
                    }
                }
                // bdiv phase over column kk.
                for ii in kk + 1..nb {
                    if sh.get().is_allocated(ii, kk) {
                        ctx.task(move |_| {
                            let m = unsafe { sh.get_mut() };
                            let (diag, row) = m
                                .block_and_mut((kk, kk), (ii, kk))
                                .unwrap();
                            backend.bdiv(diag, row, bs);
                        });
                    }
                }
                ctx.taskwait();
                // bmod phase over the trailing submatrix.
                for ii in kk + 1..nb {
                    if !sh.get().is_allocated(ii, kk) {
                        continue;
                    }
                    for jj in kk + 1..nb {
                        if !sh.get().is_allocated(kk, jj) {
                            continue;
                        }
                        ctx.task(move |_| {
                            // SAFETY: unique (ii,jj) per task within
                            // the phase; row/col finalised by the
                            // preceding taskwait.
                            let m = unsafe { sh.get_mut() };
                            m.allocate_clean_block(ii, jj);
                            let (row, col, inner) = m
                                .read2_write1((ii, kk), (kk, jj), (ii, jj))
                                .unwrap();
                            backend.bmod(row, col, inner, bs);
                        });
                    }
                }
                ctx.taskwait();
            }
        });
    })
    .expect("omp sparselu region failed");
    *a = shared.into_inner();
}

/// GPRM hybrid worksharing-tasking SparseLU — paper Listings 5–6.
/// Factorises `a` in place.
pub fn sparselu_gprm(
    rt: &GprmRuntime,
    a: &mut BlockedSparseMatrix,
    cfg: &LuRunConfig,
) {
    let nb = a.nb();
    let bs = a.bs();
    let cl = rt.concurrency_level();
    let shared = SharedBlocked::new(std::mem::replace(
        a,
        BlockedSparseMatrix::empty(1, 1),
    ));
    let sh = &shared;
    let backend = &cfg.backend;
    let contiguous = cfg.contiguous;
    for kk in 0..nb {
        // #pragma gprm seq — lu0 first.
        {
            let m = unsafe { sh.get_mut() };
            backend.lu0(m.block_mut(kk, kk).unwrap(), bs);
        }
        // fwd_bdiv_tasks: CL instances; the first half runs fwd over
        // row kk with CL/2-way worksharing, the second half bdiv over
        // column kk (Listing 5 passes CL/2 as each lane's concurrency
        // level).
        let half = (cl / 2).max(1);
        rt.par_invoke(2 * half, |ind| {
            let lane_fwd = ind < half;
            let lane_ind = if lane_fwd { ind } else { ind - half };
            let work = |j: usize| {
                // Listing 6: fwd_work checks allocation itself. The
                // diagonal block is read in place (split-borrow), the
                // lane's own (row-kk or column-kk) block written.
                let m = unsafe { sh.get_mut() };
                if lane_fwd {
                    if m.is_allocated(kk, j) {
                        let (diag, col) =
                            m.block_and_mut((kk, kk), (kk, j)).unwrap();
                        backend.fwd(diag, col, bs);
                    }
                } else if m.is_allocated(j, kk) {
                    let (diag, row) =
                        m.block_and_mut((kk, kk), (j, kk)).unwrap();
                    backend.bdiv(diag, row, bs);
                }
            };
            if contiguous {
                worksharing::par_for_contiguous(kk + 1, nb, lane_ind, half, work);
            } else {
                worksharing::par_for(kk + 1, nb, lane_ind, half, work);
            }
        })
        .expect("gprm fwd/bdiv phase failed");
        // bmod_tasks: CL instances over the nested (ii, jj) domain.
        rt.par_invoke(cl, |ind| {
            let work = |ii: usize, jj: usize| {
                let m = unsafe { sh.get_mut() };
                if m.is_allocated(ii, kk) && m.is_allocated(kk, jj) {
                    m.allocate_clean_block(ii, jj);
                    let (row, col, inner) = m
                        .read2_write1((ii, kk), (kk, jj), (ii, jj))
                        .unwrap();
                    backend.bmod(row, col, inner, bs);
                }
            };
            if contiguous {
                worksharing::par_nested_for_contiguous(
                    kk + 1,
                    nb,
                    kk + 1,
                    nb,
                    ind,
                    cl,
                    work,
                );
            } else {
                worksharing::par_nested_for(
                    kk + 1,
                    nb,
                    kk + 1,
                    nb,
                    ind,
                    cl,
                    work,
                );
            }
        })
        .expect("gprm bmod phase failed");
    }
    *a = shared.into_inner();
}

/// Dataflow (DAG-scheduled) SparseLU — no phase barriers; every block
/// kernel fires as soon as its dependencies are final. Factorises `a`
/// in place and returns the executor's statistics. The executor is
/// selected by `cfg.exec`: lock-free work stealing by default, the
/// mutex scoreboard as the measurable baseline; the event log is
/// opt-in (`cfg.exec.record_events`) so the default hot path neither
/// locks nor allocates per task.
///
/// The graph and dispatch are fully generic
/// ([`super::dataflow::run_dataflow`]): this function only supplies
/// the SparseLU kernel table, aligned with the
/// [`crate::sched::LU_OPS`] op vocabulary.
///
/// Results are bit-identical (f32) to [`sparselu_seq`]: the DAG's
/// RAW/WAW/WAR chains reproduce the sequential per-block operation
/// order, only the inter-block interleaving changes.
///
/// [`sparselu_seq`]: crate::linalg::lu::sparselu_seq
pub fn sparselu_dataflow(
    rt: &DataflowRt,
    a: &mut BlockedSparseMatrix,
    cfg: &LuRunConfig,
) -> ExecStats {
    let graph = TaskGraph::sparselu(&a.pattern(), a.nb());
    let backend = &cfg.backend;
    let k_lu0 = |_: &[&[f32]], w: &mut [f32], bs: usize| backend.lu0(w, bs);
    let k_fwd =
        |r: &[&[f32]], w: &mut [f32], bs: usize| backend.fwd(r[0], w, bs);
    let k_bdiv =
        |r: &[&[f32]], w: &mut [f32], bs: usize| backend.bdiv(r[0], w, bs);
    let k_bmod = |r: &[&[f32]], w: &mut [f32], bs: usize| {
        backend.bmod(r[0], r[1], w, bs)
    };
    // Indexed by OP_LU0..OP_BMOD, aligned with sched::LU_OPS.
    let kernels: [BlockKernel; 4] = [&k_lu0, &k_fwd, &k_bdiv, &k_bmod];
    run_dataflow(rt, a, &graph, &kernels, cfg.exec)
        .expect("sparselu dataflow failed")
}

/// Batched SparseLU on the persistent pool — a thin call into the
/// registry-generic [`run_workload_batch`]: one graph per matrix
/// (derived from each input's sparsity pattern), every job submitted
/// into one [`Pool::scope`] before any wait, so independent
/// factorisations run **concurrently** on the shared worker team.
/// Each matrix is factorised in place; per-job stats return in
/// order. Kernels are the [`Sparselu`] declaration's plain-rust table
/// (the pool path has no PJRT backend).
///
/// Every job's result is bit-identical (f32) to running
/// [`sparselu_seq`] on that matrix alone — concurrency changes only
/// the interleaving across jobs and blocks, never the per-block
/// operation order.
///
/// [`sparselu_seq`]: crate::linalg::lu::sparselu_seq
pub fn sparselu_dataflow_batch(
    pool: &Pool,
    mats: &mut [BlockedSparseMatrix],
) -> Result<Vec<ExecStats>, Error> {
    run_workload_batch(pool, &Sparselu, mats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat;
    use crate::linalg::lu::sparselu_seq;
    use crate::linalg::verify::{assert_blocked_close, lu_residual_sparse};
    use crate::sched::check_event_ordering;

    fn check_against_seq(factorise: impl FnOnce(&mut BlockedSparseMatrix)) {
        let nb = 10;
        let bs = 8;
        let mut a = genmat(nb, bs);
        let orig = a.to_dense();
        let mut want = a.deep_clone();
        sparselu_seq(&mut want);
        factorise(&mut a);
        // Identical schedule-independent result (f32-exact: same
        // operations in the same per-block order).
        assert_blocked_close(&a, &want, 1e-4);
        // And mathematically correct.
        let res = lu_residual_sparse(&orig, &a);
        assert!(res < 1e-4, "residual {res}");
    }

    #[test]
    fn omp_matches_sequential() {
        let rt = OmpRuntime::new(4);
        check_against_seq(|a| {
            sparselu_omp(&rt, a, &LuRunConfig::default())
        });
        rt.shutdown();
    }

    #[test]
    fn gprm_matches_sequential() {
        let rt = GprmRuntime::with_tiles(6);
        check_against_seq(|a| {
            sparselu_gprm(&rt, a, &LuRunConfig::default())
        });
        rt.shutdown();
    }

    #[test]
    fn gprm_contiguous_matches_sequential() {
        let rt = GprmRuntime::with_tiles(6);
        check_against_seq(|a| {
            sparselu_gprm(
                &rt,
                a,
                &LuRunConfig { contiguous: true, ..Default::default() },
            )
        });
        rt.shutdown();
    }

    #[test]
    fn gprm_single_tile_degenerate() {
        let rt = GprmRuntime::with_tiles(1);
        check_against_seq(|a| {
            sparselu_gprm(&rt, a, &LuRunConfig::default())
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_omp_matches_sequential() {
        let rt = OmpRuntime::new(4);
        check_against_seq(|a| {
            sparselu_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                &LuRunConfig::default(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_omp_mutex_baseline_matches_sequential() {
        let rt = OmpRuntime::new(4);
        check_against_seq(|a| {
            sparselu_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                &LuRunConfig {
                    exec: ExecOpts::mutex_baseline(),
                    ..Default::default()
                },
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_gprm_matches_sequential() {
        let rt = GprmRuntime::with_tiles(6);
        check_against_seq(|a| {
            sparselu_dataflow(
                &DataflowRt::Gprm(&rt),
                a,
                &LuRunConfig::default(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_single_worker_degenerate() {
        let rt = OmpRuntime::new(1);
        check_against_seq(|a| {
            sparselu_dataflow(
                &DataflowRt::Omp(&rt),
                a,
                &LuRunConfig::default(),
            );
        });
        rt.shutdown();
    }

    #[test]
    fn dataflow_pool_matches_sequential() {
        let pool = Pool::new(4);
        check_against_seq(|a| {
            sparselu_dataflow(
                &DataflowRt::Pool(&pool),
                a,
                &LuRunConfig::default(),
            );
        });
        // Pool is persistent: a second factorisation reuses the team.
        check_against_seq(|a| {
            sparselu_dataflow(
                &DataflowRt::Pool(&pool),
                a,
                &LuRunConfig::default(),
            );
        });
        pool.shutdown();
    }

    #[test]
    fn dataflow_batch_every_job_bit_identical_to_seq() {
        use crate::linalg::genmat::genmat_pattern;
        let pool = Pool::new(4);
        let (nb, bs) = (8usize, 6usize);
        let n_tasks = TaskGraph::sparselu(&genmat_pattern(nb), nb).len();
        let mut want = genmat(nb, bs);
        sparselu_seq(&mut want);
        let want_dense = want.to_dense();
        let mut mats: Vec<BlockedSparseMatrix> =
            (0..4).map(|_| genmat(nb, bs)).collect();
        let stats =
            sparselu_dataflow_batch(&pool, &mut mats).unwrap();
        assert_eq!(stats.len(), 4);
        for (m, s) in mats.iter().zip(&stats) {
            assert_eq!(s.executed, n_tasks);
            assert_eq!(
                m.to_dense().as_slice(),
                want_dense.as_slice(),
                "batched job diverged from sequential"
            );
        }
        pool.shutdown();
    }

    #[test]
    fn dataflow_schedule_is_edge_valid() {
        let rt = OmpRuntime::new(8);
        for exec in
            [ExecOpts::default(), ExecOpts::mutex_baseline()]
        {
            let nb = 10;
            let mut a = genmat(nb, 4);
            let graph = TaskGraph::sparselu(&a.pattern(), nb);
            let stats = sparselu_dataflow(
                &DataflowRt::Omp(&rt),
                &mut a,
                &LuRunConfig { exec: exec.with_events(), ..Default::default() },
            );
            assert_eq!(stats.executed, graph.len());
            check_event_ordering(&graph, &stats.events).unwrap();
        }
        rt.shutdown();
    }

    #[test]
    fn fill_in_matches_structure_prediction() {
        use crate::linalg::genmat::genmat_pattern;
        use crate::linalg::lu::lu_task_counts;
        let nb = 8;
        let rt = OmpRuntime::new(3);
        let mut a = genmat(nb, 4);
        sparselu_omp(&rt, &mut a, &LuRunConfig::default());
        // Predicted final pattern from the structural walk:
        let counts = lu_task_counts(&genmat_pattern(nb), nb);
        let total_bmod: usize = counts.bmod.iter().sum();
        assert!(total_bmod > 0);
        rt.shutdown();
    }
}
