//! The generic dataflow driver: runs *any* [`TaskGraph`] over a
//! [`BlockedSparseMatrix`] by dispatching each task through a
//! workload-supplied kernel table — the kernel-agnostic core both
//! [`super::sparselu::sparselu_dataflow`] and
//! [`super::cholesky::cholesky_dataflow`] funnel through.
//!
//! A kernel receives the task's extra read blocks (shared slices) and
//! its write block (exclusive slice), all split-borrowed zero-copy
//! from the one matrix. The table is indexed by the task's
//! [`OpId`](crate::sched::OpId), mirroring the graph's
//! [`OpSpec`](crate::sched::OpSpec) vocabulary — adding a workload
//! means a graph constructor plus a kernel table, never an executor
//! change.

use crate::coordinator::GprmRuntime;
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use crate::omp::OmpRuntime;
use crate::sched::{
    execute_gprm_opts, execute_omp_opts, ExecOpts, ExecStats, TaskGraph,
    TaskId,
};

/// Which host runtime hosts the dataflow executor's workers.
pub enum DataflowRt<'r> {
    /// OpenMP-style team: every team thread runs the worker loop.
    Omp(&'r OmpRuntime),
    /// GPRM machine: `CL` coordinator tasks map ready tasks onto tiles.
    Gprm(&'r GprmRuntime),
}

/// One entry of a workload's executable kernel table: `(reads, write,
/// bs)` — the extra read blocks in task order, then the (exclusive)
/// write block. Indexed by op id, aligned with the graph's op table.
pub type BlockKernel<'k> =
    &'k (dyn Fn(&[&[f32]], &mut [f32], usize) + Sync);

/// Execute `graph` over `a` on the selected host runtime, dispatching
/// every task through `kernels[task.op]`. Factorises (or otherwise
/// transforms) `a` in place and returns the executor statistics.
///
/// Results are bit-identical (f32) to the workload's sequential
/// reference: the graph chains every pair of tasks touching the same
/// block (RAW/WAW/WAR) in sequential program order, so only the
/// inter-block interleaving varies between runs.
pub fn run_dataflow(
    rt: &DataflowRt,
    a: &mut BlockedSparseMatrix,
    graph: &TaskGraph,
    kernels: &[BlockKernel],
    exec: ExecOpts,
) -> ExecStats {
    assert_eq!(graph.nb(), a.nb(), "graph and matrix block grids differ");
    assert_eq!(
        graph.ops().len(),
        kernels.len(),
        "kernel table must cover the graph's op vocabulary"
    );
    let bs = a.bs();
    let shared = SharedBlocked::new(std::mem::replace(
        a,
        BlockedSparseMatrix::empty(1, 1),
    ));
    let sh = &shared;
    let run = |id: TaskId| {
        let t = *graph.task(id);
        // SAFETY: the task graph chains every touch of a given block
        // (RAW/WAW/WAR) and the executor carries a release/acquire
        // edge per dependency (see `SharedBlocked`'s Sync impl), so
        // this task has exclusive access to the block it writes and
        // read-only access to blocks finalised by its predecessors.
        // Fill-in allocation mutates only the written block's own
        // slot. Within the task the borrows split, zero-copy.
        let m = unsafe { sh.get_mut() };
        if t.alloc_write {
            m.allocate_clean_block(t.write.0, t.write.1);
        }
        let kernel = kernels[t.op.0];
        match t.reads() {
            [] => {
                let w = m.block_mut(t.write.0, t.write.1).unwrap();
                kernel(&[], w, bs);
            }
            &[r0] => {
                let (r, w) = m.block_and_mut(r0, t.write).unwrap();
                kernel(&[r], w, bs);
            }
            &[r0, r1] => {
                let (a0, a1, w) =
                    m.read2_write1(r0, r1, t.write).unwrap();
                kernel(&[a0, a1], w, bs);
            }
            _ => unreachable!("tasks carry at most two extra reads"),
        }
    };
    let stats = match rt {
        DataflowRt::Omp(omp) => execute_omp_opts(omp, graph, run, exec),
        DataflowRt::Gprm(gprm) => execute_gprm_opts(gprm, graph, run, exec),
    }
    .expect("dataflow execution failed");
    *a = shared.into_inner();
    stats
}
