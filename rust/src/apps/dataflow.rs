//! The generic dataflow driver: runs *any* [`TaskGraph`] over a
//! [`BlockedSparseMatrix`] by dispatching each task through a
//! workload-supplied kernel table — the kernel-agnostic core that
//! [`super::sparselu::sparselu_dataflow`],
//! [`super::cholesky::cholesky_dataflow`] and
//! [`super::matmul::matmul_dataflow`] all funnel through.
//!
//! Since the workload redesign, the preferred entry points are the
//! **registry-generic** [`run_workload`] / [`run_workload_batch`]: a
//! `&dyn Workload` from [`crate::sched::workload::registry`] supplies
//! the graph ([`Workload::graph_for`]) and the kernel table
//! ([`Workload::kernels`]), so callers (CLI, harness, benches, tests)
//! never name a concrete workload. The raw [`run_dataflow`] /
//! [`run_dataflow_batch`] remain for callers bringing their own graph
//! or kernel closures (the PJRT-backed SparseLU driver).
//!
//! A kernel receives the task's extra read blocks (shared slices) and
//! its write block (exclusive slice), all split-borrowed zero-copy
//! from the one matrix ([`crate::sched::workload::kernel_runner`]).
//! The table is indexed by the task's [`OpId`](crate::sched::OpId),
//! mirroring the graph's [`OpSpec`](crate::sched::OpSpec) vocabulary.
//!
//! # Hosts
//!
//! [`run_dataflow`] is a thin client over three hosts: the two
//! **one-shot** executors (an OpenMP-style team or the GPRM machine
//! spun up per launch — preserved so the PR-2/PR-3 drivers and BENCH
//! rows stay comparable) and the **persistent pool**
//! ([`DataflowRt::Pool`]), where the call becomes submit-and-wait on
//! a long-lived worker team. [`run_dataflow_batch`] is the multi-job
//! form: it submits every job into one [`Pool::scope`] and only then
//! waits, so independent factorisations overlap and workers steal
//! across job boundaries — mixed workloads welcome (each job carries
//! its own graph and kernel table). Every failure mode is the typed
//! [`Error`]; nothing on an error path panics.
//!
//! [`Workload::graph_for`]: crate::sched::workload::Workload::graph_for
//! [`Workload::kernels`]: crate::sched::workload::Workload::kernels

use crate::coordinator::GprmRuntime;
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use crate::linalg::microkernel::KernelMode;
use crate::omp::OmpRuntime;
use crate::sched::workload::{kernel_runner, Workload};
use crate::sched::{
    execute_gprm_opts, execute_omp_opts, Error, ExecOpts, ExecStats,
    Pool, TaskGraph,
};

pub use crate::sched::workload::BlockKernel;

/// Which host runs the dataflow workers.
pub enum DataflowRt<'r> {
    /// OpenMP-style team: every team thread runs the worker loop
    /// (one-shot: the team is dedicated to this graph until it
    /// drains).
    Omp(&'r OmpRuntime),
    /// GPRM machine: `CL` coordinator tasks map ready tasks onto
    /// tiles (one-shot).
    Gprm(&'r GprmRuntime),
    /// Persistent multi-job pool: the call is a submit-and-wait
    /// client; the pool's workers serve other jobs concurrently.
    /// [`ExecOpts`] are not consulted on this host — the pool always
    /// work-steals and records no event log (schedule audits belong
    /// to the one-shot executors).
    Pool(&'r Pool),
}

fn check_job(
    a: &BlockedSparseMatrix,
    graph: &TaskGraph,
    kernels: &[BlockKernel],
) -> Result<(), Error> {
    if graph.nb() != a.nb() {
        return Err(Error::GridMismatch {
            graph_nb: graph.nb(),
            matrix_nb: a.nb(),
        });
    }
    if graph.ops().len() != kernels.len() {
        return Err(Error::KernelTable {
            ops: graph.ops().len(),
            kernels: kernels.len(),
        });
    }
    Ok(())
}

/// Execute `graph` over `a` on the selected host, dispatching every
/// task through `kernels[task.op]`. Factorises (or otherwise
/// transforms) `a` in place and returns the executor statistics; all
/// failures (grid/kernel-table mismatch, executor-option misuse on
/// the pool host, a poisoned job) surface as the typed [`Error`].
///
/// Results are bit-identical (f32) to the workload's sequential
/// reference: the graph chains every pair of tasks touching the same
/// block (RAW/WAW/WAR) in sequential program order, so only the
/// inter-block interleaving varies between runs — on every host.
pub fn run_dataflow(
    rt: &DataflowRt,
    a: &mut BlockedSparseMatrix,
    graph: &TaskGraph,
    kernels: &[BlockKernel],
    exec: ExecOpts,
) -> Result<ExecStats, Error> {
    check_job(a, graph, kernels)?;
    if matches!(rt, DataflowRt::Pool(_))
        && (!exec.steal || exec.record_events)
    {
        // Reject a silent mismatch instead of "auditing" an empty
        // event log or mislabelling a stealing run as the mutex
        // baseline.
        return Err(Error::ExecOpts(
            "ExecOpts select one-shot executors; the pool host always \
             work-steals and records no event log",
        ));
    }
    let bs = a.bs();
    let shared = SharedBlocked::new(std::mem::replace(
        a,
        BlockedSparseMatrix::empty(1, 1),
    ));
    let run = kernel_runner(graph, kernels, &shared, bs);
    let stats = match rt {
        DataflowRt::Omp(omp) => {
            execute_omp_opts(omp, graph, &run, exec).map_err(Error::Host)
        }
        DataflowRt::Gprm(gprm) => {
            execute_gprm_opts(gprm, graph, &run, exec)
                .map_err(Error::Host)
        }
        DataflowRt::Pool(pool) => pool.run(graph, &run),
    };
    drop(run);
    // The matrix is restored even on failure (a poisoned pool job
    // leaves a partial but owned result).
    *a = shared.into_inner();
    stats
}

/// Registry-generic single-job driver: the workload declaration
/// supplies the graph (matching this input's structure) and the
/// kernel table. This is what the CLI, benches and conformance tests
/// call — adding a workload never adds a caller-side arm.
pub fn run_workload(
    rt: &DataflowRt,
    w: &dyn Workload,
    a: &mut BlockedSparseMatrix,
    exec: ExecOpts,
) -> Result<ExecStats, Error> {
    run_workload_mode(rt, w, a, exec, KernelMode::BitIdentical)
}

/// [`run_workload`] with an explicit kernel precision policy: the
/// workload's [`Workload::kernels_for`] table for `mode` replaces the
/// plain table. `BitIdentical` (what [`run_workload`] always passes —
/// the conformance default) routes the update kernels through the
/// microkernel layer's bit-identical paths, which produce the same
/// f32 bits as the reference table on every build; `Fast` trades bit
/// equality for the residual-bounded vectorised accumulation order
/// (see DIVERGENCES.md).
pub fn run_workload_mode(
    rt: &DataflowRt,
    w: &dyn Workload,
    a: &mut BlockedSparseMatrix,
    exec: ExecOpts,
    mode: KernelMode,
) -> Result<ExecStats, Error> {
    let graph = w.graph_for(a);
    run_dataflow(rt, a, &graph, w.kernels_for(mode), exec)
}

/// One job of a [`run_dataflow_batch`] stream: the matrix to
/// transform in place, the graph over it, and the kernel table its op
/// ids index. Jobs in one batch may come from different workloads.
pub struct PoolJob<'a> {
    pub a: &'a mut BlockedSparseMatrix,
    pub graph: &'a TaskGraph,
    pub kernels: &'a [BlockKernel<'a>],
}

/// Submit every job into one pool scope, then wait for all: the jobs
/// execute **concurrently** on the shared worker team (cross-job
/// stealing included), unlike a loop of [`run_dataflow`] calls which
/// would serialise them. Returns per-job stats in submission order.
///
/// On a submission [`Error`] the already-submitted prefix still runs
/// to completion (their matrices hold valid results) before the error
/// is returned; nothing is ever silently dropped. A job poisoned by a
/// panicking kernel surfaces as [`Error::Job`] — but only **after**
/// every job finished and every matrix, including the healthy jobs'
/// results, was restored.
pub fn run_dataflow_batch(
    pool: &Pool,
    jobs: &mut [PoolJob<'_>],
) -> Result<Vec<ExecStats>, Error> {
    for j in jobs.iter_mut() {
        check_job(j.a, j.graph, j.kernels)?;
    }
    let shares: Vec<(SharedBlocked, usize)> = jobs
        .iter_mut()
        .map(|j| {
            let bs = j.a.bs();
            let m = std::mem::replace(j.a, BlockedSparseMatrix::empty(1, 1));
            (SharedBlocked::new(m), bs)
        })
        .collect();
    let result = pool.scope(|s| {
        let mut handles = Vec::with_capacity(shares.len());
        for (j, (sh, bs)) in jobs.iter().zip(&shares) {
            let run = kernel_runner(j.graph, j.kernels, sh, *bs);
            handles.push(s.submit(j.graph, run)?);
        }
        // Collect every outcome without unwinding mid-scope: one
        // poisoned job must not cost the other jobs their results.
        Ok(handles.iter().map(|h| h.wait()).collect::<Vec<_>>())
    });
    for (j, (sh, _)) in jobs.iter_mut().zip(shares) {
        *j.a = sh.into_inner();
    }
    result?.into_iter().collect()
}

/// Registry-generic batch driver: one graph per matrix (derived from
/// each input's structure via the workload declaration), all jobs
/// overlapped on one pool. The three `*_dataflow_batch` wrappers are
/// thin calls into this.
pub fn run_workload_batch(
    pool: &Pool,
    w: &dyn Workload,
    mats: &mut [BlockedSparseMatrix],
) -> Result<Vec<ExecStats>, Error> {
    let graphs: Vec<TaskGraph> =
        mats.iter().map(|a| w.graph_for(a)).collect();
    let mut jobs: Vec<PoolJob> = mats
        .iter_mut()
        .zip(&graphs)
        .map(|(a, graph)| PoolJob { a, graph, kernels: w.kernels() })
        .collect();
    run_dataflow_batch(pool, &mut jobs)
}
