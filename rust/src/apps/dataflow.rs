//! The generic dataflow driver: runs *any* [`TaskGraph`] over a
//! [`BlockedSparseMatrix`] by dispatching each task through a
//! workload-supplied kernel table — the kernel-agnostic core that
//! [`super::sparselu::sparselu_dataflow`],
//! [`super::cholesky::cholesky_dataflow`] and
//! [`super::matmul::matmul_dataflow`] all funnel through.
//!
//! A kernel receives the task's extra read blocks (shared slices) and
//! its write block (exclusive slice), all split-borrowed zero-copy
//! from the one matrix. The table is indexed by the task's
//! [`OpId`](crate::sched::OpId), mirroring the graph's
//! [`OpSpec`](crate::sched::OpSpec) vocabulary — adding a workload
//! means a graph constructor plus a kernel table, never an executor
//! change.
//!
//! # Hosts
//!
//! [`run_dataflow`] is a thin client over three hosts: the two
//! **one-shot** executors (an OpenMP-style team or the GPRM machine
//! spun up per launch — preserved so the PR-2/PR-3 drivers and BENCH
//! rows stay comparable) and the **persistent pool**
//! ([`DataflowRt::Pool`]), where the call becomes submit-and-wait on
//! a long-lived worker team. [`run_dataflow_batch`] is the multi-job
//! form: it submits every job into one [`Pool::scope`] and only then
//! waits, so independent factorisations overlap and workers steal
//! across job boundaries — mixed workloads welcome (each job carries
//! its own graph and kernel table).

use crate::coordinator::GprmRuntime;
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use crate::omp::OmpRuntime;
use crate::sched::{
    execute_gprm_opts, execute_omp_opts, ExecOpts, ExecStats, Pool,
    SubmitError, TaskGraph, TaskId,
};

/// Which host runs the dataflow workers.
pub enum DataflowRt<'r> {
    /// OpenMP-style team: every team thread runs the worker loop
    /// (one-shot: the team is dedicated to this graph until it
    /// drains).
    Omp(&'r OmpRuntime),
    /// GPRM machine: `CL` coordinator tasks map ready tasks onto
    /// tiles (one-shot).
    Gprm(&'r GprmRuntime),
    /// Persistent multi-job pool: the call is a submit-and-wait
    /// client; the pool's workers serve other jobs concurrently.
    /// [`ExecOpts`] are not consulted on this host — the pool always
    /// work-steals and records no event log (schedule audits belong
    /// to the one-shot executors).
    Pool(&'r Pool),
}

/// One entry of a workload's executable kernel table: `(reads, write,
/// bs)` — the extra read blocks in task order, then the (exclusive)
/// write block. Indexed by op id, aligned with the graph's op table.
pub type BlockKernel<'k> =
    &'k (dyn Fn(&[&[f32]], &mut [f32], usize) + Sync);

/// The per-task dispatch closure shared by every host: split-borrow
/// the task's blocks zero-copy and fire `kernels[task.op]`. The
/// closure is `Send + Sync` so the pool can run it from any worker;
/// the access-set discipline that makes the unsafe block sound is
/// documented inline.
fn task_runner<'a>(
    graph: &'a TaskGraph,
    kernels: &'a [BlockKernel<'a>],
    shared: &'a SharedBlocked,
    bs: usize,
) -> impl Fn(TaskId) + Send + Sync + 'a {
    move |id: TaskId| {
        let t = *graph.task(id);
        // SAFETY: the task graph chains every touch of a given block
        // (RAW/WAW/WAR) and every executor host carries a
        // release/acquire edge per dependency (see `SharedBlocked`'s
        // Sync impl), so this task has exclusive access to the block
        // it writes and read-only access to blocks finalised by its
        // predecessors. Fill-in allocation mutates only the written
        // block's own slot. Within the task the borrows split,
        // zero-copy.
        let m = unsafe { shared.get_mut() };
        if t.alloc_write {
            m.allocate_clean_block(t.write.0, t.write.1);
        }
        let kernel = kernels[t.op.0];
        match t.reads() {
            [] => {
                let w = m.block_mut(t.write.0, t.write.1).unwrap();
                kernel(&[], w, bs);
            }
            &[r0] => {
                let (r, w) = m.block_and_mut(r0, t.write).unwrap();
                kernel(&[r], w, bs);
            }
            &[r0, r1] => {
                let (a0, a1, w) = m.read2_write1(r0, r1, t.write).unwrap();
                kernel(&[a0, a1], w, bs);
            }
            _ => unreachable!("tasks carry at most two extra reads"),
        }
    }
}

fn check_job(a: &BlockedSparseMatrix, graph: &TaskGraph, kernels: &[BlockKernel]) {
    assert_eq!(graph.nb(), a.nb(), "graph and matrix block grids differ");
    assert_eq!(
        graph.ops().len(),
        kernels.len(),
        "kernel table must cover the graph's op vocabulary"
    );
}

/// Execute `graph` over `a` on the selected host, dispatching every
/// task through `kernels[task.op]`. Factorises (or otherwise
/// transforms) `a` in place and returns the executor statistics.
///
/// Results are bit-identical (f32) to the workload's sequential
/// reference: the graph chains every pair of tasks touching the same
/// block (RAW/WAW/WAR) in sequential program order, so only the
/// inter-block interleaving varies between runs — on every host.
pub fn run_dataflow(
    rt: &DataflowRt,
    a: &mut BlockedSparseMatrix,
    graph: &TaskGraph,
    kernels: &[BlockKernel],
    exec: ExecOpts,
) -> ExecStats {
    check_job(a, graph, kernels);
    let bs = a.bs();
    let shared = SharedBlocked::new(std::mem::replace(
        a,
        BlockedSparseMatrix::empty(1, 1),
    ));
    let run = task_runner(graph, kernels, &shared, bs);
    let stats = match rt {
        DataflowRt::Omp(omp) => execute_omp_opts(omp, graph, &run, exec),
        DataflowRt::Gprm(gprm) => execute_gprm_opts(gprm, graph, &run, exec),
        DataflowRt::Pool(pool) => {
            // The pool has no executor options — reject a silent
            // mismatch instead of "auditing" an empty event log or
            // mislabelling a stealing run as the mutex baseline.
            assert!(
                exec.steal && !exec.record_events,
                "ExecOpts select one-shot executors; the pool host \
                 always work-steals and records no event log"
            );
            pool.run(graph, &run)
        }
    }
    .expect("dataflow execution failed");
    drop(run);
    *a = shared.into_inner();
    stats
}

/// One job of a [`run_dataflow_batch`] stream: the matrix to
/// transform in place, the graph over it, and the kernel table its op
/// ids index. Jobs in one batch may come from different workloads.
pub struct PoolJob<'a> {
    pub a: &'a mut BlockedSparseMatrix,
    pub graph: &'a TaskGraph,
    pub kernels: &'a [BlockKernel<'a>],
}

/// Submit every job into one pool scope, then wait for all: the jobs
/// execute **concurrently** on the shared worker team (cross-job
/// stealing included), unlike a loop of [`run_dataflow`] calls which
/// would serialise them. Returns per-job stats in submission order.
///
/// On [`SubmitError`] the already-submitted prefix still runs to
/// completion (their matrices hold valid results) before the error is
/// returned; nothing is ever silently dropped. A job poisoned by a
/// panicking kernel panics here too (matching [`run_dataflow`]'s
/// `expect`) — but only **after** every job finished and every
/// matrix, including the healthy jobs' results, was restored.
pub fn run_dataflow_batch(
    pool: &Pool,
    jobs: &mut [PoolJob<'_>],
) -> Result<Vec<ExecStats>, SubmitError> {
    for j in jobs.iter_mut() {
        check_job(j.a, j.graph, j.kernels);
    }
    let shares: Vec<(SharedBlocked, usize)> = jobs
        .iter_mut()
        .map(|j| {
            let bs = j.a.bs();
            let m = std::mem::replace(j.a, BlockedSparseMatrix::empty(1, 1));
            (SharedBlocked::new(m), bs)
        })
        .collect();
    let result = pool.scope(|s| {
        let mut handles = Vec::with_capacity(shares.len());
        for (j, (sh, bs)) in jobs.iter().zip(&shares) {
            let run = task_runner(j.graph, j.kernels, sh, *bs);
            handles.push(s.submit(j.graph, run)?);
        }
        // Collect every outcome without unwinding mid-scope: one
        // poisoned job must not cost the other jobs their results.
        Ok(handles.iter().map(|h| h.wait()).collect::<Vec<_>>())
    });
    for (j, (sh, _)) in jobs.iter_mut().zip(shares) {
        *j.a = sh.into_inner();
    }
    Ok(result?
        .into_iter()
        .map(|r| r.expect("pool dataflow job failed"))
        .collect())
}
