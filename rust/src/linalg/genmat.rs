//! Faithful port of the BOTS `sparselu` input generator (`genmat`).
//!
//! The paper (§VI) states it did **not** change the BOTS initialisation
//! phase, and quotes its structural sparsity: ~85% at NB=50, ~89% at
//! NB=100 — both reproduced by this port (asserted in tests).

use super::blocked::BlockedSparseMatrix;

/// Decide whether block `(ii, jj)` is structurally null, exactly as
/// BOTS `genmat` does.
///
/// Kept public so the simulator's workload generator can enumerate the
/// task DAG without materialising block data.
pub fn bots_null_entry(ii: usize, jj: usize) -> bool {
    let mut null_entry = false;
    if ii < jj && ii % 3 != 0 {
        null_entry = true;
    }
    if ii > jj && jj % 3 != 0 {
        null_entry = true;
    }
    if ii % 2 == 1 {
        null_entry = true;
    }
    if jj % 2 == 1 {
        null_entry = true;
    }
    if ii == jj {
        null_entry = false;
    }
    if ii == jj.wrapping_sub(1) || ii.wrapping_sub(1) == jj {
        null_entry = false;
    }
    null_entry
}

/// BOTS `genmat`: build an `nb×nb` blocked sparse matrix with `bs×bs`
/// blocks. Block values come from the BOTS LCG
/// (`init_val = 3125*init_val mod 65536`, seeded 1325), streamed in the
/// same (ii, jj, i, j) order as the C code so the numbers match
/// bit-for-bit.
pub fn genmat(nb: usize, bs: usize) -> BlockedSparseMatrix {
    let mut m = BlockedSparseMatrix::empty(nb, bs);
    let mut init_val: u64 = 1325;
    for ii in 0..nb {
        for jj in 0..nb {
            if !bots_null_entry(ii, jj) {
                let mut block = vec![0.0f32; bs * bs].into_boxed_slice();
                for v in block.iter_mut() {
                    init_val = (3125 * init_val) % 65536;
                    *v = (init_val as f32 - 32768.0) / 16384.0;
                }
                // Diagonal dominance nudge on diagonal blocks keeps the
                // pivot-free factorisation well-conditioned for the
                // *numeric* verification path. BOTS itself factorises
                // whatever the LCG produces and never checks residuals;
                // we do check them, so diagonal blocks get +bs on the
                // diagonal. The task DAG (what the paper measures) is
                // unchanged: structure is identical.
                if ii == jj {
                    for d in 0..bs {
                        block[d * bs + d] += bs as f32;
                    }
                }
                m.set_block(ii, jj, block);
            }
        }
    }
    m
}

/// Structure-only variant: the allocation pattern of `genmat(nb, _)`
/// as a row-major boolean grid. Used by the simulator workload
/// generator (no data needed, only the DAG shape).
pub fn genmat_pattern(nb: usize) -> Vec<bool> {
    let mut p = Vec::with_capacity(nb * nb);
    for ii in 0..nb {
        for jj in 0..nb {
            p.push(!bots_null_entry(ii, jj));
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_rules() {
        // Diagonal and first off-diagonals always allocated.
        for i in 0..20usize {
            assert!(!bots_null_entry(i, i));
            assert!(!bots_null_entry(i, i + 1));
            assert!(!bots_null_entry(i + 1, i));
        }
        // Odd row/col (away from the tridiagonal band) are null.
        assert!(bots_null_entry(1, 5));
        assert!(bots_null_entry(5, 1));
        // (0, 2): ii<jj, ii%3==0, both even → allocated.
        assert!(!bots_null_entry(0, 2));
        // (2, 4): ii<jj, ii%3=2 → null.
        assert!(bots_null_entry(2, 4));
    }

    #[test]
    fn paper_sparsity_figures() {
        // Paper §VI: "in the case of 50×50 blocks, the matrices are 85%
        // sparse, while for the cases with 100×100 blocks, the matrices
        // become 89% sparse".
        let p50 = genmat_pattern(50);
        let s50 = 1.0 - p50.iter().filter(|&&x| x).count() as f64 / 2500.0;
        assert!((0.84..0.86).contains(&s50), "NB=50 sparsity {s50}");
        let p100 = genmat_pattern(100);
        let s100 =
            1.0 - p100.iter().filter(|&&x| x).count() as f64 / 10000.0;
        assert!((0.88..0.90).contains(&s100), "NB=100 sparsity {s100}");
    }

    #[test]
    fn genmat_matches_pattern_and_is_deterministic() {
        let m = genmat(10, 4);
        assert_eq!(m.pattern(), genmat_pattern(10));
        let m2 = genmat(10, 4);
        assert_eq!(
            m.block(0, 0).unwrap(),
            m2.block(0, 0).unwrap(),
            "generator must be deterministic"
        );
        // First streamed value: (3125*1325)%65536 = 11857, then +bs on
        // the (0,0) diagonal element of the diagonal block.
        let expect = (11857.0f32 - 32768.0) / 16384.0 + 4.0;
        assert!((m.block(0, 0).unwrap()[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn values_in_range() {
        let m = genmat(6, 5);
        for ii in 0..6 {
            for jj in 0..6 {
                if ii != jj {
                    if let Some(b) = m.block(ii, jj) {
                        assert!(b.iter().all(|&x| (-2.0..2.0).contains(&x)));
                    }
                }
            }
        }
    }
}
