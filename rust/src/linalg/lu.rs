//! The four SparseLU block kernels (`lu0`, `fwd`, `bdiv`, `bmod`)
//! exactly as in BOTS, plus sequential reference drivers.
//!
//! Shapes: every argument is one row-major `bs×bs` block.
//!
//! * `lu0(diag)`        — in-place unpivoted LU of the diagonal block.
//! * `fwd(diag, col)`   — `col ← L(diag)⁻¹ · col` (unit-lower solve);
//!   applied to blocks **right of** the diagonal (row kk).
//! * `bdiv(diag, row)`  — `row ← row · U(diag)⁻¹` (upper solve from the
//!   right); applied to blocks **below** the diagonal (column kk).
//! * `bmod(row, col, inner)` — `inner ← inner − row · col` (Schur
//!   update on the trailing submatrix).
//!
//! Naming follows BOTS: in `fwd(diag, col)` the paper's Fig 5 passes
//! `A[kk][jj]` (a block on row kk, i.e. a *column* panel of U), and in
//! `bdiv(diag, row)` it passes `A[ii][kk]` (a row panel of L).

use super::blocked::BlockedSparseMatrix;
use super::dense::DenseMatrix;

/// Approximate flop counts per kernel, used by the simulator cost
/// model and the benchmark reports.
pub fn kernel_flops(kind: BlockOp, bs: usize) -> u64 {
    let b = bs as u64;
    match kind {
        BlockOp::Lu0 => 2 * b * b * b / 3,
        BlockOp::Fwd | BlockOp::Bdiv => b * b * b,
        BlockOp::Bmod => 2 * b * b * b,
    }
}

/// The four block-kernel kinds (shared vocabulary between the rust
/// kernels, the PJRT artifacts, and the simulator workload DAG).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BlockOp {
    Lu0,
    Fwd,
    Bdiv,
    Bmod,
}

impl BlockOp {
    /// Artifact base name (matches `python/compile/aot.py`).
    pub fn name(self) -> &'static str {
        match self {
            BlockOp::Lu0 => "lu0",
            BlockOp::Fwd => "fwd",
            BlockOp::Bdiv => "bdiv",
            BlockOp::Bmod => "bmod",
        }
    }
}

/// BOTS `lu0`: unpivoted in-place LU of the diagonal block
/// (`diag = L·U`, unit diagonal on L, both packed into `diag`).
pub fn lu0(diag: &mut [f32], bs: usize) {
    debug_assert_eq!(diag.len(), bs * bs);
    for k in 0..bs {
        let pivot = diag[k * bs + k];
        debug_assert!(pivot != 0.0, "zero pivot at k={k}");
        for i in k + 1..bs {
            diag[i * bs + k] /= pivot;
            let lik = diag[i * bs + k];
            for j in k + 1..bs {
                diag[i * bs + j] -= lik * diag[k * bs + j];
            }
        }
    }
}

/// BOTS `fwd`: forward-substitute the diagonal block's unit-lower
/// factor through a block on the same block-row: `col ← L⁻¹ col`.
pub fn fwd(diag: &[f32], col: &mut [f32], bs: usize) {
    debug_assert_eq!(diag.len(), bs * bs);
    debug_assert_eq!(col.len(), bs * bs);
    for j in 0..bs {
        for k in 0..bs {
            let ckj = col[k * bs + j];
            if ckj == 0.0 {
                continue;
            }
            for i in k + 1..bs {
                col[i * bs + j] -= diag[i * bs + k] * ckj;
            }
        }
    }
}

/// BOTS `bdiv`: back-substitute the diagonal block's upper factor
/// through a block on the same block-column: `row ← row · U⁻¹`.
pub fn bdiv(diag: &[f32], row: &mut [f32], bs: usize) {
    debug_assert_eq!(diag.len(), bs * bs);
    debug_assert_eq!(row.len(), bs * bs);
    for i in 0..bs {
        for k in 0..bs {
            row[i * bs + k] /= diag[k * bs + k];
            let rik = row[i * bs + k];
            if rik == 0.0 {
                continue;
            }
            for j in k + 1..bs {
                row[i * bs + j] -= rik * diag[k * bs + j];
            }
        }
    }
}

/// BOTS `bmod`: Schur-complement update `inner ← inner − row·col`.
pub fn bmod(row: &[f32], col: &[f32], inner: &mut [f32], bs: usize) {
    debug_assert_eq!(row.len(), bs * bs);
    debug_assert_eq!(col.len(), bs * bs);
    debug_assert_eq!(inner.len(), bs * bs);
    // ikj order: stream `col` rows; identical result to the BOTS ijk
    // loop up to f32 rounding (each C element accumulates the same
    // products; f32 addition order within a k-sum is preserved).
    //
    // The j loop is unrolled 4-wide over `chunks_exact` so the
    // in-order scalar pipeline (and LLVM's vectoriser) sees four
    // independent fused update chains per iteration. Unrolling is
    // across *distinct* elements of `inner`, so each element still
    // accumulates its k-products in exactly the sequential order —
    // results stay bit-identical to the rolled loop (the determinism
    // tests assert this against `sparselu_seq`).
    for i in 0..bs {
        let irow = &mut inner[i * bs..(i + 1) * bs];
        for k in 0..bs {
            let rik = row[i * bs + k];
            if rik == 0.0 {
                continue;
            }
            let crow = &col[k * bs..(k + 1) * bs];
            let mut ic = irow.chunks_exact_mut(4);
            let mut cc = crow.chunks_exact(4);
            for (iv, cv) in ic.by_ref().zip(cc.by_ref()) {
                iv[0] -= rik * cv[0];
                iv[1] -= rik * cv[1];
                iv[2] -= rik * cv[2];
                iv[3] -= rik * cv[3];
            }
            for (iv, cv) in
                ic.into_remainder().iter_mut().zip(cc.remainder())
            {
                *iv -= rik * cv;
            }
        }
    }
}

/// Sequential blocked SparseLU — the BOTS `sparselu_seq` reference and
/// the baseline every speedup in the paper is measured against.
///
/// In-place: on return `a` holds the packed L (unit-diagonal) and U
/// factors, with fill-in blocks allocated where `bmod` hit an
/// unallocated `(ii, jj)`.
pub fn sparselu_seq(a: &mut BlockedSparseMatrix) {
    let nb = a.nb();
    let bs = a.bs();
    for kk in 0..nb {
        {
            let d = a.block_mut(kk, kk).expect("diagonal block must exist");
            lu0(d, bs);
        }
        // fwd phase: blocks right of the diagonal on row kk. The
        // diagonal block is only read, the target only written —
        // split-borrowed, zero copies.
        for jj in kk + 1..nb {
            if a.is_allocated(kk, jj) {
                let (diag, col) =
                    a.block_and_mut((kk, kk), (kk, jj)).unwrap();
                fwd(diag, col, bs);
            }
        }
        // bdiv phase: blocks below the diagonal on column kk.
        for ii in kk + 1..nb {
            if a.is_allocated(ii, kk) {
                let (diag, row) =
                    a.block_and_mut((kk, kk), (ii, kk)).unwrap();
                bdiv(diag, row, bs);
            }
        }
        // bmod phase: trailing update (allocates fill-in). The row and
        // column panels are finalised by the phases above and distinct
        // from the target (ii > kk, jj > kk), so all three borrows
        // split cleanly.
        for ii in kk + 1..nb {
            if a.is_allocated(ii, kk) {
                for jj in kk + 1..nb {
                    if a.is_allocated(kk, jj) {
                        a.allocate_clean_block(ii, jj);
                        let (row, col, inner) = a
                            .read2_write1((ii, kk), (kk, jj), (ii, jj))
                            .unwrap();
                        bmod(row, col, inner, bs);
                    }
                }
            }
        }
    }
}

/// Dense unpivoted LU (in-place, packed) — the block-size-1 oracle
/// used to validate the blocked factorisation.
pub fn dense_lu(a: &mut DenseMatrix) {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let bs = n;
    lu0(a.as_mut_slice(), bs);
}

/// Count the SparseLU task DAG for a given structure: per-elimination
/// step (kk) the number of fwd, bdiv and bmod tasks, tracking fill-in.
/// This drives the simulator workload without touching block data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LuTaskCounts {
    pub fwd: Vec<usize>,
    pub bdiv: Vec<usize>,
    pub bmod: Vec<usize>,
}

/// Walk the structure of the factorisation (fill-in included) and
/// return per-step task counts.
pub fn lu_task_counts(pattern: &[bool], nb: usize) -> LuTaskCounts {
    assert_eq!(pattern.len(), nb * nb);
    let mut alloc = pattern.to_vec();
    let mut out = LuTaskCounts {
        fwd: vec![0; nb],
        bdiv: vec![0; nb],
        bmod: vec![0; nb],
    };
    for kk in 0..nb {
        for jj in kk + 1..nb {
            if alloc[kk * nb + jj] {
                out.fwd[kk] += 1;
            }
        }
        for ii in kk + 1..nb {
            if alloc[ii * nb + kk] {
                out.bdiv[kk] += 1;
            }
        }
        for ii in kk + 1..nb {
            if alloc[ii * nb + kk] {
                for jj in kk + 1..nb {
                    if alloc[kk * nb + jj] {
                        out.bmod[kk] += 1;
                        alloc[ii * nb + jj] = true; // fill-in
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::{genmat, genmat_pattern};
    use crate::linalg::verify::{lu_residual_dense, lu_residual_sparse};

    #[test]
    fn lu0_reconstructs_2x2() {
        // A = [[4,2],[2,3]] → L=[[1,0],[.5,1]], U=[[4,2],[0,2]].
        let mut d = vec![4.0f32, 2.0, 2.0, 3.0];
        lu0(&mut d, 2);
        assert_eq!(d, vec![4.0, 2.0, 0.5, 2.0]);
    }

    #[test]
    fn dense_lu_residual_small() {
        let mut a = DenseMatrix::bots_random(16, 16, 9);
        for i in 0..16 {
            a[(i, i)] += 16.0; // diagonally dominant
        }
        let orig = a.clone();
        dense_lu(&mut a);
        let res = lu_residual_dense(&orig, &a);
        assert!(res < 1e-4, "dense LU residual {res}");
    }

    #[test]
    fn fwd_solves_unit_lower() {
        // Build L (unit lower) packed with junk U; fwd(col) must give
        // L⁻¹·col.
        let bs = 8;
        let mut diag = DenseMatrix::bots_random(bs, bs, 3);
        for i in 0..bs {
            diag[(i, i)] += bs as f32;
        }
        let orig = diag.clone();
        dense_lu(&mut diag);
        let rhs = DenseMatrix::bots_random(bs, bs, 5);
        let mut col = rhs.clone();
        fwd(diag.as_slice(), col.as_mut_slice(), bs);
        // Check L·col == rhs where L is unit-lower of `diag`.
        let mut l = DenseMatrix::eye(bs);
        for i in 0..bs {
            for j in 0..i {
                l[(i, j)] = diag[(i, j)];
            }
        }
        let lc = l.matmul(&col);
        assert!(lc.max_abs_diff(&rhs) < 1e-3);
        let _ = orig;
    }

    #[test]
    fn bdiv_solves_upper_from_right() {
        let bs = 8;
        let mut diag = DenseMatrix::bots_random(bs, bs, 4);
        for i in 0..bs {
            diag[(i, i)] += bs as f32;
        }
        dense_lu(&mut diag);
        let rhs = DenseMatrix::bots_random(bs, bs, 6);
        let mut row = rhs.clone();
        bdiv(diag.as_slice(), row.as_mut_slice(), bs);
        // Check row·U == rhs where U is upper of `diag`.
        let mut u = DenseMatrix::zeros(bs, bs);
        for i in 0..bs {
            for j in i..bs {
                u[(i, j)] = diag[(i, j)];
            }
        }
        let ru = row.matmul(&u);
        assert!(ru.max_abs_diff(&rhs) < 1e-3);
    }

    #[test]
    fn bmod_remainder_path_bit_identical_to_naive_triple_loop() {
        // Property test over bs in 1..=9 — five of which have
        // bs % 4 != 0, exercising the `chunks_exact` remainder path.
        // The 4-wide unroll runs across *distinct* elements, so every
        // element must accumulate its k-products in exactly the naive
        // ijk order: f32 bit-identity, not approximate equality.
        // Exact zeros are planted in `row` to also pin the `rik == 0`
        // skip as a no-op (skipping `x -= 0.0 * c` can only flip
        // signed zeros, which the generated inputs don't produce).
        for bs in 1..=9usize {
            let row_m = DenseMatrix::bots_random(bs, bs, 31);
            let col_m = DenseMatrix::bots_random(bs, bs, 32);
            let mut row = row_m.as_slice().to_vec();
            let col = col_m.as_slice().to_vec();
            if bs >= 3 {
                row[1] = 0.0;
                row[(bs - 1) * bs] = 0.0;
            }
            let inner0 = DenseMatrix::bots_random(bs, bs, 33)
                .as_slice()
                .to_vec();

            let mut got = inner0.clone();
            bmod(&row, &col, &mut got, bs);

            let mut want = inner0.clone();
            for i in 0..bs {
                for j in 0..bs {
                    let mut acc = want[i * bs + j];
                    for k in 0..bs {
                        acc -= row[i * bs + k] * col[k * bs + j];
                    }
                    want[i * bs + j] = acc;
                }
            }
            assert_eq!(got, want, "bmod vs naive ijk at bs={bs}");
        }
    }

    #[test]
    fn bmod_is_gemm_subtract() {
        let bs = 6;
        let a = DenseMatrix::bots_random(bs, bs, 1);
        let b = DenseMatrix::bots_random(bs, bs, 2);
        let c0 = DenseMatrix::bots_random(bs, bs, 3);
        let mut c = c0.clone();
        bmod(a.as_slice(), b.as_slice(), c.as_mut_slice(), bs);
        let ab = a.matmul(&b);
        for i in 0..bs {
            for j in 0..bs {
                let expect = c0[(i, j)] - ab[(i, j)];
                assert!((c[(i, j)] - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn sparselu_seq_matches_dense_lu() {
        // Blocked sparse LU on the dense view must equal dense LU of
        // the expanded matrix (no pivoting on either side).
        let mut a = genmat(6, 4);
        let dense0 = a.to_dense();
        sparselu_seq(&mut a);
        let mut d = dense0.clone();
        // dense blocked-size-n LU:
        dense_lu(&mut d);
        let diff = a.to_dense().max_abs_diff(&d);
        assert!(diff < 1e-2, "blocked vs dense packed LU diff {diff}");
    }

    #[test]
    fn sparselu_seq_residual() {
        let mut a = genmat(8, 8);
        let orig = a.to_dense();
        sparselu_seq(&mut a);
        let res = lu_residual_sparse(&orig, &a);
        assert!(res < 1e-4, "sparse LU residual {res}");
    }

    #[test]
    fn task_counts_track_fill_in() {
        let nb = 10;
        let counts = lu_task_counts(&genmat_pattern(nb), nb);
        // Every step has at least the superdiagonal/subdiagonal task.
        for kk in 0..nb - 1 {
            assert!(counts.fwd[kk] >= 1, "fwd[{kk}]");
            assert!(counts.bdiv[kk] >= 1, "bdiv[{kk}]");
            assert!(counts.bmod[kk] >= 1, "bmod[{kk}]");
        }
        // And bmod[kk] == fwd[kk] * bdiv[kk] by construction.
        for kk in 0..nb {
            assert_eq!(counts.bmod[kk], counts.fwd[kk] * counts.bdiv[kk]);
        }
        // Cross-check against an actual factorisation's fill-in:
        let mut a = genmat(nb, 2);
        let before = a.allocated_blocks();
        sparselu_seq(&mut a);
        assert!(a.allocated_blocks() > before, "fill-in must occur");
    }

    #[test]
    fn kernel_flops_sane() {
        assert_eq!(kernel_flops(BlockOp::Bmod, 10), 2000);
        assert_eq!(kernel_flops(BlockOp::Fwd, 10), 1000);
        assert_eq!(kernel_flops(BlockOp::Bdiv, 10), 1000);
        assert!(kernel_flops(BlockOp::Lu0, 10) < 1000);
    }
}
