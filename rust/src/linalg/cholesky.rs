//! Tiled dense Cholesky — the second workload on the kernel-agnostic
//! dataflow engine (Buttari et al., *A Class of Parallel Tiled Linear
//! Algebra Algorithms for Multicore Architectures*, arXiv:0709.1272;
//! not part of the source paper — see DIVERGENCES.md).
//!
//! Shapes: every argument is one row-major `bs×bs` block. Only the
//! lower triangle is stored and touched (`(ii, jj)` with `ii ≥ jj`;
//! diagonal blocks keep junk above their diagonal).
//!
//! * `potrf(diag)`        — in-place lower Cholesky of the diagonal
//!   block: `diag = L·Lᵀ`, `L` packed into the lower triangle.
//! * `trsm(diag, row)`    — `row ← row · L(diag)⁻ᵀ` (triangular solve
//!   from the right); applied to blocks **below** the diagonal.
//! * `syrk(panel, diag)`  — `diag ← diag − panel·panelᵀ` (symmetric
//!   rank-bs update of a trailing diagonal block, lower part only).
//! * `gemm_nt(a, b, c)`   — `c ← c − a·bᵀ` (general trailing update).
//!
//! These are the *reference* bodies — the bit-identity baseline every
//! schedule is compared against. Packed/SIMD variants of the update
//! kernels (`trsm`/`syrk`/`gemm_nt`) live in [`super::microkernel`]
//! and are bit-identical to these loops in their default mode.

use super::blocked::BlockedSparseMatrix;
use super::dense::DenseMatrix;

/// The four Cholesky block-kernel kinds (naming as in LAPACK/PLASMA).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum CholOp {
    Potrf,
    Trsm,
    Syrk,
    Gemm,
}

impl CholOp {
    pub fn name(self) -> &'static str {
        match self {
            CholOp::Potrf => "potrf",
            CholOp::Trsm => "trsm",
            CholOp::Syrk => "syrk",
            CholOp::Gemm => "gemm",
        }
    }
}

/// Approximate flop counts per kernel, used by the simulator cost
/// model and the benchmark reports (same granularity of approximation
/// as [`crate::linalg::lu::kernel_flops`]).
pub fn chol_kernel_flops(kind: CholOp, bs: usize) -> u64 {
    let b = bs as u64;
    match kind {
        CholOp::Potrf => b * b * b / 3,
        CholOp::Trsm | CholOp::Syrk => b * b * b,
        CholOp::Gemm => 2 * b * b * b,
    }
}

/// In-place lower Cholesky of one diagonal block: on return the lower
/// triangle (diagonal included) holds `L` with `diag = L·Lᵀ`; entries
/// above the diagonal are left untouched.
pub fn potrf(diag: &mut [f32], bs: usize) {
    debug_assert_eq!(diag.len(), bs * bs);
    for k in 0..bs {
        let akk = diag[k * bs + k];
        debug_assert!(akk > 0.0, "non-positive pivot {akk} at k={k}");
        let lkk = akk.sqrt();
        diag[k * bs + k] = lkk;
        for i in k + 1..bs {
            diag[i * bs + k] /= lkk;
        }
        for j in k + 1..bs {
            let ljk = diag[j * bs + k];
            if ljk == 0.0 {
                continue;
            }
            for i in j..bs {
                diag[i * bs + j] -= diag[i * bs + k] * ljk;
            }
        }
    }
}

/// Triangular solve from the right: `row ← row · L(diag)⁻ᵀ`, where `L`
/// is the lower-triangular factor packed in `diag` by [`potrf`].
/// Row-by-row forward substitution, in place.
pub fn trsm(diag: &[f32], row: &mut [f32], bs: usize) {
    debug_assert_eq!(diag.len(), bs * bs);
    debug_assert_eq!(row.len(), bs * bs);
    for r in 0..bs {
        let x = &mut row[r * bs..(r + 1) * bs];
        for c in 0..bs {
            let mut v = x[c];
            for j in 0..c {
                v -= x[j] * diag[c * bs + j];
            }
            x[c] = v / diag[c * bs + c];
        }
    }
}

/// Symmetric rank-`bs` update of a trailing diagonal block:
/// `diag ← diag − panel·panelᵀ`, lower triangle only.
pub fn syrk(panel: &[f32], diag: &mut [f32], bs: usize) {
    debug_assert_eq!(panel.len(), bs * bs);
    debug_assert_eq!(diag.len(), bs * bs);
    for i in 0..bs {
        for j in 0..=i {
            let mut acc = diag[i * bs + j];
            for k in 0..bs {
                acc -= panel[i * bs + k] * panel[j * bs + k];
            }
            diag[i * bs + j] = acc;
        }
    }
}

/// General trailing update: `c ← c − a·bᵀ`.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], bs: usize) {
    debug_assert_eq!(a.len(), bs * bs);
    debug_assert_eq!(b.len(), bs * bs);
    debug_assert_eq!(c.len(), bs * bs);
    for i in 0..bs {
        for j in 0..bs {
            let mut acc = c[i * bs + j];
            for k in 0..bs {
                acc -= a[i * bs + k] * b[j * bs + k];
            }
            c[i * bs + j] = acc;
        }
    }
}

/// Sequential tiled Cholesky — the reference every parallel schedule
/// is compared against (bit-identically: the task DAG chains all
/// touches of a block in exactly this loop order).
///
/// In place: on return the lower-triangle blocks of `a` hold `L` with
/// `A = L·Lᵀ`. The loop structure mirrors
/// [`crate::sched::TaskGraph::cholesky`] task for task.
pub fn cholesky_seq(a: &mut BlockedSparseMatrix) {
    let nb = a.nb();
    let bs = a.bs();
    for kk in 0..nb {
        potrf(a.block_mut(kk, kk).expect("diagonal block"), bs);
        for ii in kk + 1..nb {
            let (diag, row) = a.block_and_mut((kk, kk), (ii, kk)).unwrap();
            trsm(diag, row, bs);
        }
        for ii in kk + 1..nb {
            {
                let (panel, diag) =
                    a.block_and_mut((ii, kk), (ii, ii)).unwrap();
                syrk(panel, diag, bs);
            }
            for jj in kk + 1..ii {
                let (pi, pj, tgt) = a
                    .read2_write1((ii, kk), (jj, kk), (ii, jj))
                    .unwrap();
                gemm_nt(pi, pj, tgt, bs);
            }
        }
    }
}

/// Deterministic symmetric positive-definite input: values from the
/// BOTS LCG (the same generator family as `genmat`), symmetrised, with
/// the diagonal lifted to strict diagonal dominance (`+2·n`), which
/// guarantees positive definiteness and keeps the pivot-free f32
/// factorisation well-conditioned. Only the lower-triangle blocks
/// (`ii ≥ jj`) are allocated — the Cholesky drivers never touch the
/// strict upper triangle.
pub fn gen_spd(nb: usize, bs: usize) -> BlockedSparseMatrix {
    let n = nb * bs;
    let mut d = DenseMatrix::zeros(n, n);
    let mut init_val: u64 = 1325;
    for i in 0..n {
        for j in 0..=i {
            init_val = (3125 * init_val) % 65536;
            let x = (init_val as f32 - 32768.0) / 16384.0;
            d[(i, j)] = x;
            d[(j, i)] = x;
        }
    }
    for i in 0..n {
        d[(i, i)] = d[(i, i)].abs() + 2.0 * n as f32;
    }
    let mut m = BlockedSparseMatrix::empty(nb, bs);
    for ii in 0..nb {
        for jj in 0..=ii {
            let mut block = vec![0.0f32; bs * bs].into_boxed_slice();
            for r in 0..bs {
                for c in 0..bs {
                    block[r * bs + c] = d[(ii * bs + r, jj * bs + c)];
                }
            }
            m.set_block(ii, jj, block);
        }
    }
    m
}

/// Expand a lower-triangle blocked matrix to its full symmetric dense
/// form (mirroring the strictly-lower part; diagonal blocks contribute
/// their lower triangle both ways). This is the `A` the residual check
/// reconstructs `L·Lᵀ` against.
pub fn sym_dense(a: &BlockedSparseMatrix) -> DenseMatrix {
    let n = a.dim();
    let bs = a.bs();
    let mut d = DenseMatrix::zeros(n, n);
    for ii in 0..a.nb() {
        for jj in 0..=ii {
            if let Some(b) = a.block(ii, jj) {
                for r in 0..bs {
                    for c in 0..bs {
                        let (gi, gj) = (ii * bs + r, jj * bs + c);
                        if gi < gj {
                            continue; // junk above a diag block's diagonal
                        }
                        d[(gi, gj)] = b[r * bs + c];
                        d[(gj, gi)] = b[r * bs + c];
                    }
                }
            }
        }
    }
    d
}

/// Dense (block-size-`n`) lower Cholesky — the oracle used to validate
/// the blocked factorisation.
pub fn dense_cholesky(a: &mut DenseMatrix) {
    let n = a.rows();
    assert_eq!(n, a.cols());
    potrf(a.as_mut_slice(), n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::chol_residual_sparse;

    #[test]
    fn potrf_reconstructs_2x2() {
        // A = [[4,2],[2,3]] → L = [[2,0],[1,sqrt(2)]].
        let mut d = vec![4.0f32, 2.0, 2.0, 3.0];
        potrf(&mut d, 2);
        assert_eq!(d[0], 2.0);
        assert_eq!(d[2], 1.0);
        assert!((d[3] - 2.0f32.sqrt()).abs() < 1e-6);
        // Upper entry untouched.
        assert_eq!(d[1], 2.0);
    }

    #[test]
    fn trsm_solves_against_lt() {
        let bs = 8;
        let spd = gen_spd(1, bs);
        let mut diag = spd.block(0, 0).unwrap().to_vec();
        potrf(&mut diag, bs);
        let rhs = DenseMatrix::bots_random(bs, bs, 5);
        let mut row = rhs.clone();
        trsm(&diag, row.as_mut_slice(), bs);
        // Check row · Lᵀ == rhs.
        let mut l = DenseMatrix::zeros(bs, bs);
        for i in 0..bs {
            for j in 0..=i {
                l[(i, j)] = diag[i * bs + j];
            }
        }
        let mut lt = DenseMatrix::zeros(bs, bs);
        for i in 0..bs {
            for j in 0..bs {
                lt[(i, j)] = l[(j, i)];
            }
        }
        let back = row.matmul(&lt);
        assert!(back.max_abs_diff(&rhs) < 1e-3);
    }

    #[test]
    fn syrk_matches_gemm_on_lower() {
        let bs = 6;
        let p = DenseMatrix::bots_random(bs, bs, 2);
        let c0 = DenseMatrix::bots_random(bs, bs, 3);
        let mut c_syrk = c0.clone();
        syrk(p.as_slice(), c_syrk.as_mut_slice(), bs);
        let mut c_gemm = c0.clone();
        gemm_nt(p.as_slice(), p.as_slice(), c_gemm.as_mut_slice(), bs);
        for i in 0..bs {
            for j in 0..bs {
                if j <= i {
                    assert_eq!(c_syrk[(i, j)], c_gemm[(i, j)]);
                } else {
                    assert_eq!(c_syrk[(i, j)], c0[(i, j)], "upper touched");
                }
            }
        }
    }

    #[test]
    fn gemm_nt_is_a_bt_subtract() {
        let bs = 5;
        let a = DenseMatrix::bots_random(bs, bs, 1);
        let b = DenseMatrix::bots_random(bs, bs, 2);
        let c0 = DenseMatrix::bots_random(bs, bs, 3);
        let mut c = c0.clone();
        gemm_nt(a.as_slice(), b.as_slice(), c.as_mut_slice(), bs);
        for i in 0..bs {
            for j in 0..bs {
                let mut want = c0[(i, j)];
                for k in 0..bs {
                    want -= a[(i, k)] * b[(j, k)];
                }
                assert!((c[(i, j)] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn gen_spd_is_symmetric_and_dominant() {
        let m = gen_spd(4, 3);
        let n = m.dim();
        let d = sym_dense(&m);
        for i in 0..n {
            let mut off = 0.0f64;
            for j in 0..n {
                assert_eq!(d[(i, j)], d[(j, i)]);
                if i != j {
                    off += d[(i, j)].abs() as f64;
                }
            }
            assert!(
                d[(i, i)] as f64 > off,
                "row {i} not diagonally dominant"
            );
        }
        // Only lower-triangle blocks allocated.
        for ii in 0..4 {
            for jj in 0..4 {
                assert_eq!(m.is_allocated(ii, jj), ii >= jj);
            }
        }
    }

    #[test]
    fn blocked_matches_dense_oracle() {
        let mut a = gen_spd(5, 4);
        let mut d = sym_dense(&a);
        cholesky_seq(&mut a);
        dense_cholesky(&mut d);
        let n = d.rows();
        let bs = a.bs();
        let mut worst = 0.0f32;
        for i in 0..n {
            for j in 0..=i {
                let b = a.block(i / bs, j / bs).unwrap();
                let got = b[(i % bs) * bs + (j % bs)];
                worst = worst.max((got - d[(i, j)]).abs());
            }
        }
        assert!(worst < 1e-2, "blocked vs dense L diff {worst}");
    }

    #[test]
    fn cholesky_seq_residual() {
        let mut a = gen_spd(6, 5);
        let orig = sym_dense(&a);
        cholesky_seq(&mut a);
        let res = chol_residual_sparse(&orig, &a);
        assert!(res < 1e-5, "cholesky residual {res}");
    }

    #[test]
    fn chol_flops_sane() {
        assert_eq!(chol_kernel_flops(CholOp::Gemm, 10), 2000);
        assert_eq!(chol_kernel_flops(CholOp::Trsm, 10), 1000);
        assert_eq!(chol_kernel_flops(CholOp::Syrk, 10), 1000);
        assert!(chol_kernel_flops(CholOp::Potrf, 10) < 1000);
    }
}
