//! Startup autotuner for block sizes: sweep candidate tile/block
//! sizes per registry workload with a short calibration pass and
//! cache the winner in the workload registry.
//!
//! Block size is the one knob the tiled algorithms are sharply
//! sensitive to (Buttari et al.): too small and per-task dispatch
//! overhead swamps the `O(bs³)` kernels; too large and the working
//! set spills L1 and the DAG loses parallelism. The tuner holds the
//! *matrix* size `n = nb·bs` fixed, re-derives `(nb, bs)` for each
//! candidate, and scores each point with a [`Calibrator`]:
//!
//! * [`HostCalibrator`] times the workload's flop-dominant block
//!   kernel on this machine with a short warm calibration run and
//!   extrapolates over the graph's total flops — a real measurement,
//!   and the default behind `--autotune on` ([`cli_calibrator`] is
//!   the CLI's routing table). If the host clock cannot resolve the
//!   calibration kernel it falls back to the model below;
//! * [`ModelCalibrator`] prices the full task graph on the TILEPro64
//!   cycle model ([`CostModel`]) — deterministic, instant, selected
//!   by `--autotune model` and used by the harness `kernels`
//!   experiment (which asserts exact modelled crossovers, so it must
//!   not depend on host noise).
//!
//! The winner is cached per registry entry via
//! [`crate::sched::workload::set_tuned_bs`]; tuned sizes only ever
//! select among bit-identical-by-construction kernel configurations,
//! so autotuning cannot affect conformance (proved by the
//! `tests/microkernel.rs` conformance run).

use crate::bench::black_box;
use crate::linalg::dense::DenseMatrix;
use crate::linalg::microkernel::{simd_level, KernelMode, SimdLevel};
use crate::sched::workload::{registry, set_tuned_bs, Params, Workload};
use crate::tilesim::cost::CostModel;
use std::time::Instant;

/// Candidate block sizes the tuner sweeps. Powers of two from
/// "dispatch-bound" to "past the L1 spill point", bracketing the
/// useful range on both sides so the optimum is interior.
pub const CANDIDATE_BS: [usize; 4] = [4, 8, 16, 32];

/// Scores one `(workload, sizing)` point; lower is better. Units are
/// calibrator-specific (cycles for the model, seconds for the host) —
/// only comparisons at fixed `n` are meaningful.
pub trait Calibrator {
    fn cost(&self, w: &dyn Workload, p: &Params) -> f64;
    fn name(&self) -> &'static str;
}

/// Deterministic calibrator on the TILEPro64 cycle model: every task
/// is priced as kernel cycles (scalar or packed/SIMD by op) plus the
/// GPRM dispatch cost, divided by the worker count (the tuner ranks
/// total work + overhead; DAG shape effects are second-order for
/// ranking block sizes).
pub struct ModelCalibrator {
    pub cost: CostModel,
    pub workers: usize,
    /// Price the update kernels on the packed/SIMD path.
    pub simd: bool,
    /// Apply the fast-mode ILP gain on top of the SIMD path.
    pub fast: bool,
}

impl ModelCalibrator {
    /// Defaults: the stock cost model, SIMD pricing iff the running
    /// build actually dispatches vector kernels.
    pub fn new(workers: usize) -> Self {
        Self {
            cost: CostModel::default(),
            workers: workers.max(1),
            simd: simd_level() != SimdLevel::Scalar,
            fast: false,
        }
    }
}

/// The ops the microkernel layer vectorises; everything else is
/// priced scalar.
pub fn is_vectorised(op_name: &str) -> bool {
    matches!(op_name, "bmod" | "gemm" | "syrk" | "trsm" | "madd")
}

impl Calibrator for ModelCalibrator {
    fn cost(&self, w: &dyn Workload, p: &Params) -> f64 {
        let g = w.graph(p);
        let dispatch = self.cost.gprm_packet + self.cost.gprm_task_fire;
        let mut total = 0.0;
        for t in g.tasks() {
            let flops = w.flops(t.op, p.bs);
            let kernel = if self.simd
                && is_vectorised(w.ops()[t.op.0].name)
            {
                self.cost.kernel_simd(flops, p.bs, self.fast)
            } else {
                self.cost.kernel_scalar(flops, p.bs)
            };
            total += kernel + dispatch;
        }
        total / self.workers as f64
    }

    fn name(&self) -> &'static str {
        "model"
    }
}

/// Host-clock calibrator: finds the op contributing the most total
/// flops to the graph (always one of the `O(bs³)` update kernels on
/// real sizings), times that kernel on random operands with a warmup,
/// and charges the graph's total flops at the measured rate. Short by
/// construction — one kernel, a handful of reps, per candidate.
pub struct HostCalibrator {
    pub reps: u32,
}

impl HostCalibrator {
    pub fn new() -> Self {
        Self { reps: 5 }
    }
}

impl Calibrator for HostCalibrator {
    fn cost(&self, w: &dyn Workload, p: &Params) -> f64 {
        let g = w.graph(p);
        let bs = p.bs;
        let nops = w.ops().len();
        let mut op_flops = vec![0u64; nops];
        let mut op_arity = vec![0usize; nops];
        for t in g.tasks() {
            op_flops[t.op.0] += w.flops(t.op, bs);
            op_arity[t.op.0] = t.reads().len();
        }
        let dom = op_flops
            .iter()
            .enumerate()
            .max_by_key(|&(_, f)| *f)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let kernel = w.kernels_for(KernelMode::BitIdentical)[dom];
        let srcs: Vec<Vec<f32>> = (0..2)
            .map(|s| {
                DenseMatrix::bots_random(bs, bs, 71 + s)
                    .as_slice()
                    .to_vec()
            })
            .collect();
        let reads: Vec<&[f32]> =
            srcs[..op_arity[dom]].iter().map(|b| b.as_slice()).collect();
        let mut write = DenseMatrix::bots_random(bs, bs, 73)
            .as_slice()
            .to_vec();
        for _ in 0..2 {
            kernel(&reads, &mut write, bs); // warmup
        }
        let t0 = Instant::now();
        for _ in 0..self.reps.max(1) {
            kernel(&reads, &mut write, bs);
        }
        black_box(&write);
        let per_call =
            t0.elapsed().as_secs_f64() / f64::from(self.reps.max(1));
        if per_call <= 0.0 || !per_call.is_finite() {
            // The host clock could not resolve the kernel (coarse
            // timer, or a degenerate sizing finished below tick
            // granularity): fall back to the deterministic model so
            // `--autotune on` always ranks candidates meaningfully.
            return ModelCalibrator::new(1).cost(w, p);
        }
        let per_call_flops =
            (w.ops()[dom].flops)(bs).max(1) as f64;
        w.graph_flops(&g, bs) as f64 * (per_call / per_call_flops)
    }

    fn name(&self) -> &'static str {
        "host"
    }
}

/// Outcome of one tuning sweep: every candidate scored, plus the
/// winner. `candidates` keeps `(bs, cost)` in sweep order for the
/// sensitivity table.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub workload: &'static str,
    pub n: usize,
    pub candidates: Vec<(usize, f64)>,
    pub best_bs: usize,
}

impl TuneResult {
    /// Cost of candidate `bs`, if it was swept.
    pub fn cost_of(&self, bs: usize) -> Option<f64> {
        self.candidates
            .iter()
            .find(|&&(b, _)| b == bs)
            .map(|&(_, c)| c)
    }
}

/// Sweep [`CANDIDATE_BS`] for workload `w` at fixed matrix size `n`,
/// skipping candidates that don't divide `n` or leave fewer than two
/// blocks per dimension (no DAG to schedule). Falls back to the
/// single-block sizing if nothing qualifies, so the result always
/// names a runnable `best_bs`.
pub fn tune(
    w: &dyn Workload,
    n: usize,
    cal: &dyn Calibrator,
) -> TuneResult {
    let mut candidates = Vec::new();
    for &bs in &CANDIDATE_BS {
        if n % bs != 0 || n / bs < 2 {
            continue;
        }
        let p = Params::new(n / bs, bs);
        candidates.push((bs, cal.cost(w, &p)));
    }
    if candidates.is_empty() {
        candidates.push((n, cal.cost(w, &Params::new(1, n))));
    }
    let best_bs = candidates
        .iter()
        .fold((candidates[0].0, f64::INFINITY), |acc, &(bs, c)| {
            if c < acc.1 {
                (bs, c)
            } else {
                acc
            }
        })
        .0;
    TuneResult { workload: w.name(), n, candidates, best_bs }
}

/// The CLI's `--autotune` routing table: `"on"` selects the
/// runtime-measured [`HostCalibrator`] (the default tuning path —
/// real block kernels on this machine), `"model"` the deterministic
/// [`ModelCalibrator`] at `workers` workers. Anything else (including
/// `"off"`, which the CLI handles before tuning) is `None`.
pub fn cli_calibrator(
    mode: &str,
    workers: usize,
) -> Option<Box<dyn Calibrator>> {
    match mode {
        "on" => Some(Box::new(HostCalibrator::new())),
        "model" => Some(Box::new(ModelCalibrator::new(workers))),
        _ => None,
    }
}

/// The startup pass behind `--autotune on`: tune every registered
/// workload at matrix size `n` and cache each winner in the registry
/// (see [`crate::sched::workload::tuned_bs`]).
pub fn autotune_registry(
    n: usize,
    cal: &dyn Calibrator,
) -> Vec<TuneResult> {
    registry()
        .iter()
        .map(|w| {
            let r = tune(*w, n, cal);
            set_tuned_bs(*w, r.best_bs);
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::workload::{
        clear_tuned_bs, tuned_bs, TUNED_LOCK,
    };

    fn model(simd: bool, fast: bool) -> ModelCalibrator {
        ModelCalibrator {
            cost: CostModel::default(),
            workers: 1,
            simd,
            fast,
        }
    }

    #[test]
    fn tuner_finds_an_interior_optimum() {
        // The model brackets the optimum by construction: bs=4 is
        // dispatch-bound (210 cycles per ~b³-cycle task), bs=32
        // spills L1 (3×). The winner must be interior, with strictly
        // worse costs at both extremes — for every workload, with and
        // without SIMD pricing.
        for simd in [false, true] {
            let cal = model(simd, false);
            for w in registry() {
                let r = tune(*w, 128, &cal);
                assert!(
                    r.best_bs == 8 || r.best_bs == 16,
                    "{} simd={simd}: best {}",
                    w.name(),
                    r.best_bs
                );
                let best = r.cost_of(r.best_bs).unwrap();
                assert!(r.cost_of(4).unwrap() > best);
                assert!(r.cost_of(32).unwrap() > best);
            }
        }
    }

    #[test]
    fn model_simd_never_slower_at_useful_sizes() {
        // Acceptance shape for the harness: at bs >= 8 the packed
        // path must not model slower than scalar for any workload.
        for w in registry() {
            for bs in [8usize, 16, 32] {
                let p = Params::new(4, bs);
                let scalar = model(false, false).cost(*w, &p);
                let simd = model(true, false).cost(*w, &p);
                let fast = model(true, true).cost(*w, &p);
                assert!(
                    simd <= scalar,
                    "{} bs={bs}: simd {simd} > scalar {scalar}",
                    w.name()
                );
                assert!(fast <= simd, "{} bs={bs}", w.name());
            }
        }
    }

    #[test]
    fn tune_skips_non_divisible_and_degenerate_sizings() {
        let cal = model(false, false);
        let r = tune(&crate::sched::workload::Cholesky, 24, &cal);
        let swept: Vec<usize> =
            r.candidates.iter().map(|&(b, _)| b).collect();
        // 24 % 16 != 0; 24/32 < 1; 24/16 < 2 anyway.
        assert_eq!(swept, vec![4, 8]);
        // Nothing qualifies at n=6: fall back to one block.
        let r = tune(&crate::sched::workload::Cholesky, 6, &cal);
        assert_eq!(r.best_bs, 6);
        assert_eq!(r.candidates.len(), 1);
    }

    #[test]
    fn autotune_registry_caches_winners() {
        let _g = TUNED_LOCK.lock().unwrap();
        clear_tuned_bs();
        let results = autotune_registry(64, &model(true, false));
        assert_eq!(results.len(), registry().len());
        for (w, r) in registry().iter().zip(&results) {
            assert_eq!(w.name(), r.workload);
            assert_eq!(tuned_bs(*w), Some(r.best_bs));
        }
        clear_tuned_bs();
    }

    #[test]
    fn cli_flag_routes_on_to_the_host_calibrator() {
        // The satellite's acceptance: `--autotune on` must reach the
        // runtime-measured path, `model` the deterministic one, and
        // anything else (incl. `off`) must route nowhere.
        assert_eq!(cli_calibrator("on", 4).unwrap().name(), "host");
        assert_eq!(cli_calibrator("model", 4).unwrap().name(), "model");
        assert!(cli_calibrator("off", 4).is_none());
        assert!(cli_calibrator("sideways", 4).is_none());
    }

    #[test]
    fn host_calibrator_orders_total_work() {
        // A real-clock smoke: more blocks of the same size means more
        // measured work. Compare two sizings differing only in nb —
        // monotone in graph flops by construction, robust to noise
        // because the per-flop rate is identical (same timed kernel).
        let cal = HostCalibrator::new();
        let w = &crate::sched::workload::Matmul;
        let small = cal.cost(w, &Params::new(2, 8));
        let large = cal.cost(w, &Params::new(4, 8));
        assert!(small > 0.0 && large > 0.0);
        assert!(large > small, "large {large} <= small {small}");
    }
}
