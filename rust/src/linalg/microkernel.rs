//! SIMD microkernel layer: packed, register-blocked f32 inner kernels
//! for the block-update operations (`bmod`, `gemm_nt`, `syrk`, `trsm`,
//! `madd`), with explicit precision policy and runtime CPU dispatch.
//!
//! # Why a layer, not a rewrite
//!
//! The factorisation kernels in [`super::lu`] / [`super::cholesky`]
//! are the *reference semantics*: every scheduler claim in this repo
//! rests on parallel results being **bit-identical (f32)** to those
//! sequential loops. This module adds faster bodies for the hot
//! *update* kernels only — the rank-`bs` GEMM-like operations that
//! dominate flop counts — and leaves the recurrence kernels (`lu0`,
//! `potrf`, `fwd`, `bdiv`) on their scalar reference: their pivot /
//! square-root dependences and zero-skip short-circuits gain little
//! from lanes and are where bit drift would be hardest to reason
//! about.
//!
//! # Precision policy
//!
//! | mode | accumulation order | verified by | default? |
//! |------|--------------------|-------------|----------|
//! | [`KernelMode::BitIdentical`] | reference order, lanes across independent elements | `==` on f32 bits vs the scalar reference | **yes** (conformance) |
//! | [`KernelMode::Fast`] | `k` processed in pairs (two-term sums), zero-skips dropped | relative residual `<= 1e-5` vs the reference | opt-in (`--kernels fast`) |
//!
//! The bit-identical vector paths work because each SIMD lane performs
//! exactly the scalar per-element operation sequence: a lane computes
//! `d - s·x` (one rounding per op, no FMA), and vectorisation runs
//! across *independent* output elements — `j` columns of an update
//! row, or independent rows of a triangular solve — never across the
//! `k`-reduction, whose f32 addition order is the contract. Where the
//! reference strides non-unit (`b[j,k]` in `gemm_nt`, `diag` columns
//! in `trsm`), the operand is transpose-packed into a [`PackedTile`]
//! first; an f32 store/reload is exact, so packing never perturbs a
//! result. `Fast` instead restructures the reduction itself
//! (`x−(a+b)` vs `((x−a)−b)`) for instruction-level parallelism; it
//! also drops `bmod`'s `rik == 0` skip, which can flip a `-0.0` to
//! `+0.0` — hence residual-bounded, never bit-compared (see
//! DIVERGENCES.md).
//!
//! # Dispatch
//!
//! [`simd_level`] detects SSE2/AVX once at startup (cached) when the
//! crate is built with `--features simd` on x86-64; every other build
//! reports [`SimdLevel::Scalar`]. In a scalar build the
//! `BitIdentical` entry points call the original reference kernels
//! *verbatim*, so the default build's behaviour is byte-for-byte the
//! pre-microkernel code path. Block-size autotuning on top of these
//! kernels lives in [`super::autotune`].

use super::cholesky::{gemm_nt, syrk, trsm};
use super::lu::bmod;

/// Precision policy for kernel dispatch (see the module docs' table).
/// `BitIdentical` is the conformance default everywhere.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum KernelMode {
    /// Reference accumulation order; results are f32-bit-equal to the
    /// sequential reference kernels on every build and SIMD level.
    #[default]
    BitIdentical,
    /// Paired-`k` (two-term) accumulation, zero-skips dropped:
    /// faster reduction with more ILP, verified by residual bound
    /// (`<= 1e-5` relative) instead of bit equality.
    Fast,
}

impl KernelMode {
    /// CLI value (`--kernels bit|fast`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "bit" => Some(KernelMode::BitIdentical),
            "fast" => Some(KernelMode::Fast),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelMode::BitIdentical => "bit",
            KernelMode::Fast => "fast",
        }
    }
}

/// Vector instruction set selected at runtime. Non-x86-64 targets and
/// builds without `--features simd` always run `Scalar`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdLevel {
    Scalar,
    Sse2,
    Avx,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx => "avx",
        }
    }
}

/// Runtime CPU detection, cached after the first call.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_level() -> SimdLevel {
    use std::sync::atomic::{AtomicU8, Ordering};
    static LEVEL: AtomicU8 = AtomicU8::new(0); // 0 = undetected
    match LEVEL.load(Ordering::Relaxed) {
        1 => SimdLevel::Scalar,
        2 => SimdLevel::Sse2,
        3 => SimdLevel::Avx,
        _ => {
            let (l, tag) = if is_x86_feature_detected!("avx") {
                (SimdLevel::Avx, 3)
            } else if is_x86_feature_detected!("sse2") {
                (SimdLevel::Sse2, 2)
            } else {
                (SimdLevel::Scalar, 1)
            };
            LEVEL.store(tag, Ordering::Relaxed);
            l
        }
    }
}

/// Runtime CPU detection: always `Scalar` without the `simd` feature
/// (or off x86-64), so the default build never touches `std::arch`.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_level() -> SimdLevel {
    SimdLevel::Scalar
}

// ---------------------------------------------------------------------
// Packed tile storage
// ---------------------------------------------------------------------

/// A `bs×bs` tile copied into contiguous, unit-stride panel storage.
///
/// [`PackedTile::pack`] preserves row-major layout (a row panel);
/// [`PackedTile::pack_transposed`] stores the transpose, turning a
/// column access pattern (`src[j·bs + k]` over `j`) into a unit-stride
/// row sweep (`row(k)[j]`) the vector helpers can stream. Packing is
/// a pure f32 copy — store/reload is exact — so packed kernels stay
/// bit-identical to their unpacked reference.
#[derive(Clone, Debug)]
pub struct PackedTile {
    data: Vec<f32>,
    bs: usize,
}

impl PackedTile {
    /// Pack row-major (identity layout; contiguous panel copy).
    pub fn pack(src: &[f32], bs: usize) -> Self {
        debug_assert_eq!(src.len(), bs * bs);
        Self { data: src.to_vec(), bs }
    }

    /// Pack the transpose: `packed[k·bs + j] = src[j·bs + k]`.
    pub fn pack_transposed(src: &[f32], bs: usize) -> Self {
        debug_assert_eq!(src.len(), bs * bs);
        let mut data = vec![0.0f32; bs * bs];
        for j in 0..bs {
            for k in 0..bs {
                data[k * bs + j] = src[j * bs + k];
            }
        }
        Self { data, bs }
    }

    pub fn bs(&self) -> usize {
        self.bs
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// One packed panel row (unit stride).
    pub fn row(&self, k: usize) -> &[f32] {
        &self.data[k * self.bs..(k + 1) * self.bs]
    }

    pub fn row_mut(&mut self, k: usize) -> &mut [f32] {
        &mut self.data[k * self.bs..(k + 1) * self.bs]
    }

    /// Split-borrow row `w` mutably together with an earlier row
    /// `r < w` immutably (the triangular-solve sweep's access shape).
    pub fn row_pair_mut(
        &mut self,
        w: usize,
        r: usize,
    ) -> (&mut [f32], &[f32]) {
        debug_assert!(r < w, "read row must precede the written row");
        let bs = self.bs;
        let (lo, hi) = self.data.split_at_mut(w * bs);
        (&mut hi[..bs], &lo[r * bs..(r + 1) * bs])
    }

    /// Undo [`PackedTile::pack`]: copy back row-major.
    pub fn unpack_into(&self, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), self.bs * self.bs);
        dst.copy_from_slice(&self.data);
    }

    /// Undo [`PackedTile::pack_transposed`]:
    /// `dst[j·bs + k] = packed[k·bs + j]`.
    pub fn unpack_transposed_into(&self, dst: &mut [f32]) {
        let bs = self.bs;
        debug_assert_eq!(dst.len(), bs * bs);
        for k in 0..bs {
            for j in 0..bs {
                dst[j * bs + k] = self.data[k * bs + j];
            }
        }
    }
}

// ---------------------------------------------------------------------
// Vector helpers: the entire intrinsic surface of the crate
// ---------------------------------------------------------------------
//
// Three operations (and their two-term "fast" forms), each with a
// scalar body, an SSE2 body and an AVX body. Every lane computes the
// exact scalar per-element sequence — mul then sub/add (no FMA), or
// mul+mul+add then sub/add for the paired forms — so a vector call is
// bit-equal to its scalar body on the same inputs, in either mode.

#[inline]
fn axpy_sub_scalar(dst: &mut [f32], src: &[f32], s: f32) {
    for (d, &x) in dst.iter_mut().zip(src) {
        *d -= s * x;
    }
}

#[inline]
fn axpy_add_scalar(dst: &mut [f32], src: &[f32], s: f32) {
    for (d, &x) in dst.iter_mut().zip(src) {
        *d += s * x;
    }
}

#[inline]
fn axpy2_sub_scalar(
    dst: &mut [f32],
    s0: f32,
    x0: &[f32],
    s1: f32,
    x1: &[f32],
) {
    for ((d, &a), &b) in dst.iter_mut().zip(x0).zip(x1) {
        *d -= s0 * a + s1 * b;
    }
}

#[inline]
fn axpy2_add_scalar(
    dst: &mut [f32],
    s0: f32,
    x0: &[f32],
    s1: f32,
    x1: &[f32],
) {
    for ((d, &a), &b) in dst.iter_mut().zip(x0).zip(x1) {
        *d += s0 * a + s1 * b;
    }
}

#[inline]
fn div_by_scalar(dst: &mut [f32], s: f32) {
    for d in dst.iter_mut() {
        *d /= s;
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    //! SSE2/AVX bodies. Each streams 4- (resp. 8-)wide over the
    //! unit-stride slices with unaligned loads/stores and finishes the
    //! remainder scalar — per element the operation sequence matches
    //! the scalar helper exactly (IEEE mul/add/sub/div, no FMA).
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified SSE2 support (see [`super::simd_level`]).
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sub_sse2(dst: &mut [f32], src: &[f32], s: f32) {
        let n = dst.len().min(src.len());
        let vs = _mm_set1_ps(s);
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_loadu_ps(dst.as_ptr().add(i));
            let x = _mm_loadu_ps(src.as_ptr().add(i));
            _mm_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm_sub_ps(d, _mm_mul_ps(vs, x)),
            );
            i += 4;
        }
        while i < n {
            dst[i] -= s * src[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified SSE2 support.
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_add_sse2(dst: &mut [f32], src: &[f32], s: f32) {
        let n = dst.len().min(src.len());
        let vs = _mm_set1_ps(s);
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_loadu_ps(dst.as_ptr().add(i));
            let x = _mm_loadu_ps(src.as_ptr().add(i));
            _mm_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm_add_ps(d, _mm_mul_ps(vs, x)),
            );
            i += 4;
        }
        while i < n {
            dst[i] += s * src[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified SSE2 support.
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy2_sub_sse2(
        dst: &mut [f32],
        s0: f32,
        x0: &[f32],
        s1: f32,
        x1: &[f32],
    ) {
        let n = dst.len().min(x0.len()).min(x1.len());
        let v0 = _mm_set1_ps(s0);
        let v1 = _mm_set1_ps(s1);
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_loadu_ps(dst.as_ptr().add(i));
            let a = _mm_loadu_ps(x0.as_ptr().add(i));
            let b = _mm_loadu_ps(x1.as_ptr().add(i));
            let t =
                _mm_add_ps(_mm_mul_ps(v0, a), _mm_mul_ps(v1, b));
            _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_sub_ps(d, t));
            i += 4;
        }
        while i < n {
            dst[i] -= s0 * x0[i] + s1 * x1[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified SSE2 support.
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy2_add_sse2(
        dst: &mut [f32],
        s0: f32,
        x0: &[f32],
        s1: f32,
        x1: &[f32],
    ) {
        let n = dst.len().min(x0.len()).min(x1.len());
        let v0 = _mm_set1_ps(s0);
        let v1 = _mm_set1_ps(s1);
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_loadu_ps(dst.as_ptr().add(i));
            let a = _mm_loadu_ps(x0.as_ptr().add(i));
            let b = _mm_loadu_ps(x1.as_ptr().add(i));
            let t =
                _mm_add_ps(_mm_mul_ps(v0, a), _mm_mul_ps(v1, b));
            _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_add_ps(d, t));
            i += 4;
        }
        while i < n {
            dst[i] += s0 * x0[i] + s1 * x1[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified SSE2 support. (IEEE division is
    /// exactly rounded, so `_mm_div_ps` is bit-equal to scalar `/`.)
    #[target_feature(enable = "sse2")]
    pub unsafe fn div_by_sse2(dst: &mut [f32], s: f32) {
        let n = dst.len();
        let vs = _mm_set1_ps(s);
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm_loadu_ps(dst.as_ptr().add(i));
            _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_div_ps(d, vs));
            i += 4;
        }
        while i < n {
            dst[i] /= s;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy_sub_avx(dst: &mut [f32], src: &[f32], s: f32) {
        let n = dst.len().min(src.len());
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let x = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm256_sub_ps(d, _mm256_mul_ps(vs, x)),
            );
            i += 8;
        }
        while i < n {
            dst[i] -= s * src[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy_add_avx(dst: &mut [f32], src: &[f32], s: f32) {
        let n = dst.len().min(src.len());
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let x = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm256_add_ps(d, _mm256_mul_ps(vs, x)),
            );
            i += 8;
        }
        while i < n {
            dst[i] += s * src[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy2_sub_avx(
        dst: &mut [f32],
        s0: f32,
        x0: &[f32],
        s1: f32,
        x1: &[f32],
    ) {
        let n = dst.len().min(x0.len()).min(x1.len());
        let v0 = _mm256_set1_ps(s0);
        let v1 = _mm256_set1_ps(s1);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let a = _mm256_loadu_ps(x0.as_ptr().add(i));
            let b = _mm256_loadu_ps(x1.as_ptr().add(i));
            let t = _mm256_add_ps(
                _mm256_mul_ps(v0, a),
                _mm256_mul_ps(v1, b),
            );
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm256_sub_ps(d, t),
            );
            i += 8;
        }
        while i < n {
            dst[i] -= s0 * x0[i] + s1 * x1[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy2_add_avx(
        dst: &mut [f32],
        s0: f32,
        x0: &[f32],
        s1: f32,
        x1: &[f32],
    ) {
        let n = dst.len().min(x0.len()).min(x1.len());
        let v0 = _mm256_set1_ps(s0);
        let v1 = _mm256_set1_ps(s1);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let a = _mm256_loadu_ps(x0.as_ptr().add(i));
            let b = _mm256_loadu_ps(x1.as_ptr().add(i));
            let t = _mm256_add_ps(
                _mm256_mul_ps(v0, a),
                _mm256_mul_ps(v1, b),
            );
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm256_add_ps(d, t),
            );
            i += 8;
        }
        while i < n {
            dst[i] += s0 * x0[i] + s1 * x1[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support.
    #[target_feature(enable = "avx")]
    pub unsafe fn div_by_avx(dst: &mut [f32], s: f32) {
        let n = dst.len();
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm256_div_ps(d, vs),
            );
            i += 8;
        }
        while i < n {
            dst[i] /= s;
            i += 1;
        }
    }
}

#[inline]
fn axpy_sub(level: SimdLevel, dst: &mut [f32], src: &[f32], s: f32) {
    match level {
        SimdLevel::Scalar => axpy_sub_scalar(dst, src, s),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe { x86::axpy_sub_sse2(dst, src, s) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx => unsafe { x86::axpy_sub_avx(dst, src, s) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => axpy_sub_scalar(dst, src, s),
    }
}

#[inline]
fn axpy_add(level: SimdLevel, dst: &mut [f32], src: &[f32], s: f32) {
    match level {
        SimdLevel::Scalar => axpy_add_scalar(dst, src, s),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe { x86::axpy_add_sse2(dst, src, s) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx => unsafe { x86::axpy_add_avx(dst, src, s) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => axpy_add_scalar(dst, src, s),
    }
}

#[inline]
fn axpy2_sub(
    level: SimdLevel,
    dst: &mut [f32],
    s0: f32,
    x0: &[f32],
    s1: f32,
    x1: &[f32],
) {
    match level {
        SimdLevel::Scalar => axpy2_sub_scalar(dst, s0, x0, s1, x1),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe {
            x86::axpy2_sub_sse2(dst, s0, x0, s1, x1)
        },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx => unsafe {
            x86::axpy2_sub_avx(dst, s0, x0, s1, x1)
        },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => axpy2_sub_scalar(dst, s0, x0, s1, x1),
    }
}

#[inline]
fn axpy2_add(
    level: SimdLevel,
    dst: &mut [f32],
    s0: f32,
    x0: &[f32],
    s1: f32,
    x1: &[f32],
) {
    match level {
        SimdLevel::Scalar => axpy2_add_scalar(dst, s0, x0, s1, x1),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe {
            x86::axpy2_add_sse2(dst, s0, x0, s1, x1)
        },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx => unsafe {
            x86::axpy2_add_avx(dst, s0, x0, s1, x1)
        },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => axpy2_add_scalar(dst, s0, x0, s1, x1),
    }
}

#[inline]
fn div_by(level: SimdLevel, dst: &mut [f32], s: f32) {
    match level {
        SimdLevel::Scalar => div_by_scalar(dst, s),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe { x86::div_by_sse2(dst, s) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx => unsafe { x86::div_by_avx(dst, s) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => div_by_scalar(dst, s),
    }
}

// ---------------------------------------------------------------------
// Reference madd (moved here from the workload module: the microkernel
// layer owns every flavour of the update kernels)
// ---------------------------------------------------------------------

/// The `madd` block kernel: `c += a·b` on row-major `bs×bs` blocks,
/// j-inner accumulation. The sequential reference uses the identical
/// loop, which is what makes every edge-respecting schedule
/// bit-identical (f32) to it.
pub fn madd(a: &[f32], b: &[f32], c: &mut [f32], bs: usize) {
    debug_assert!(
        a.len() == bs * bs && b.len() == bs * bs && c.len() == bs * bs
    );
    for i in 0..bs {
        for j in 0..bs {
            let mut acc = c[i * bs + j];
            for k in 0..bs {
                acc += a[i * bs + k] * b[k * bs + j];
            }
            c[i * bs + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------
// Mode-dispatching microkernels
// ---------------------------------------------------------------------

/// `bmod` microkernel: `inner ← inner − row·col` (Schur update).
///
/// Bit-identical path: the reference [`bmod`] is already ikj with the
/// j-loop streaming `col` rows unit-stride, so the vector form is a
/// direct `axpy` per `(i, k)` — same per-element sequence, `rik == 0`
/// skip preserved. Fast path: paired-`k` two-term updates, skip
/// dropped.
pub fn bmod_mk(
    mode: KernelMode,
    row: &[f32],
    col: &[f32],
    inner: &mut [f32],
    bs: usize,
) {
    debug_assert!(
        row.len() == bs * bs
            && col.len() == bs * bs
            && inner.len() == bs * bs
    );
    let level = simd_level();
    match mode {
        KernelMode::BitIdentical => {
            if level == SimdLevel::Scalar {
                return bmod(row, col, inner, bs);
            }
            for i in 0..bs {
                let irow = &mut inner[i * bs..(i + 1) * bs];
                for k in 0..bs {
                    let rik = row[i * bs + k];
                    if rik == 0.0 {
                        continue;
                    }
                    axpy_sub(
                        level,
                        irow,
                        &col[k * bs..(k + 1) * bs],
                        rik,
                    );
                }
            }
        }
        KernelMode::Fast => {
            for i in 0..bs {
                let irow = &mut inner[i * bs..(i + 1) * bs];
                let mut k = 0;
                while k + 1 < bs {
                    axpy2_sub(
                        level,
                        irow,
                        row[i * bs + k],
                        &col[k * bs..(k + 1) * bs],
                        row[i * bs + k + 1],
                        &col[(k + 1) * bs..(k + 2) * bs],
                    );
                    k += 2;
                }
                if k < bs {
                    axpy_sub(
                        level,
                        irow,
                        &col[k * bs..(k + 1) * bs],
                        row[i * bs + k],
                    );
                }
            }
        }
    }
}

/// `gemm_nt` microkernel: `c ← c − a·bᵀ`.
///
/// The reference reads `b[j,k]` column-wise; the packed form
/// transposes `b` once ([`PackedTile::pack_transposed`]) and runs ikj
/// with unit-stride j-sweeps. Each `c[i,j]` still accumulates its
/// products in ascending-`k` order through an exact store/reload, so
/// the bit-identical path is f32-equal to [`gemm_nt`].
pub fn gemm_nt_mk(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    bs: usize,
) {
    debug_assert!(
        a.len() == bs * bs && b.len() == bs * bs && c.len() == bs * bs
    );
    let level = simd_level();
    if mode == KernelMode::BitIdentical && level == SimdLevel::Scalar {
        return gemm_nt(a, b, c, bs);
    }
    let bt = PackedTile::pack_transposed(b, bs);
    match mode {
        KernelMode::BitIdentical => {
            for i in 0..bs {
                let crow = &mut c[i * bs..(i + 1) * bs];
                for k in 0..bs {
                    axpy_sub(level, crow, bt.row(k), a[i * bs + k]);
                }
            }
        }
        KernelMode::Fast => {
            for i in 0..bs {
                let crow = &mut c[i * bs..(i + 1) * bs];
                let mut k = 0;
                while k + 1 < bs {
                    let (r0, r1) = (bt.row(k), bt.row(k + 1));
                    axpy2_sub(
                        level,
                        crow,
                        a[i * bs + k],
                        r0,
                        a[i * bs + k + 1],
                        r1,
                    );
                    k += 2;
                }
                if k < bs {
                    axpy_sub(level, crow, bt.row(k), a[i * bs + k]);
                }
            }
        }
    }
}

/// `syrk` microkernel: `diag ← diag − panel·panelᵀ`, lower triangle
/// only. Same packing strategy as [`gemm_nt_mk`], with the j-sweep
/// clipped to `j <= i` so entries above the diagonal stay untouched.
pub fn syrk_mk(
    mode: KernelMode,
    panel: &[f32],
    diag: &mut [f32],
    bs: usize,
) {
    debug_assert!(panel.len() == bs * bs && diag.len() == bs * bs);
    let level = simd_level();
    if mode == KernelMode::BitIdentical && level == SimdLevel::Scalar {
        return syrk(panel, diag, bs);
    }
    let pt = PackedTile::pack_transposed(panel, bs);
    match mode {
        KernelMode::BitIdentical => {
            for i in 0..bs {
                let drow = &mut diag[i * bs..i * bs + i + 1];
                for k in 0..bs {
                    axpy_sub(
                        level,
                        drow,
                        &pt.row(k)[..i + 1],
                        panel[i * bs + k],
                    );
                }
            }
        }
        KernelMode::Fast => {
            for i in 0..bs {
                let drow = &mut diag[i * bs..i * bs + i + 1];
                let mut k = 0;
                while k + 1 < bs {
                    axpy2_sub(
                        level,
                        drow,
                        panel[i * bs + k],
                        &pt.row(k)[..i + 1],
                        panel[i * bs + k + 1],
                        &pt.row(k + 1)[..i + 1],
                    );
                    k += 2;
                }
                if k < bs {
                    axpy_sub(
                        level,
                        drow,
                        &pt.row(k)[..i + 1],
                        panel[i * bs + k],
                    );
                }
            }
        }
    }
}

/// `trsm` microkernel: `row ← row · L(diag)⁻ᵀ`.
///
/// The reference solves each row independently (forward substitution
/// over columns); rows are therefore the vector dimension. The write
/// tile is transpose-packed so "all rows at column c" is one
/// unit-stride panel row, the column sweep runs subtract-then-divide
/// exactly as the reference does per element, and the tile is
/// transpose-unpacked at the end. Both modes share this body: the
/// substitution recurrence admits no accumulation reorder, so `Fast`
/// has nothing further to trade — it stays bit-identical.
pub fn trsm_mk(
    mode: KernelMode,
    diag: &[f32],
    row: &mut [f32],
    bs: usize,
) {
    debug_assert!(diag.len() == bs * bs && row.len() == bs * bs);
    let level = simd_level();
    if mode == KernelMode::BitIdentical && level == SimdLevel::Scalar {
        return trsm(diag, row, bs);
    }
    let mut xt = PackedTile::pack_transposed(row, bs);
    for c in 0..bs {
        for j in 0..c {
            let dcj = diag[c * bs + j];
            let (xc, xj) = xt.row_pair_mut(c, j);
            axpy_sub(level, xc, xj, dcj);
        }
        div_by(level, xt.row_mut(c), diag[c * bs + c]);
    }
    xt.unpack_transposed_into(row);
}

/// `madd` microkernel: `c += a·b`. `b`'s rows are already unit-stride
/// in `j`, so no packing is needed: ikj with an `axpy` per `(i, k)`
/// (bit-identical), or paired-`k` two-term updates (fast).
pub fn madd_mk(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    bs: usize,
) {
    debug_assert!(
        a.len() == bs * bs && b.len() == bs * bs && c.len() == bs * bs
    );
    let level = simd_level();
    match mode {
        KernelMode::BitIdentical => {
            if level == SimdLevel::Scalar {
                return madd(a, b, c, bs);
            }
            for i in 0..bs {
                let crow = &mut c[i * bs..(i + 1) * bs];
                for k in 0..bs {
                    axpy_add(
                        level,
                        crow,
                        &b[k * bs..(k + 1) * bs],
                        a[i * bs + k],
                    );
                }
            }
        }
        KernelMode::Fast => {
            for i in 0..bs {
                let crow = &mut c[i * bs..(i + 1) * bs];
                let mut k = 0;
                while k + 1 < bs {
                    axpy2_add(
                        level,
                        crow,
                        a[i * bs + k],
                        &b[k * bs..(k + 1) * bs],
                        a[i * bs + k + 1],
                        &b[(k + 1) * bs..(k + 2) * bs],
                    );
                    k += 2;
                }
                if k < bs {
                    axpy_add(
                        level,
                        crow,
                        &b[k * bs..(k + 1) * bs],
                        a[i * bs + k],
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::{gen_spd, potrf};
    use crate::linalg::dense::DenseMatrix;

    fn rel_diff(got: &[f32], want: &[f32]) -> f64 {
        let scale = want
            .iter()
            .fold(0.0f32, |m, &v| m.max(v.abs()))
            .max(1e-30);
        let worst = got
            .iter()
            .zip(want)
            .fold(0.0f32, |m, (&g, &w)| m.max((g - w).abs()));
        f64::from(worst) / f64::from(scale)
    }

    fn blocks(bs: usize, seeds: [u32; 3]) -> [Vec<f32>; 3] {
        seeds.map(|s| {
            DenseMatrix::bots_random(bs, bs, s).as_slice().to_vec()
        })
    }

    #[test]
    fn pack_unpack_round_trip() {
        for bs in 1..=9 {
            let src = DenseMatrix::bots_random(bs, bs, bs as u32)
                .as_slice()
                .to_vec();
            let mut back = vec![0.0f32; bs * bs];
            PackedTile::pack(&src, bs).unpack_into(&mut back);
            assert_eq!(src, back, "identity pack bs={bs}");
            let pt = PackedTile::pack_transposed(&src, bs);
            for j in 0..bs {
                for k in 0..bs {
                    assert_eq!(pt.row(k)[j], src[j * bs + k]);
                }
            }
            pt.unpack_transposed_into(&mut back);
            assert_eq!(src, back, "transpose round trip bs={bs}");
        }
    }

    #[test]
    fn bit_identical_mode_matches_reference_kernels() {
        // On a scalar build this is dispatch-identity; with
        // `--features simd` it proves the vector paths produce the
        // same f32 bits as the reference loops, remainders included.
        for bs in [1usize, 2, 3, 4, 5, 7, 8, 9, 16] {
            let [a, b, c0] = blocks(bs, [1, 2, 3]);
            let m = KernelMode::BitIdentical;

            let mut want = c0.clone();
            bmod(&a, &b, &mut want, bs);
            let mut got = c0.clone();
            bmod_mk(m, &a, &b, &mut got, bs);
            assert_eq!(got, want, "bmod bs={bs}");

            let mut want = c0.clone();
            gemm_nt(&a, &b, &mut want, bs);
            let mut got = c0.clone();
            gemm_nt_mk(m, &a, &b, &mut got, bs);
            assert_eq!(got, want, "gemm_nt bs={bs}");

            let mut want = c0.clone();
            syrk(&a, &mut want, bs);
            let mut got = c0.clone();
            syrk_mk(m, &a, &mut got, bs);
            assert_eq!(got, want, "syrk bs={bs}");

            let mut want = c0.clone();
            madd(&a, &b, &mut want, bs);
            let mut got = c0.clone();
            madd_mk(m, &a, &b, &mut got, bs);
            assert_eq!(got, want, "madd bs={bs}");
        }
    }

    #[test]
    fn trsm_mk_matches_reference_both_modes() {
        for bs in [2usize, 3, 4, 5, 8, 9] {
            let mut diag = gen_spd(1, bs).block(0, 0).unwrap().to_vec();
            potrf(&mut diag, bs);
            let rhs = DenseMatrix::bots_random(bs, bs, 5)
                .as_slice()
                .to_vec();
            let mut want = rhs.clone();
            trsm(&diag, &mut want, bs);
            for m in [KernelMode::BitIdentical, KernelMode::Fast] {
                let mut got = rhs.clone();
                trsm_mk(m, &diag, &mut got, bs);
                assert_eq!(got, want, "trsm {} bs={bs}", m.name());
            }
        }
    }

    #[test]
    fn fast_mode_stays_within_residual_bound() {
        // The fast paths reorder the k-reduction, so results differ in
        // bits but must stay within 1e-5 relative of the reference.
        for bs in [4usize, 5, 8, 9, 16] {
            let [a, b, c0] = blocks(bs, [11, 12, 13]);

            let mut want = c0.clone();
            bmod(&a, &b, &mut want, bs);
            let mut got = c0.clone();
            bmod_mk(KernelMode::Fast, &a, &b, &mut got, bs);
            assert!(rel_diff(&got, &want) <= 1e-5, "bmod bs={bs}");

            let mut want = c0.clone();
            gemm_nt(&a, &b, &mut want, bs);
            let mut got = c0.clone();
            gemm_nt_mk(KernelMode::Fast, &a, &b, &mut got, bs);
            assert!(rel_diff(&got, &want) <= 1e-5, "gemm bs={bs}");

            let mut want = c0.clone();
            syrk(&a, &mut want, bs);
            let mut got = c0.clone();
            syrk_mk(KernelMode::Fast, &a, &mut got, bs);
            assert!(rel_diff(&got, &want) <= 1e-5, "syrk bs={bs}");

            let mut want = c0.clone();
            madd(&a, &b, &mut want, bs);
            let mut got = c0.clone();
            madd_mk(KernelMode::Fast, &a, &b, &mut got, bs);
            assert!(rel_diff(&got, &want) <= 1e-5, "madd bs={bs}");
        }
    }

    #[test]
    fn fast_mode_genuinely_reorders_at_even_bs() {
        // Sanity that the residual tests aren't vacuous: at bs >= 2
        // the paired reduction produces different bits for generic
        // inputs (if it ever matched exactly the mode split would be
        // pointless).
        let bs = 8;
        let [a, b, c0] = blocks(bs, [21, 22, 23]);
        let mut want = c0.clone();
        madd(&a, &b, &mut want, bs);
        let mut got = c0.clone();
        madd_mk(KernelMode::Fast, &a, &b, &mut got, bs);
        assert_ne!(got, want, "fast madd should reorder the reduction");
    }

    #[test]
    fn mode_and_level_names() {
        assert_eq!(KernelMode::parse("bit"), Some(KernelMode::BitIdentical));
        assert_eq!(KernelMode::parse("fast"), Some(KernelMode::Fast));
        assert_eq!(KernelMode::parse("x"), None);
        assert_eq!(KernelMode::default().name(), "bit");
        // Detection is total and cached; scalar builds report scalar.
        let l = simd_level();
        assert_eq!(l, simd_level());
        if !cfg!(feature = "simd") {
            assert_eq!(l, SimdLevel::Scalar);
        }
        assert!(!l.name().is_empty());
    }
}
