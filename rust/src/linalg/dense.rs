//! Dense `f32` row-major matrices and the matmul micro-benchmark
//! kernels (paper §V, Listing 3).

use std::fmt;

/// A dense row-major `f32` matrix.
#[derive(Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for DenseMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "DenseMatrix({}x{})", self.rows, self.cols)
    }
}

impl DenseMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data: data.to_vec() }
    }

    /// Deterministic pseudo-random matrix in `[-2, 2)` using the BOTS
    /// LCG (`init_val = 3125*init_val % 65536`), so inputs match the
    /// paper's generator family.
    pub fn bots_random(rows: usize, cols: usize, seed: u32) -> Self {
        let mut v = if seed == 0 { 1325 } else { seed } as u64;
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            v = (3125 * v) % 65536;
            data.push((v as f32 - 32768.0) / 16384.0);
        }
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `C = A · B`, naive triple loop — the exact micro-benchmark body
    /// from paper Listing 3 (ikj order for the accumulating variant is
    /// in [`matmul_opt`]).
    pub fn matmul(&self, b: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, b.rows, "inner dims must agree");
        let mut c = DenseMatrix::zeros(self.rows, b.cols);
        matmul_rows_into(
            self.as_slice(),
            b.as_slice(),
            c.as_mut_slice(),
            0,
            self.rows,
            self.cols,
            b.cols,
        );
        c
    }

    /// Cache-friendlier ikj-order matmul used by the optimized hot
    /// path; same result as [`Self::matmul`] up to f32 rounding.
    pub fn matmul_opt(&self, b: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, b.rows);
        let mut c = DenseMatrix::zeros(self.rows, b.cols);
        matmul_rows_into_ikj(
            self.as_slice(),
            b.as_slice(),
            c.as_mut_slice(),
            0,
            self.rows,
            self.cols,
            b.cols,
        );
        c
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Largest absolute elementwise difference against `other`.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for DenseMatrix {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMatrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// Row-range matmul: computes rows `[row_start, row_end)` of
/// `C += A·B` with the paper's naive ijk loop. This is the *job* unit
/// of the micro-benchmark: parallelising the `i` loop makes `m` jobs of
/// size `p·n` each (paper §V).
#[allow(clippy::too_many_arguments)]
pub fn matmul_rows_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    row_start: usize,
    row_end: usize,
    n: usize,
    p: usize,
) {
    for i in row_start..row_end {
        for j in 0..p {
            let mut acc = c[i * p + j];
            for k in 0..n {
                acc += a[i * n + k] * b[k * p + j];
            }
            c[i * p + j] = acc;
        }
    }
}

/// ikj-order row-range matmul — the optimized variant (streams `B`
/// rows instead of striding columns).
#[allow(clippy::too_many_arguments)]
pub fn matmul_rows_into_ikj(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    row_start: usize,
    row_end: usize,
    n: usize,
    p: usize,
) {
    for i in row_start..row_end {
        let crow = &mut c[i * p..(i + 1) * p];
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * p..(k + 1) * p];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// Flop count of one micro-benchmark *job* (one row of `C`, paper §V):
/// `p` dot products of length `n` → `2·n·p` flops.
pub fn matmul_job_flops(n: usize, p: usize) -> u64 {
    2 * (n as u64) * (p as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_indexing() {
        let z = DenseMatrix::zeros(3, 4);
        assert_eq!(z.rows(), 3);
        assert_eq!(z.cols(), 4);
        assert!(z.as_slice().iter().all(|&x| x == 0.0));
        let e = DenseMatrix::eye(3);
        assert_eq!(e[(1, 1)], 1.0);
        assert_eq!(e[(1, 2)], 0.0);
    }

    #[test]
    fn matmul_identity() {
        let a = DenseMatrix::bots_random(5, 5, 7);
        let i = DenseMatrix::eye(5);
        let ai = a.matmul(&i);
        assert_eq!(a, ai);
        let ia = i.matmul(&a);
        assert_eq!(a, ia);
    }

    #[test]
    fn matmul_known_values() {
        // Same check the reference load_hlo uses: [[1,2],[3,4]]·ones + 0.
        let a = DenseMatrix::from_slice(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = DenseMatrix::from_slice(2, 2, &[1.0; 4]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // (2x3)·(3x4) against hand-computed values.
        let a = DenseMatrix::from_slice(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = DenseMatrix::from_slice(
            3,
            4,
            &[1., 0., 0., 1., 0., 1., 0., 2., 0., 0., 1., 3.],
        );
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[1., 2., 3., 14., 4., 5., 6., 32.]);
    }

    #[test]
    fn opt_matches_naive() {
        let a = DenseMatrix::bots_random(17, 23, 1);
        let b = DenseMatrix::bots_random(23, 11, 2);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_opt(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-4, "ikj must match ijk");
    }

    #[test]
    fn bots_random_range_and_determinism() {
        let a = DenseMatrix::bots_random(8, 8, 0);
        let b = DenseMatrix::bots_random(8, 8, 0);
        assert_eq!(a, b);
        assert!(a.as_slice().iter().all(|&x| (-2.0..2.0).contains(&x)));
        // BOTS LCG starting at 1325: first value (3125*1325)%65536=11857
        // → (11857-32768)/16384.
        assert!((a.as_slice()[0] - (11857.0 - 32768.0) / 16384.0).abs() < 1e-6);
    }

    #[test]
    fn row_range_partial() {
        let a = DenseMatrix::bots_random(6, 4, 3);
        let b = DenseMatrix::bots_random(4, 5, 4);
        let full = a.matmul(&b);
        let mut c = DenseMatrix::zeros(6, 5);
        matmul_rows_into(a.as_slice(), b.as_slice(), c.as_mut_slice(), 0, 3, 4, 5);
        matmul_rows_into(a.as_slice(), b.as_slice(), c.as_mut_slice(), 3, 6, 4, 5);
        assert!(full.max_abs_diff(&c) < 1e-5);
    }

    #[test]
    fn fro_norm_and_flops() {
        let a = DenseMatrix::from_slice(1, 2, &[3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(matmul_job_flops(10, 20), 400);
    }
}
