//! Factorisation verification: reconstruct `L·U` from a packed LU and
//! measure the relative residual against the original matrix.
//!
//! BOTS itself only cross-checks parallel-vs-sequential results; we
//! additionally verify against the *mathematical* definition so that a
//! scheduling bug that reorders dependent kernels cannot silently pass.

use super::blocked::BlockedSparseMatrix;
use super::dense::DenseMatrix;

/// Split a packed LU (as produced by `lu0`/`dense_lu`) into unit-lower
/// `L` and upper `U`.
pub fn split_lu(packed: &DenseMatrix) -> (DenseMatrix, DenseMatrix) {
    let n = packed.rows();
    assert_eq!(n, packed.cols());
    let mut l = DenseMatrix::eye(n);
    let mut u = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            if j < i {
                l[(i, j)] = packed[(i, j)];
            } else {
                u[(i, j)] = packed[(i, j)];
            }
        }
    }
    (l, u)
}

/// Relative residual ‖L·U − A‖_F / ‖A‖_F for a packed dense LU.
pub fn lu_residual_dense(a: &DenseMatrix, packed: &DenseMatrix) -> f64 {
    let (l, u) = split_lu(packed);
    let lu = l.matmul_opt(&u);
    let n = a.rows();
    let mut num = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let d = (lu[(i, j)] - a[(i, j)]) as f64;
            num += d * d;
        }
    }
    num.sqrt() / a.fro_norm().max(1e-30)
}

/// Relative residual for a packed *blocked sparse* LU against the
/// dense expansion of the original matrix.
pub fn lu_residual_sparse(orig_dense: &DenseMatrix, packed: &BlockedSparseMatrix) -> f64 {
    lu_residual_dense(orig_dense, &packed.to_dense())
}

/// Relative residual ‖L·Lᵀ − A‖_F / ‖A‖_F for a packed *blocked
/// sparse* lower Cholesky (as produced by
/// [`crate::linalg::cholesky::cholesky_seq`]) against the full
/// symmetric dense original.
pub fn chol_residual_sparse(
    orig_dense: &DenseMatrix,
    packed: &BlockedSparseMatrix,
) -> f64 {
    let n = packed.dim();
    let bs = packed.bs();
    assert_eq!(orig_dense.rows(), n);
    // Extract L (lower triangle incl. diagonal) from the lower blocks.
    let mut l = DenseMatrix::zeros(n, n);
    for ii in 0..packed.nb() {
        for jj in 0..=ii {
            if let Some(b) = packed.block(ii, jj) {
                for r in 0..bs {
                    for c in 0..bs {
                        let (gi, gj) = (ii * bs + r, jj * bs + c);
                        if gi >= gj {
                            l[(gi, gj)] = b[r * bs + c];
                        }
                    }
                }
            }
        }
    }
    // ‖L·Lᵀ − A‖ via Lᵀ materialised once.
    let mut lt = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            lt[(i, j)] = l[(j, i)];
        }
    }
    let llt = l.matmul_opt(&lt);
    let mut num = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let d = (llt[(i, j)] - orig_dense[(i, j)]) as f64;
            num += d * d;
        }
    }
    num.sqrt() / orig_dense.fro_norm().max(1e-30)
}

/// Assert two blocked matrices have identical structure and
/// elementwise-close values; returns max abs diff.
pub fn assert_blocked_close(
    a: &BlockedSparseMatrix,
    b: &BlockedSparseMatrix,
    tol: f32,
) -> f32 {
    assert_eq!(a.nb(), b.nb());
    assert_eq!(a.bs(), b.bs());
    assert_eq!(a.pattern(), b.pattern(), "allocation patterns differ");
    let mut worst = 0.0f32;
    for ii in 0..a.nb() {
        for jj in 0..a.nb() {
            if let (Some(x), Some(y)) = (a.block(ii, jj), b.block(ii, jj)) {
                for (u, v) in x.iter().zip(y) {
                    let d = (u - v).abs();
                    if d > worst {
                        worst = d;
                    }
                    assert!(
                        d <= tol,
                        "block ({ii},{jj}) differs by {d} (tol {tol})"
                    );
                }
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat;
    use crate::linalg::lu::{dense_lu, sparselu_seq};

    #[test]
    fn split_roundtrip() {
        let packed =
            DenseMatrix::from_slice(2, 2, &[4.0, 2.0, 0.5, 2.0]);
        let (l, u) = split_lu(&packed);
        assert_eq!(l.as_slice(), &[1.0, 0.0, 0.5, 1.0]);
        assert_eq!(u.as_slice(), &[4.0, 2.0, 0.0, 2.0]);
        let lu = l.matmul(&u);
        assert_eq!(lu.as_slice(), &[4.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn residual_zero_for_exact() {
        let a = DenseMatrix::from_slice(2, 2, &[4.0, 2.0, 2.0, 3.0]);
        let mut p = a.clone();
        dense_lu(&mut p);
        assert!(lu_residual_dense(&a, &p) < 1e-7);
    }

    #[test]
    fn residual_detects_corruption() {
        let a = DenseMatrix::from_slice(2, 2, &[4.0, 2.0, 2.0, 3.0]);
        let mut p = a.clone();
        dense_lu(&mut p);
        p[(0, 0)] += 1.0;
        assert!(lu_residual_dense(&a, &p) > 0.05);
    }

    #[test]
    fn chol_residual_detects_corruption() {
        use crate::linalg::cholesky::{cholesky_seq, gen_spd, sym_dense};
        let mut a = gen_spd(3, 4);
        let orig = sym_dense(&a);
        cholesky_seq(&mut a);
        assert!(chol_residual_sparse(&orig, &a) < 1e-5);
        a.block_mut(1, 0).unwrap()[0] += 5.0;
        assert!(chol_residual_sparse(&orig, &a) > 1e-3);
    }

    #[test]
    fn blocked_close_detects_structure_diff() {
        let a = genmat(4, 2);
        let mut b = genmat(4, 2);
        sparselu_seq(&mut b);
        // b has fill-in now → patterns differ → should panic.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            assert_blocked_close(&a, &b, 1.0)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn blocked_close_passes_for_clones() {
        let a = genmat(4, 3);
        let b = a.deep_clone();
        assert_eq!(assert_blocked_close(&a, &b, 0.0), 0.0);
    }
}
