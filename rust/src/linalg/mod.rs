//! Linear-algebra substrate for the SparseLU / MatMul workloads.
//!
//! Everything the paper's evaluation needs, built from scratch:
//!
//! * [`dense`] — a small dense `f32` matrix type with naive and
//!   cache-blocked matmul (the micro-benchmark of paper §V).
//! * [`blocked`] — the BOTS-style blocked sparse matrix: an `NB×NB`
//!   grid of optionally-allocated `BS×BS` blocks (paper §VI).
//! * [`genmat`] — a faithful port of the BOTS `sparselu` input
//!   generator (same structural sparsity: ~85% at NB=50, ~89% at
//!   NB=100).
//! * [`lu`] — the four block kernels `lu0`, `fwd`, `bdiv`, `bmod`
//!   exactly as in BOTS, plus sequential blocked-sparse and dense LU
//!   reference drivers.
//! * [`cholesky`] — tiled dense Cholesky: the POTRF/TRSM/SYRK/GEMM
//!   block kernels, an SPD input generator, and the sequential tiled
//!   reference (the second workload on the dataflow engine; not in the
//!   source paper — see DIVERGENCES.md).
//! * [`verify`] — ‖L·U − A‖ / ‖L·Lᵀ − A‖ reconstruction checks used
//!   by tests and the end-to-end example.
//! * [`microkernel`] — packed, register-blocked SIMD variants of the
//!   update kernels (`bmod`/`gemm`/`syrk`/`trsm`/`madd`) behind the
//!   `simd` feature, with an explicit bit-identical-vs-fast precision
//!   policy ([`microkernel::KernelMode`]).
//! * [`autotune`] — startup block-size tuner: sweeps candidate sizes
//!   per registry workload against a calibrator and caches the winner
//!   in the workload registry.

pub mod dense;
pub mod autotune;
pub mod blocked;
pub mod cholesky;
pub mod genmat;
pub mod lu;
pub mod microkernel;
pub mod verify;

pub use blocked::BlockedSparseMatrix;
pub use dense::DenseMatrix;
