//! The BOTS-style blocked sparse matrix (paper §VI).
//!
//! The matrix is an `NB×NB` grid of blocks; each block is either
//! unallocated (`None`, a structurally-zero `BS×BS` region) or an owned
//! dense `BS×BS` tile. During factorisation the `bmod` phase allocates
//! *fill-in* blocks on demand (`allocate_clean_block` in BOTS).

use super::dense::DenseMatrix;

/// One dense `BS×BS` tile, row-major.
pub type Block = Box<[f32]>;

/// Blocked sparse matrix: `NB×NB` grid of optional `BS×BS` blocks.
pub struct BlockedSparseMatrix {
    nb: usize,
    bs: usize,
    blocks: Vec<Option<Block>>,
}

impl BlockedSparseMatrix {
    /// Fully-empty matrix.
    pub fn empty(nb: usize, bs: usize) -> Self {
        assert!(nb > 0 && bs > 0);
        let mut blocks = Vec::with_capacity(nb * nb);
        blocks.resize_with(nb * nb, || None);
        Self { nb, bs, blocks }
    }

    /// Number of blocks per dimension (`NB`, "number of blocks" in the
    /// paper; `bots_arg_size` in BOTS).
    pub fn nb(&self) -> usize {
        self.nb
    }

    /// Block edge length (`bots_arg_size_1` in BOTS).
    pub fn bs(&self) -> usize {
        self.bs
    }

    /// Full matrix dimension `nb*bs`.
    pub fn dim(&self) -> usize {
        self.nb * self.bs
    }

    #[inline]
    fn idx(&self, ii: usize, jj: usize) -> usize {
        debug_assert!(ii < self.nb && jj < self.nb);
        ii * self.nb + jj
    }

    /// Is block `(ii, jj)` allocated?
    pub fn is_allocated(&self, ii: usize, jj: usize) -> bool {
        self.blocks[self.idx(ii, jj)].is_some()
    }

    /// Borrow block `(ii, jj)`.
    pub fn block(&self, ii: usize, jj: usize) -> Option<&[f32]> {
        self.blocks[self.idx(ii, jj)].as_deref()
    }

    /// Mutably borrow block `(ii, jj)`.
    pub fn block_mut(&mut self, ii: usize, jj: usize) -> Option<&mut [f32]> {
        let i = self.idx(ii, jj);
        self.blocks[i].as_deref_mut()
    }

    /// Install a block (replacing any existing one).
    pub fn set_block(&mut self, ii: usize, jj: usize, data: Block) {
        assert_eq!(data.len(), self.bs * self.bs, "block shape mismatch");
        let i = self.idx(ii, jj);
        self.blocks[i] = Some(data);
    }

    /// BOTS `allocate_clean_block`: ensure `(ii, jj)` exists (zeroed if
    /// fresh) and return it mutably. This is the fill-in path of `bmod`.
    pub fn allocate_clean_block(&mut self, ii: usize, jj: usize) -> &mut [f32] {
        let i = self.idx(ii, jj);
        let bs = self.bs;
        self.blocks[i]
            .get_or_insert_with(|| vec![0.0f32; bs * bs].into_boxed_slice())
    }

    /// Take block `(ii, jj)` out of the matrix (used by runtimes that
    /// ship blocks to PJRT and re-install results).
    pub fn take_block(&mut self, ii: usize, jj: usize) -> Option<Block> {
        let i = self.idx(ii, jj);
        self.blocks[i].take()
    }

    /// Count of allocated blocks.
    pub fn allocated_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_some()).count()
    }

    /// Structural sparsity in `[0,1]`: fraction of *unallocated* blocks.
    /// The paper reports 85% at NB=50 and 89% at NB=100.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.allocated_blocks() as f64 / (self.nb * self.nb) as f64
    }

    /// Expand to a dense matrix (zeros where unallocated).
    pub fn to_dense(&self) -> DenseMatrix {
        let n = self.dim();
        let mut d = DenseMatrix::zeros(n, n);
        for ii in 0..self.nb {
            for jj in 0..self.nb {
                if let Some(b) = self.block(ii, jj) {
                    for r in 0..self.bs {
                        for c in 0..self.bs {
                            d[(ii * self.bs + r, jj * self.bs + c)] =
                                b[r * self.bs + c];
                        }
                    }
                }
            }
        }
        d
    }

    /// Deep copy.
    pub fn deep_clone(&self) -> Self {
        Self {
            nb: self.nb,
            bs: self.bs,
            blocks: self.blocks.iter().map(|b| b.clone()).collect(),
        }
    }

    /// The allocation pattern as a boolean grid (row-major `nb*nb`).
    pub fn pattern(&self) -> Vec<bool> {
        self.blocks.iter().map(|b| b.is_some()).collect()
    }

    /// Unsafe split used by the parallel factorisation: returns raw
    /// pointers to the block storage so distinct blocks can be updated
    /// from different threads. Safety is the scheduler's obligation —
    /// the LU dependency structure guarantees disjointness (fwd writes
    /// row kk, bdiv writes column kk, bmod writes (ii>kk, jj>kk), and
    /// within a phase each task touches a distinct block).
    pub fn block_ptr(&self, ii: usize, jj: usize) -> Option<*const f32> {
        self.blocks[self.idx(ii, jj)].as_ref().map(|b| b.as_ptr())
    }
}

/// A shareable handle for the parallel SparseLU phases: wraps the
/// matrix so worker threads can mutate *disjoint* blocks concurrently.
///
/// The LU schedule guarantees disjoint writes per phase; readers only
/// read blocks finalised in earlier phases. This mirrors what the
/// OpenMP/BOTS C code does with bare `float**` and is encapsulated
/// here behind one audited unsafe boundary.
pub struct SharedBlocked {
    inner: std::cell::UnsafeCell<BlockedSparseMatrix>,
}

// SAFETY: see struct docs — two schedules uphold data-race freedom:
// * phase drivers: each phase's tasks write disjoint blocks and
//   synchronise with a barrier (taskwait / GPRM seq) before the next
//   phase reads;
// * the dataflow driver (`apps::sparselu::sparselu_dataflow`): the
//   `sched::TaskGraph` chains *every* pair of tasks touching the same
//   block (RAW/WAW/WAR edges), and the executor's scoreboard mutex
//   (claim after all predecessors completed under the same lock)
//   establishes the happens-before between a block's writer and its
//   readers. If the executor ever drops that mutex for lock-free
//   claims, it must provide an equivalent release/acquire edge per
//   dependency or this Sync impl becomes unsound for that caller.
unsafe impl Sync for SharedBlocked {}
unsafe impl Send for SharedBlocked {}

impl SharedBlocked {
    pub fn new(m: BlockedSparseMatrix) -> Self {
        Self { inner: std::cell::UnsafeCell::new(m) }
    }

    /// Shared view (reads of blocks finalised in earlier phases).
    ///
    /// SAFETY: caller must not alias a concurrent `get_mut` write to
    /// the same block.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut BlockedSparseMatrix {
        &mut *self.inner.get()
    }

    pub fn get(&self) -> &BlockedSparseMatrix {
        unsafe { &*self.inner.get() }
    }

    pub fn into_inner(self) -> BlockedSparseMatrix {
        self.inner.into_inner()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_alloc() {
        let mut m = BlockedSparseMatrix::empty(4, 3);
        assert_eq!(m.nb(), 4);
        assert_eq!(m.bs(), 3);
        assert_eq!(m.dim(), 12);
        assert_eq!(m.allocated_blocks(), 0);
        assert!((m.sparsity() - 1.0).abs() < 1e-12);
        assert!(!m.is_allocated(1, 2));
        let b = m.allocate_clean_block(1, 2);
        assert!(b.iter().all(|&x| x == 0.0));
        b[0] = 5.0;
        assert!(m.is_allocated(1, 2));
        assert_eq!(m.allocated_blocks(), 1);
        // idempotent: second call returns the same (non-zeroed) block
        assert_eq!(m.allocate_clean_block(1, 2)[0], 5.0);
    }

    #[test]
    fn set_take_roundtrip() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.set_block(0, 1, vec![1., 2., 3., 4.].into_boxed_slice());
        let b = m.take_block(0, 1).unwrap();
        assert_eq!(&*b, &[1., 2., 3., 4.]);
        assert!(!m.is_allocated(0, 1));
    }

    #[test]
    fn to_dense_placement() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.set_block(1, 0, vec![1., 2., 3., 4.].into_boxed_slice());
        let d = m.to_dense();
        assert_eq!(d[(2, 0)], 1.0);
        assert_eq!(d[(2, 1)], 2.0);
        assert_eq!(d[(3, 0)], 3.0);
        assert_eq!(d[(3, 1)], 4.0);
        assert_eq!(d[(0, 0)], 0.0);
    }

    #[test]
    fn deep_clone_is_independent() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.allocate_clean_block(0, 0)[0] = 1.0;
        let c = m.deep_clone();
        m.block_mut(0, 0).unwrap()[0] = 9.0;
        assert_eq!(c.block(0, 0).unwrap()[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "block shape mismatch")]
    fn set_block_shape_checked() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.set_block(0, 0, vec![0.0; 3].into_boxed_slice());
    }
}
