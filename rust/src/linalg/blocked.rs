//! The BOTS-style blocked sparse matrix (paper §VI).
//!
//! The matrix is an `NB×NB` grid of blocks; each block is either
//! unallocated (`None`, a structurally-zero `BS×BS` region) or an owned
//! dense `BS×BS` tile. During factorisation the `bmod` phase allocates
//! *fill-in* blocks on demand (`allocate_clean_block` in BOTS).

use super::dense::DenseMatrix;

/// One dense `BS×BS` tile, row-major.
pub type Block = Box<[f32]>;

/// Blocked sparse matrix: `NB×NB` grid of optional `BS×BS` blocks.
pub struct BlockedSparseMatrix {
    nb: usize,
    bs: usize,
    blocks: Vec<Option<Block>>,
}

impl BlockedSparseMatrix {
    /// Fully-empty matrix.
    pub fn empty(nb: usize, bs: usize) -> Self {
        assert!(nb > 0 && bs > 0);
        let mut blocks = Vec::with_capacity(nb * nb);
        blocks.resize_with(nb * nb, || None);
        Self { nb, bs, blocks }
    }

    /// Number of blocks per dimension (`NB`, "number of blocks" in the
    /// paper; `bots_arg_size` in BOTS).
    pub fn nb(&self) -> usize {
        self.nb
    }

    /// Block edge length (`bots_arg_size_1` in BOTS).
    pub fn bs(&self) -> usize {
        self.bs
    }

    /// Full matrix dimension `nb*bs`.
    pub fn dim(&self) -> usize {
        self.nb * self.bs
    }

    #[inline]
    fn idx(&self, ii: usize, jj: usize) -> usize {
        debug_assert!(ii < self.nb && jj < self.nb);
        ii * self.nb + jj
    }

    /// Is block `(ii, jj)` allocated?
    pub fn is_allocated(&self, ii: usize, jj: usize) -> bool {
        self.blocks[self.idx(ii, jj)].is_some()
    }

    /// Borrow block `(ii, jj)`.
    pub fn block(&self, ii: usize, jj: usize) -> Option<&[f32]> {
        self.blocks[self.idx(ii, jj)].as_deref()
    }

    /// Mutably borrow block `(ii, jj)`.
    pub fn block_mut(&mut self, ii: usize, jj: usize) -> Option<&mut [f32]> {
        let i = self.idx(ii, jj);
        self.blocks[i].as_deref_mut()
    }

    /// Install a block (replacing any existing one).
    pub fn set_block(&mut self, ii: usize, jj: usize, data: Block) {
        assert_eq!(data.len(), self.bs * self.bs, "block shape mismatch");
        let i = self.idx(ii, jj);
        self.blocks[i] = Some(data);
    }

    /// BOTS `allocate_clean_block`: ensure `(ii, jj)` exists (zeroed if
    /// fresh) and return it mutably. This is the fill-in path of `bmod`.
    pub fn allocate_clean_block(&mut self, ii: usize, jj: usize) -> &mut [f32] {
        let i = self.idx(ii, jj);
        let bs = self.bs;
        self.blocks[i]
            .get_or_insert_with(|| vec![0.0f32; bs * bs].into_boxed_slice())
    }

    /// Take block `(ii, jj)` out of the matrix (used by runtimes that
    /// ship blocks to PJRT and re-install results).
    pub fn take_block(&mut self, ii: usize, jj: usize) -> Option<Block> {
        let i = self.idx(ii, jj);
        self.blocks[i].take()
    }

    /// Count of allocated blocks.
    pub fn allocated_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_some()).count()
    }

    /// Structural sparsity in `[0,1]`: fraction of *unallocated* blocks.
    /// The paper reports 85% at NB=50 and 89% at NB=100.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.allocated_blocks() as f64 / (self.nb * self.nb) as f64
    }

    /// Expand to a dense matrix (zeros where unallocated).
    pub fn to_dense(&self) -> DenseMatrix {
        let n = self.dim();
        let mut d = DenseMatrix::zeros(n, n);
        for ii in 0..self.nb {
            for jj in 0..self.nb {
                if let Some(b) = self.block(ii, jj) {
                    for r in 0..self.bs {
                        for c in 0..self.bs {
                            d[(ii * self.bs + r, jj * self.bs + c)] =
                                b[r * self.bs + c];
                        }
                    }
                }
            }
        }
        d
    }

    /// Deep copy.
    pub fn deep_clone(&self) -> Self {
        Self {
            nb: self.nb,
            bs: self.bs,
            blocks: self.blocks.to_vec(),
        }
    }

    /// The allocation pattern as a boolean grid (row-major `nb*nb`).
    pub fn pattern(&self) -> Vec<bool> {
        self.blocks.iter().map(|b| b.is_some()).collect()
    }

    /// Split-borrow: read block `r` while mutably borrowing block `w`
    /// from the same matrix — the zero-copy form of the `fwd`/`bdiv`
    /// call sites, which previously had to `.to_vec()` the diagonal
    /// block to satisfy the borrow checker. `None` if either block is
    /// unallocated. Panics if `r == w` (use [`Self::block_mut`]).
    pub fn block_and_mut(
        &mut self,
        r: (usize, usize),
        w: (usize, usize),
    ) -> Option<(&[f32], &mut [f32])> {
        let ri = self.idx(r.0, r.1);
        let wi = self.idx(w.0, w.1);
        assert_ne!(ri, wi, "read and write block must be distinct");
        let (read, write) = if ri < wi {
            let (lo, hi) = self.blocks.split_at_mut(wi);
            (lo[ri].as_deref(), hi[0].as_deref_mut())
        } else {
            let (lo, hi) = self.blocks.split_at_mut(ri);
            (hi[0].as_deref(), lo[wi].as_deref_mut())
        };
        match (read, write) {
            (Some(read), Some(write)) => Some((read, write)),
            _ => None,
        }
    }

    /// Split-borrow for `bmod`: shared references to blocks `r1` and
    /// `r2` plus a mutable reference to block `w`, all from the same
    /// matrix, with no copies. `w` must already be allocated (call
    /// [`Self::allocate_clean_block`] first on the fill-in path) and
    /// distinct from both reads; `r1 == r2` is allowed.
    pub fn read2_write1(
        &mut self,
        r1: (usize, usize),
        r2: (usize, usize),
        w: (usize, usize),
    ) -> Option<(&[f32], &[f32], &mut [f32])> {
        let i1 = self.idx(r1.0, r1.1);
        let i2 = self.idx(r2.0, r2.1);
        let iw = self.idx(w.0, w.1);
        assert!(
            i1 != iw && i2 != iw,
            "write block must not alias a read block"
        );
        let p1: *const [f32] = self.blocks[i1].as_deref()?;
        let p2: *const [f32] = self.blocks[i2].as_deref()?;
        let pw: *mut [f32] = self.blocks[iw].as_deref_mut()?;
        // SAFETY: the three slots are distinct `Option<Box<[f32]>>`
        // entries (iw differs from i1 and i2; boxes own disjoint
        // heap storage even for i1 == i2, which yields two shared
        // refs), and all three reborrows are tied to the `&mut self`
        // borrow of this call, so nothing else can touch the matrix
        // while they live.
        unsafe { Some((&*p1, &*p2, &mut *pw)) }
    }
}

/// A shareable handle for the parallel SparseLU phases: wraps the
/// matrix so worker threads can mutate *disjoint* blocks concurrently.
///
/// The LU schedule guarantees disjoint writes per phase; readers only
/// read blocks finalised in earlier phases. This mirrors what the
/// OpenMP/BOTS C code does with bare `float**` and is encapsulated
/// here behind one audited unsafe boundary.
pub struct SharedBlocked {
    inner: std::cell::UnsafeCell<BlockedSparseMatrix>,
}

// SAFETY: see struct docs — two schedules uphold data-race freedom:
// * phase drivers: each phase's tasks write disjoint blocks and
//   synchronise with a barrier (taskwait / GPRM seq) before the next
//   phase reads;
// * the dataflow driver (`apps::sparselu::sparselu_dataflow`): the
//   `sched::TaskGraph` chains *every* pair of tasks touching the same
//   block (RAW/WAW/WAR edges), and the executor provides a
//   happens-before edge per dependency:
//   - mutex scoreboard (`ExecOpts::mutex_baseline`): a task is
//     claimed only after all predecessors completed under the same
//     lock;
//   - lock-free work stealing (the default): a completing task
//     decrements each successor's in-degree with `Release`; the
//     worker that observes zero issues an `Acquire` fence
//     (`sched::exec::StealExec::run_one`) and enqueues the successor
//     through the Chase–Lev deque, whose publish (`Release` fence
//     before the `bottom` store) / consume (`Acquire` loads + `SeqCst`
//     CAS on `top`) pair carries the edge to whichever worker claims
//     it. Either way the block writes of every predecessor are
//     visible before the successor's kernel runs.
//   Any future executor must keep providing an equivalent
//   release/acquire edge per dependency or this Sync impl becomes
//   unsound for that caller.
unsafe impl Sync for SharedBlocked {}
unsafe impl Send for SharedBlocked {}

impl SharedBlocked {
    pub fn new(m: BlockedSparseMatrix) -> Self {
        Self { inner: std::cell::UnsafeCell::new(m) }
    }

    /// Shared view (reads of blocks finalised in earlier phases).
    ///
    /// SAFETY: caller must not alias a concurrent `get_mut` write to
    /// the same block.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut BlockedSparseMatrix {
        &mut *self.inner.get()
    }

    pub fn get(&self) -> &BlockedSparseMatrix {
        unsafe { &*self.inner.get() }
    }

    pub fn into_inner(self) -> BlockedSparseMatrix {
        self.inner.into_inner()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_alloc() {
        let mut m = BlockedSparseMatrix::empty(4, 3);
        assert_eq!(m.nb(), 4);
        assert_eq!(m.bs(), 3);
        assert_eq!(m.dim(), 12);
        assert_eq!(m.allocated_blocks(), 0);
        assert!((m.sparsity() - 1.0).abs() < 1e-12);
        assert!(!m.is_allocated(1, 2));
        let b = m.allocate_clean_block(1, 2);
        assert!(b.iter().all(|&x| x == 0.0));
        b[0] = 5.0;
        assert!(m.is_allocated(1, 2));
        assert_eq!(m.allocated_blocks(), 1);
        // idempotent: second call returns the same (non-zeroed) block
        assert_eq!(m.allocate_clean_block(1, 2)[0], 5.0);
    }

    #[test]
    fn set_take_roundtrip() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.set_block(0, 1, vec![1., 2., 3., 4.].into_boxed_slice());
        let b = m.take_block(0, 1).unwrap();
        assert_eq!(&*b, &[1., 2., 3., 4.]);
        assert!(!m.is_allocated(0, 1));
    }

    #[test]
    fn to_dense_placement() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.set_block(1, 0, vec![1., 2., 3., 4.].into_boxed_slice());
        let d = m.to_dense();
        assert_eq!(d[(2, 0)], 1.0);
        assert_eq!(d[(2, 1)], 2.0);
        assert_eq!(d[(3, 0)], 3.0);
        assert_eq!(d[(3, 1)], 4.0);
        assert_eq!(d[(0, 0)], 0.0);
    }

    #[test]
    fn deep_clone_is_independent() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.allocate_clean_block(0, 0)[0] = 1.0;
        let c = m.deep_clone();
        m.block_mut(0, 0).unwrap()[0] = 9.0;
        assert_eq!(c.block(0, 0).unwrap()[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "block shape mismatch")]
    fn set_block_shape_checked() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.set_block(0, 0, vec![0.0; 3].into_boxed_slice());
    }

    #[test]
    fn block_and_mut_both_orders() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.allocate_clean_block(0, 0)[0] = 1.0;
        m.allocate_clean_block(1, 1)[0] = 2.0;
        // read index below write index…
        let (r, w) = m.block_and_mut((0, 0), (1, 1)).unwrap();
        assert_eq!(r[0], 1.0);
        w[0] = 5.0;
        // …and above it.
        let (r, w) = m.block_and_mut((1, 1), (0, 0)).unwrap();
        assert_eq!(r[0], 5.0);
        w[0] = 7.0;
        assert_eq!(m.block(0, 0).unwrap()[0], 7.0);
        // Unallocated read → None.
        assert!(m.block_and_mut((0, 1), (0, 0)).is_none());
    }

    #[test]
    #[should_panic(expected = "must be distinct")]
    fn block_and_mut_rejects_alias() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.allocate_clean_block(0, 0);
        let _ = m.block_and_mut((0, 0), (0, 0));
    }

    #[test]
    fn read2_write1_zero_copy() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.allocate_clean_block(0, 1)[0] = 1.0;
        m.allocate_clean_block(1, 0)[0] = 2.0;
        m.allocate_clean_block(1, 1)[0] = 3.0;
        let (r1, r2, w) = m.read2_write1((0, 1), (1, 0), (1, 1)).unwrap();
        assert_eq!((r1[0], r2[0], w[0]), (1.0, 2.0, 3.0));
        w[0] = 10.0 * r1[0] + r2[0];
        assert_eq!(m.block(1, 1).unwrap()[0], 12.0);
        // Same block twice as reads is fine (two shared refs).
        let (r1, r2, _) = m.read2_write1((0, 1), (0, 1), (1, 1)).unwrap();
        assert_eq!(r1[0], r2[0]);
        // Missing write target → None.
        assert!(m.read2_write1((0, 1), (1, 0), (0, 0)).is_none());
    }

    #[test]
    #[should_panic(expected = "must not alias")]
    fn read2_write1_rejects_alias() {
        let mut m = BlockedSparseMatrix::empty(2, 2);
        m.allocate_clean_block(0, 0);
        m.allocate_clean_block(0, 1);
        let _ = m.read2_write1((0, 0), (0, 1), (0, 0));
    }
}
