//! A miniature property-testing kit (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs and, on failure, greedily shrinks the input via the
//! generator's `shrink` before panicking with the minimal
//! counterexample.

use crate::util::prng::SplitMix64;

/// A generator of values of type `T` with optional shrinking.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut SplitMix64) -> Self::Value;
    /// Candidate "smaller" values; default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform usize in `[lo, hi)`, shrinking toward `lo`.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut SplitMix64) -> usize {
        rng.range(self.0, self.1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Triple of independent generators.
pub struct Triple<A, B, C>(pub A, pub B, pub C);

impl<A: Gen, B: Gen, C: Gen> Gen for Triple<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);
    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        (
            self.0.generate(rng),
            self.1.generate(rng),
            self.2.generate(rng),
        )
    }
    fn shrink(&self, (a, b, c): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone(), c.clone()))
            .collect();
        out.extend(
            self.1
                .shrink(b)
                .into_iter()
                .map(|b2| (a.clone(), b2, c.clone())),
        );
        out.extend(
            self.2
                .shrink(c)
                .into_iter()
                .map(|c2| (a.clone(), b.clone(), c2)),
        );
        out
    }
}

/// Vec of `len` values from an element generator.
pub struct VecOf<G>(pub G, pub usize);

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        (0..self.1).map(|_| self.0.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        // Shrink by halving length.
        if v.len() > 1 {
            vec![v[..v.len() / 2].to_vec()]
        } else {
            Vec::new()
        }
    }
}

/// Run a property over `cases` random inputs (deterministic seed
/// derived from `name`), shrinking on failure.
pub fn check<G: Gen>(
    name: &str,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let seed = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(e) = prop(&v) {
            // Greedy shrink: keep taking the first failing candidate.
            let mut cur = v;
            let mut msg = e;
            'shrinking: loop {
                for cand in gen.shrink(&cur) {
                    if let Err(e2) = prop(&cand) {
                        cur = cand;
                        msg = e2;
                        continue 'shrinking;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}) on minimal input {cur:?}: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 200, &Pair(UsizeRange(0, 100), UsizeRange(0, 100)), |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check("find-ge-10", 500, &UsizeRange(0, 1000), |&v| {
                if v < 10 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 10"))
                }
            });
        });
        let msg = match r {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy shrink must land on a small counterexample (10..20).
        assert!(msg.contains("minimal input 1"), "msg: {msg}");
    }

    #[test]
    fn vec_gen_len() {
        let mut rng = SplitMix64::new(1);
        let v = VecOf(UsizeRange(0, 5), 7).generate(&mut rng);
        assert_eq!(v.len(), 7);
        assert!(v.iter().all(|&x| x < 5));
    }
}
