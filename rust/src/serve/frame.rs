//! Length-delimited framing and byte-level codec primitives.
//!
//! One frame = a little-endian `u32` payload length followed by the
//! payload bytes. The length prefix is the *only* transport-level
//! structure — everything else (request/response tags, fields) lives
//! in [`super::protocol`]. Frames are capped at [`MAX_FRAME`] bytes so
//! a corrupt or hostile length prefix can never make the server
//! allocate unboundedly.
//!
//! [`ByteWriter`] / [`ByteReader`] are the payload codec: fixed-width
//! little-endian integers, `u16`-length-prefixed UTF-8 strings, and
//! flagged optionals. Decoding is total — every malformed input maps
//! to a typed [`WireError`], never a panic — because the server feeds
//! it bytes from the network.

use std::io::{self, Read, Write};

/// Hard cap on a frame payload (64 KiB). Requests and responses are
/// tiny (well under 1 KiB); the cap exists to bound allocation on a
/// garbage length prefix.
pub const MAX_FRAME: usize = 1 << 16;

/// Write one length-delimited frame and flush it.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// What one read attempt produced.
pub enum ReadOutcome {
    /// A complete frame payload.
    Frame(Vec<u8>),
    /// The peer closed the stream cleanly at a frame boundary.
    Eof,
    /// A read timeout fired before *any* byte of the next frame
    /// arrived (only with a socket read timeout set) — the caller can
    /// poll its stop flag and retry. Once the first header byte is
    /// in, the frame is read to completion regardless of timeouts.
    Idle,
}

/// Fill `buf`, tolerating short reads. Returns `Ok(false)` on clean
/// EOF before the first byte; timeouts before the first byte surface
/// as `WouldBlock`/`TimedOut` errors only when `may_idle`.
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    may_idle: bool,
) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "stream closed mid-frame",
                ));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                ) =>
            {
                // Mid-frame the bytes are in flight: keep reading.
                // Before the first byte, report idleness if allowed.
                if got == 0 && may_idle {
                    return Err(e);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one frame, distinguishing clean EOF and (when the stream has
/// a read timeout) idleness before the next frame starts.
pub fn read_frame_idle(r: &mut impl Read) -> io::Result<ReadOutcome> {
    let mut len = [0u8; 4];
    match read_full(r, &mut len, true) {
        Ok(false) => return Ok(ReadOutcome::Eof),
        Ok(true) => {}
        Err(e)
            if matches!(
                e.kind(),
                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
            ) =>
        {
            return Ok(ReadOutcome::Idle)
        }
        Err(e) => return Err(e),
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {n} exceeds MAX_FRAME"),
        ));
    }
    let mut buf = vec![0u8; n];
    read_full(r, &mut buf, false)?;
    Ok(ReadOutcome::Frame(buf))
}

/// Read one frame from a stream without a read timeout: blocks until
/// a frame or clean EOF (`None`).
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    loop {
        match read_frame_idle(r)? {
            ReadOutcome::Frame(f) => return Ok(Some(f)),
            ReadOutcome::Eof => return Ok(None),
            ReadOutcome::Idle => {}
        }
    }
}

/// Why a payload failed to decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The payload ended before the field being read.
    Truncated,
    /// An unknown request/response tag byte.
    BadTag(u8),
    /// A string field held invalid UTF-8.
    BadUtf8,
    /// Bytes remained after the last field (framing desync).
    Trailing(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "payload truncated"),
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::BadUtf8 => write!(f, "string field is not UTF-8"),
            WireError::Trailing(n) => {
                write!(f, "{n} trailing bytes after the last field")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// Append-only payload builder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u16` length + UTF-8 bytes. Strings longer than `u16::MAX`
    /// bytes are truncated at a char boundary (fields are names and
    /// panic messages; losing a tail beats failing the frame).
    pub fn str(&mut self, s: &str) {
        let mut end = s.len().min(u16::MAX as usize);
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        let bytes = &s.as_bytes()[..end];
        self.buf.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
        self.buf.extend_from_slice(bytes);
    }

    /// Presence flag byte, then the value only when present.
    pub fn opt_u32(&mut self, v: Option<u32>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u32(x);
            }
            None => self.u8(0),
        }
    }
}

/// Cursor over a payload.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let n = u16::from_le_bytes(self.take(2)?.try_into().unwrap());
        let bytes = self.take(n as usize)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    pub fn opt_u32(&mut self) -> Result<Option<u32>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            _ => Ok(Some(self.u32()?)),
        }
    }

    /// Assert the payload is fully consumed.
    pub fn finish(&self) -> Result<(), WireError> {
        let left = self.buf.len() - self.pos;
        if left == 0 {
            Ok(())
        } else {
            Err(WireError::Trailing(left))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_round_trips_through_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn oversize_frames_are_refused_both_ways() {
        let mut buf = Vec::new();
        let e = write_frame(&mut buf, &vec![0u8; MAX_FRAME + 1]);
        assert!(e.is_err());
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let e = read_frame(&mut io::Cursor::new(wire)).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_hang() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u32.to_le_bytes());
        wire.extend_from_slice(b"abc"); // 3 of 8 bytes
        let e = read_frame(&mut io::Cursor::new(wire)).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn byte_codec_round_trips_every_field_kind() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.str("cholesky");
        w.str("");
        w.opt_u32(Some(42));
        w.opt_u32(None);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.str().unwrap(), "cholesky");
        assert_eq!(r.str().unwrap(), "");
        assert_eq!(r.opt_u32().unwrap(), Some(42));
        assert_eq!(r.opt_u32().unwrap(), None);
        r.finish().unwrap();
    }

    #[test]
    fn decode_errors_are_typed() {
        let mut r = ByteReader::new(&[1, 2]);
        assert_eq!(r.u32(), Err(WireError::Truncated));
        let mut w = ByteWriter::new();
        w.u32(5);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        r.u8().unwrap();
        assert_eq!(r.finish(), Err(WireError::Trailing(3)));
        // Invalid UTF-8 in a string field.
        let bad = [2u8, 0, 0xFF, 0xFE];
        let mut r = ByteReader::new(&bad);
        assert_eq!(r.str(), Err(WireError::BadUtf8));
    }
}
