//! Open-loop load generator for a serve loop.
//!
//! **Open-loop** is the load-testing discipline that avoids
//! coordinated omission: requests are sent on a precomputed arrival
//! schedule (uniform inter-arrival jitter in `[Δ/2, 3Δ/2]` around the
//! offered mean gap, drawn from the SplitMix64 seed discipline — the
//! same process [`super::model`] integrates in virtual time), and a
//! request's latency is measured from its *scheduled* arrival, not
//! from when the sender got around to writing it. A server that
//! stalls therefore inflates the recorded latencies instead of
//! silently slowing the offered load, which is exactly the behaviour
//! an operator sizing a service needs to see.
//!
//! The generator multiplexes requests round-robin over a fixed set of
//! connections, each with its own reader thread feeding one shared
//! log-bucketed [`LatencyHistogram`]; successful responses can be
//! checked bit-exactly against locally computed sequential reference
//! digests (`--verify`), and every Nth request can be poisoned
//! (fault-injected — must come back as a typed failure frame, never a
//! dropped connection) or deadlined.

use super::frame::{read_frame, write_frame};
use super::protocol::{matrix_digest, Request, Response};
use crate::harness::report::LatencyHistogram;
use crate::sched::workload::{self, Params};
use crate::util::prng::SplitMix64;
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// What the generator offers the server.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub addr: String,
    /// Offered arrival rate, requests per second.
    pub rate_per_sec: f64,
    pub requests: usize,
    /// Connections to round-robin requests over.
    pub conns: usize,
    pub nb: usize,
    pub bs: usize,
    /// Seeds both the arrival jitter and the submitted jobs.
    pub seed: u64,
    /// Workload names, cycled per request; empty = the registry's
    /// factorisation (phase-capable) workloads.
    pub workloads: Vec<String>,
    /// Check each `Done` digest against the sequential reference.
    pub verify: bool,
    /// Poison every Nth request (0 = never).
    pub poison_every: usize,
    /// Deadline every Nth request at 0 executed tasks (0 = never).
    pub deadline_every: usize,
    /// Send a `Shutdown` frame after the run and await the ack.
    pub shutdown: bool,
}

impl LoadConfig {
    pub fn new(addr: &str) -> Self {
        LoadConfig {
            addr: addr.to_string(),
            rate_per_sec: 100.0,
            requests: 100,
            conns: 4,
            nb: 8,
            bs: 8,
            seed: 1,
            workloads: Vec::new(),
            verify: false,
            poison_every: 0,
            deadline_every: 0,
            shutdown: false,
        }
    }
}

/// What each request is expected to come back as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Normal,
    /// Fault-injected: the only acceptable terminal is `Failed`.
    Poisoned,
    /// Deadlined at 0 tasks: `Cancelled`, or `Done` if it won the
    /// race (then the digest must still check out).
    Deadlined,
}

struct Expect {
    kind: Kind,
    /// Reference digest for verification (`None` when not verifying
    /// or when the request cannot complete normally).
    digest: Option<u64>,
}

#[derive(Default)]
struct Tally {
    accepted: usize,
    busy: usize,
    draining: usize,
    rejected: usize,
    done: usize,
    failed: usize,
    cancelled: usize,
    digest_mismatches: usize,
    /// Failures/cancellations of requests that were not poisoned or
    /// deadlined, and `Done`s of poisoned ones.
    unexpected_outcomes: usize,
    send_errors: usize,
}

/// One load run's results. Latencies (µs, from scheduled arrival to
/// terminal frame) are recorded for successful responses only.
#[derive(Debug)]
pub struct LoadReport {
    pub offered_per_sec: f64,
    pub achieved_per_sec: f64,
    pub sent: usize,
    pub accepted: usize,
    pub busy: usize,
    pub draining: usize,
    pub rejected: usize,
    pub done: usize,
    pub failed: usize,
    pub cancelled: usize,
    pub digest_mismatches: usize,
    pub unexpected_outcomes: usize,
    pub send_errors: usize,
    /// Requests that never received a terminal frame — must be 0:
    /// admitted work is never dropped, refusals are typed.
    pub lost: usize,
    pub shutdown_acked: bool,
    pub hist: LatencyHistogram,
    pub elapsed: Duration,
}

impl LoadReport {
    /// The machine verdict `gprm loadgen` prints PASS/FAIL from.
    /// Shedding (`busy`) and drain refusals are *expected* under
    /// overload and are not failures; lost frames, digest mismatches,
    /// and untyped outcomes are.
    pub fn pass(&self) -> bool {
        // `shutdown_acked` is pre-set to true when no shutdown was
        // requested, so it only gates runs that sent one.
        self.lost == 0
            && self.digest_mismatches == 0
            && self.unexpected_outcomes == 0
            && self.send_errors == 0
            && self.shutdown_acked
    }
}

fn kind_of(cfg: &LoadConfig, i: usize) -> Kind {
    if cfg.poison_every > 0 && (i + 1) % cfg.poison_every == 0 {
        Kind::Poisoned
    } else if cfg.deadline_every > 0
        && (i + 1) % cfg.deadline_every == 0
    {
        Kind::Deadlined
    } else {
        Kind::Normal
    }
}

/// Drive one open-loop run. Returns `Err` on setup problems (bad
/// workload name, connect failure); server-side behaviour — typed
/// refusals, failures, lost frames — is *data*, reported in the
/// [`LoadReport`].
pub fn run(cfg: &LoadConfig) -> Result<LoadReport, String> {
    if cfg.rate_per_sec <= 0.0 {
        return Err("rate must be positive".into());
    }
    if cfg.requests == 0 || cfg.conns == 0 {
        return Err("requests and conns must be positive".into());
    }
    let p = Params::new(cfg.nb, cfg.bs);
    let names: Vec<String> = if cfg.workloads.is_empty() {
        workload::registry()
            .iter()
            .filter(|w| w.phases(&p).is_some())
            .map(|w| w.name().to_string())
            .collect()
    } else {
        cfg.workloads.clone()
    };
    let mut ref_digests: Vec<Option<u64>> = Vec::new();
    for n in &names {
        let w = workload::find(n)
            .ok_or_else(|| format!("unknown workload '{n}'"))?;
        ref_digests.push(if cfg.verify {
            let mut m = w.make_input(&p, cfg.seed as u32);
            w.reference_seq(&mut m);
            Some(matrix_digest(&m))
        } else {
            None
        });
    }
    // Per-request expectations, indexed by request id.
    let expect: Vec<Expect> = (0..cfg.requests)
        .map(|i| {
            let kind = kind_of(cfg, i);
            Expect {
                kind,
                digest: match kind {
                    Kind::Poisoned => None,
                    _ => ref_digests[i % names.len()],
                },
            }
        })
        .collect();

    // Connect all conns up front; writer halves stay on this thread,
    // reader halves go to per-connection reader threads.
    let mut writers: Vec<TcpStream> = Vec::with_capacity(cfg.conns);
    let mut reader_streams: Vec<TcpStream> =
        Vec::with_capacity(cfg.conns);
    for _ in 0..cfg.conns {
        let s = TcpStream::connect(&cfg.addr)
            .map_err(|e| format!("connect {}: {e}", cfg.addr))?;
        s.set_nodelay(true).ok();
        reader_streams.push(
            s.try_clone().map_err(|e| format!("clone stream: {e}"))?,
        );
        writers.push(s);
    }

    // id -> scheduled arrival; inserted before the frame is written,
    // removed by whichever reader sees the terminal frame. Leftovers
    // at the end are lost requests.
    let pending: Mutex<HashMap<u64, Instant>> =
        Mutex::new(HashMap::new());
    let hist: Mutex<LatencyHistogram> =
        Mutex::new(LatencyHistogram::new());
    let tally: Mutex<Tally> = Mutex::new(Tally::default());
    let expect_ref = &expect;
    let mean_gap_ns = (1e9 / cfg.rate_per_sec).max(1.0) as u64;
    let mut rng = SplitMix64::new(cfg.seed);
    let start = Instant::now();
    let mut sent = 0usize;

    std::thread::scope(|s| {
        for rs in reader_streams {
            let (pd, hs, tl) = (&pending, &hist, &tally);
            s.spawn(move || {
                reader_loop(rs, pd, hs, tl, expect_ref);
            });
        }
        let mut sched = Duration::ZERO;
        for i in 0..cfg.requests {
            let gap =
                mean_gap_ns / 2 + rng.next_u64() % (mean_gap_ns + 1);
            sched += Duration::from_nanos(gap);
            let target = start + sched;
            if let Some(wait) =
                target.checked_duration_since(Instant::now())
            {
                std::thread::sleep(wait);
            }
            let id = i as u64;
            let kind = expect_ref[i].kind;
            let req = Request::Submit {
                id,
                workload: names[i % names.len()].clone(),
                nb: cfg.nb as u32,
                bs: cfg.bs as u32,
                seed: cfg.seed as u32,
                poison_task: (kind == Kind::Poisoned).then_some(0),
                deadline: (kind == Kind::Deadlined).then_some(0),
            };
            pending.lock().unwrap().insert(id, target);
            let w = &mut writers[i % cfg.conns];
            if write_frame(w, &req.encode()).is_err() {
                pending.lock().unwrap().remove(&id);
                tally.lock().unwrap().send_errors += 1;
            } else {
                sent += 1;
            }
        }
        // Half-close every connection: the server finishes the
        // in-flight jobs, streams their terminal frames, and closes —
        // which is what pops the readers out of their loops.
        for w in &writers {
            let _ = w.shutdown(std::net::Shutdown::Write);
        }
    });

    let elapsed = start.elapsed();
    let mut shutdown_acked = true;
    if cfg.shutdown {
        shutdown_acked = matches!(
            super::client::Client::connect(&cfg.addr)
                .map_err(|e| e.to_string())
                .and_then(|mut c| c
                    .request(&Request::Shutdown)
                    .map_err(|e| e.to_string())),
            Ok(Response::ShuttingDown)
        );
    }
    let t = tally.into_inner().unwrap();
    let hist = hist.into_inner().unwrap();
    let lost = pending.into_inner().unwrap().len();
    let achieved = if elapsed.as_secs_f64() > 0.0 {
        t.done as f64 / elapsed.as_secs_f64()
    } else {
        0.0
    };
    Ok(LoadReport {
        offered_per_sec: cfg.rate_per_sec,
        achieved_per_sec: achieved,
        sent,
        accepted: t.accepted,
        busy: t.busy,
        draining: t.draining,
        rejected: t.rejected,
        done: t.done,
        failed: t.failed,
        cancelled: t.cancelled,
        digest_mismatches: t.digest_mismatches,
        unexpected_outcomes: t.unexpected_outcomes,
        send_errors: t.send_errors,
        lost,
        shutdown_acked,
        hist,
        elapsed,
    })
}

fn reader_loop(
    mut rs: TcpStream,
    pending: &Mutex<HashMap<u64, Instant>>,
    hist: &Mutex<LatencyHistogram>,
    tally: &Mutex<Tally>,
    expect: &[Expect],
) {
    while let Ok(Some(buf)) = read_frame(&mut rs) {
        let rsp = match Response::decode(&buf) {
            Ok(r) => r,
            Err(_) => {
                tally.lock().unwrap().unexpected_outcomes += 1;
                continue;
            }
        };
        let id = match rsp.id() {
            Some(id) => id,
            None => continue, // Pong / ShuttingDown
        };
        let exp = expect.get(id as usize);
        let now = Instant::now();
        // Accepted is a progress frame: keep the pending entry so
        // the terminal frame can compute the latency.
        if matches!(rsp, Response::Accepted { .. }) {
            tally.lock().unwrap().accepted += 1;
            continue;
        }
        if !rsp.is_terminal() {
            continue; // Polled
        }
        let sched = pending.lock().unwrap().remove(&id);
        let mut t = tally.lock().unwrap();
        match rsp {
            Response::Busy { .. } => t.busy += 1,
            Response::Draining { .. } => t.draining += 1,
            Response::Rejected { .. } => t.rejected += 1,
            Response::Done { digest, .. } => {
                t.done += 1;
                match exp.map(|e| e.kind) {
                    Some(Kind::Poisoned) => t.unexpected_outcomes += 1,
                    _ => {
                        if let Some(want) =
                            exp.and_then(|e| e.digest)
                        {
                            if want != digest {
                                t.digest_mismatches += 1;
                            }
                        }
                    }
                }
                if let Some(sc) = sched {
                    let us = now
                        .saturating_duration_since(sc)
                        .as_micros()
                        as u64;
                    hist.lock().unwrap().record(us);
                }
            }
            Response::Failed { .. } => {
                t.failed += 1;
                if exp.map(|e| e.kind) != Some(Kind::Poisoned) {
                    t.unexpected_outcomes += 1;
                }
            }
            Response::Cancelled { .. } => {
                t.cancelled += 1;
                if exp.map(|e| e.kind) != Some(Kind::Deadlined) {
                    t.unexpected_outcomes += 1;
                }
            }
            _ => {}
        }
    }
}
