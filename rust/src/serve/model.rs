//! Deterministic virtual-time model of the serving loop: an open-loop
//! arrival process against the persistent pool's calibrated service
//! rate, in TILEPro64 cycles.
//!
//! The host load generator ([`super::loadgen`]) measures wall-clock
//! latency, which varies machine to machine. Experiment tables and
//! the committed BENCH rows instead come from this model, which is
//! exact and portable: the pool's steady-state **service quantum**
//! `S` (cycles consumed per admitted job, from the simulator's
//! pool-stream run over the registry's mixed factorisation stream)
//! and an isolated-job **makespan floor** `M` feed a Lindley-style
//! recursion over a deterministic arrival schedule drawn from the
//! SplitMix64 seed discipline — uniform inter-arrival jitter in
//! `[Δ/2, 3Δ/2]` around the offered mean gap `Δ`. Admission mirrors
//! the pool's shed rule: a request arriving with more than
//! `max_pending` service quanta of backlog is shed (the model's
//! [`SubmitError::Overloaded`]), everything admitted completes after
//! `backlog + M` cycles. All arithmetic is integer, so every derived
//! table and BENCH row reproduces digit-for-digit on any platform.
//!
//! The shapes this predicts — flat p99 below capacity, latency
//! exploding through saturation while achieved throughput plateaus
//! at the service rate, shedding only past the pending bound — are
//! the machine checks of `gprm exp serve`, and the same predictions
//! the host loopback harness probes in wall-clock time.
//!
//! [`SubmitError::Overloaded`]: crate::sched::pool::SubmitError::Overloaded

use crate::harness::report::percentile_nearest_rank;
use crate::sched::workload::{registry, Params, Workload};
use crate::sched::TaskGraph;
use crate::tilesim::{CostModel, DataflowSim, LaunchModel, SimJob};
use crate::util::prng::SplitMix64;

/// The calibrated serving model: all quantities in simulator cycles.
#[derive(Clone, Copy, Debug)]
pub struct ServeModel {
    /// Steady-state cycles one admitted job costs the pool (total
    /// mixed-stream cycles / jobs, ceiling).
    pub service: u64,
    /// Latency floor: mean single-job pool makespan across the
    /// stream's workload kinds.
    pub makespan: u64,
    /// Shed bound, in queued jobs ([`crate::sched::PoolConfig`]'s
    /// `max_pending`).
    pub max_pending: usize,
    /// Clock the cycle counts are priced at (Hz).
    pub clock_hz: f64,
}

/// Jobs in the calibration stream (matches the `throughput`
/// experiment's mixed stream).
pub const CALIBRATION_JOBS: usize = 8;

impl ServeModel {
    /// Calibrate `S` and `M` for a `workers`-tile pool serving the
    /// registry's phase-capable (factorisation) workloads at
    /// `nb`×`nb` blocks of `bs`×`bs`, with the given shed bound.
    pub fn calibrate(
        workers: usize,
        nb: usize,
        bs: usize,
        max_pending: usize,
    ) -> ServeModel {
        let p = Params::new(nb, bs);
        let facts: Vec<&'static dyn Workload> = registry()
            .iter()
            .copied()
            .filter(|w| w.phases(&p).is_some())
            .collect();
        assert!(!facts.is_empty(), "registry has no factorisation entries");
        let graphs: Vec<TaskGraph> =
            facts.iter().map(|w| w.graph(&p)).collect();
        let jobs: Vec<SimJob> = (0..CALIBRATION_JOBS)
            .map(|i| SimJob {
                workload: facts[i % facts.len()],
                graph: &graphs[i % facts.len()],
                bs,
            })
            .collect();
        let sim = DataflowSim::tilepro(workers);
        let stream =
            sim.run_jobs(&jobs, LaunchModel::PersistentPool).cycles;
        let service = stream.div_ceil(CALIBRATION_JOBS as u64);
        // Isolated-job makespan: each kind alone through the pool,
        // averaged — the latency an uncontended request sees.
        let mks: u64 = facts
            .iter()
            .zip(&graphs)
            .map(|(w, g)| {
                let one = [SimJob { workload: *w, graph: g, bs }];
                sim.run_jobs(&one, LaunchModel::PersistentPool).cycles
            })
            .sum();
        let makespan = mks / facts.len() as u64;
        ServeModel {
            service,
            makespan,
            max_pending,
            clock_hz: CostModel::default().clock_hz,
        }
    }

    /// Mean inter-arrival gap (cycles) offering `pct`% of the pool's
    /// saturation rate `1/S`.
    pub fn gap_for_offered_pct(&self, pct: u64) -> u64 {
        assert!(pct > 0, "offered load must be positive");
        (self.service * 100) / pct
    }

    /// Drive `requests` arrivals with mean gap `mean_gap` through the
    /// model. Deterministic for a given seed.
    pub fn run(
        &self,
        mean_gap: u64,
        requests: usize,
        seed: u64,
    ) -> ModelOutcome {
        assert!(mean_gap > 0 && self.service > 0);
        let mut rng = SplitMix64::new(seed);
        let mut arrival: u64 = 0;
        // When the server frees up: the end of the last admitted
        // job's service quantum.
        let mut free: u64 = 0;
        let mut latencies: Vec<u64> = Vec::with_capacity(requests);
        let mut shed = 0usize;
        let mut horizon: u64 = 0;
        for _ in 0..requests {
            // Uniform jitter in [Δ/2, 3Δ/2]: deterministic, integer,
            // bursty enough to queue near saturation.
            let gap = mean_gap / 2 + rng.next_u64() % (mean_gap + 1);
            arrival += gap;
            let backlog = free.saturating_sub(arrival);
            // Jobs ahead that have not started service yet.
            let pending = backlog.div_ceil(self.service);
            if pending > self.max_pending as u64 {
                shed += 1;
                continue;
            }
            free = free.max(arrival) + self.service;
            let latency = backlog + self.makespan;
            latencies.push(latency);
            horizon = horizon.max(arrival + latency);
        }
        latencies.sort_unstable();
        ModelOutcome {
            latencies,
            shed,
            horizon,
            clock_hz: self.clock_hz,
        }
    }
}

/// One model run's results. Latencies are sorted ascending, in
/// cycles.
#[derive(Clone, Debug)]
pub struct ModelOutcome {
    pub latencies: Vec<u64>,
    pub shed: usize,
    /// Completion time of the last admitted job (cycles from the
    /// first arrival) — the denominator of the achieved rate.
    pub horizon: u64,
    pub clock_hz: f64,
}

impl ModelOutcome {
    pub fn completed(&self) -> usize {
        self.latencies.len()
    }

    /// Nearest-rank percentile latency in integer microseconds
    /// (866 cycles/µs — integer division, platform-exact).
    pub fn percentile_us(&self, per_mille: u32) -> u64 {
        assert!(!self.latencies.is_empty(), "no admitted requests");
        percentile_nearest_rank(&self.latencies, per_mille) / 866
    }

    /// Completed jobs per virtual second.
    pub fn achieved_per_sec(&self) -> f64 {
        if self.horizon == 0 {
            return 0.0;
        }
        self.completed() as f64 / (self.horizon as f64 / self.clock_hz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ServeModel {
        ServeModel::calibrate(8, 12, 16, 64)
    }

    #[test]
    fn calibration_is_deterministic_and_sane() {
        let a = model();
        let b = model();
        assert_eq!(a.service, b.service);
        assert_eq!(a.makespan, b.makespan);
        assert!(a.service > 0);
        // A lone job cannot finish faster than the per-job share of a
        // saturated stream, and an 8-job stream on 8 tiles overlaps:
        // service quantum < isolated makespan.
        assert!(
            a.service < a.makespan,
            "S={} M={}",
            a.service,
            a.makespan
        );
    }

    #[test]
    fn runs_are_seed_deterministic() {
        let m = model();
        let gap = m.gap_for_offered_pct(80);
        let a = m.run(gap, 500, 1);
        let b = m.run(gap, 500, 1);
        assert_eq!(a.latencies, b.latencies);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.horizon, b.horizon);
        let c = m.run(gap, 500, 2);
        assert_ne!(a.latencies, c.latencies);
    }

    #[test]
    fn latency_rises_through_saturation_and_throughput_plateaus() {
        let m = model();
        let low = m.run(m.gap_for_offered_pct(20), 1000, 1);
        let sat = m.run(m.gap_for_offered_pct(200), 1000, 1);
        assert_eq!(low.shed, 0, "shedding below capacity");
        assert!(low.percentile_us(990) < sat.percentile_us(990));
        // At 2x offered, achieved clamps near the service rate.
        let mu = m.clock_hz / m.service as f64;
        assert!(sat.achieved_per_sec() <= mu * 1.05);
        assert!(sat.achieved_per_sec() > mu * 0.5);
    }

    #[test]
    fn overload_sheds_and_a_tight_bound_sheds_more() {
        let m = model();
        let wide = m.run(m.gap_for_offered_pct(400), 1000, 1);
        assert!(wide.shed > 0, "4x offered load must shed at bound 64");
        let tight = ServeModel { max_pending: 2, ..m };
        let t = tight.run(tight.gap_for_offered_pct(400), 1000, 1);
        assert!(t.shed > wide.shed);
        // Everything admitted completes: completed + shed = requests.
        assert_eq!(t.completed() + t.shed, 1000);
        assert_eq!(wide.completed() + wide.shed, 1000);
    }

    #[test]
    fn uncontended_latency_is_the_makespan_floor() {
        let m = model();
        // 1% offered load: gaps dwarf service, queue never forms.
        let idle = m.run(m.gap_for_offered_pct(1), 200, 7);
        assert_eq!(idle.shed, 0);
        assert_eq!(idle.latencies[0], m.makespan);
        assert_eq!(*idle.latencies.last().unwrap(), m.makespan);
    }
}
