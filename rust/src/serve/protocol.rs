//! Typed request/response vocabulary of the serving protocol, and the
//! output digest that makes results checkable over the wire.
//!
//! A client speaks [`Request`] frames; the server answers with
//! [`Response`] frames. One `Submit` produces **two** responses on
//! success — an immediate admission verdict (`Accepted`, or a typed
//! rejection) and, later, a terminal frame (`Done` / `Failed` /
//! `Cancelled`) when the job completes — correlated by the
//! client-chosen `id`. Responses to different ids interleave freely:
//! the server streams each job's terminal frame as it finishes, not
//! in submission order.
//!
//! Every scheduling failure maps onto a typed frame via
//! [`Response::failure`] — [`SubmitError::Overloaded`] → `Busy`,
//! [`SubmitError::Draining`] → `Draining`, a poisoned job → `Failed`
//! with the failing attempt's coordinates, a missed deadline →
//! `Cancelled` — so overload, drain, poison and deadline are all
//! observable client-side without ever dropping a connection.
//!
//! Results travel as a [`matrix_digest`] (FNV-1a over the blocked
//! matrix's shape and f32 bit patterns), not the matrix itself: the
//! client can compute the same digest over its own sequential
//! reference, which makes "f32-bit-identical to the reference" an
//! end-to-end wire-level check at eight bytes per response.
//!
//! [`SubmitError::Overloaded`]: crate::sched::pool::SubmitError::Overloaded
//! [`SubmitError::Draining`]: crate::sched::pool::SubmitError::Draining

use super::frame::{ByteReader, ByteWriter, WireError};
use crate::linalg::blocked::BlockedSparseMatrix;
use crate::sched::pool::SubmitError;
use crate::sched::Error;

/// A client-to-server frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Submit one factorisation job. `id` is client-chosen and echoed
    /// on every response for this job. `poison_task` injects a
    /// persistent panic into that task (fault-path testing);
    /// `deadline` bounds the job to that many executed tasks before
    /// cooperative cancellation.
    Submit {
        id: u64,
        workload: String,
        nb: u32,
        bs: u32,
        seed: u32,
        poison_task: Option<u32>,
        deadline: Option<u32>,
    },
    /// Ask whether job `id` (previously submitted on this
    /// connection) has finished.
    Poll { id: u64 },
    /// Graceful drain: the server stops accepting work, finishes
    /// every admitted job, then acknowledges with
    /// [`Response::ShuttingDown`] and exits.
    Shutdown,
    /// Liveness probe; answered with [`Response::Pong`].
    Ping,
}

const REQ_SUBMIT: u8 = 1;
const REQ_POLL: u8 = 2;
const REQ_SHUTDOWN: u8 = 3;
const REQ_PING: u8 = 4;

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Request::Submit {
                id,
                workload,
                nb,
                bs,
                seed,
                poison_task,
                deadline,
            } => {
                w.u8(REQ_SUBMIT);
                w.u64(*id);
                w.str(workload);
                w.u32(*nb);
                w.u32(*bs);
                w.u32(*seed);
                w.opt_u32(*poison_task);
                w.opt_u32(*deadline);
            }
            Request::Poll { id } => {
                w.u8(REQ_POLL);
                w.u64(*id);
            }
            Request::Shutdown => w.u8(REQ_SHUTDOWN),
            Request::Ping => w.u8(REQ_PING),
        }
        w.into_inner()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let req = match r.u8()? {
            REQ_SUBMIT => Request::Submit {
                id: r.u64()?,
                workload: r.str()?,
                nb: r.u32()?,
                bs: r.u32()?,
                seed: r.u32()?,
                poison_task: r.opt_u32()?,
                deadline: r.opt_u32()?,
            },
            REQ_POLL => Request::Poll { id: r.u64()? },
            REQ_SHUTDOWN => Request::Shutdown,
            REQ_PING => Request::Ping,
            t => return Err(WireError::BadTag(t)),
        };
        r.finish()?;
        Ok(req)
    }
}

/// A server-to-client frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    /// The job was admitted (or queued); a terminal frame follows.
    Accepted { id: u64 },
    /// Overload shed ([`SubmitError::Overloaded`]): the pending queue
    /// sits at the shed limit. The job was *not* accepted; `pending`
    /// and `limit` carry the server-side queue coordinates.
    Busy { id: u64, pending: u32, limit: u32 },
    /// The server is draining ([`SubmitError::Draining`]); no new
    /// work is accepted but every already-admitted job completes.
    Draining { id: u64 },
    /// The request itself was invalid (unknown workload, oversized
    /// grid, undecodable frame, …) — a client error, not a server
    /// state.
    Rejected { id: u64, msg: String },
    /// Terminal: the job completed; `digest` is the
    /// [`matrix_digest`] of the output, `tasks` the executed kernel
    /// count, `micros` the server-side service time.
    Done { id: u64, digest: u64, tasks: u32, micros: u64 },
    /// Terminal: the job was poisoned; coordinates of the last
    /// failed attempt ([`crate::sched::JobFailure`]).
    Failed { id: u64, attempts: u32, task: u32, op: String, msg: String },
    /// Terminal: the job was cooperatively cancelled (deadline) after
    /// `ran` executed kernels.
    Cancelled { id: u64, ran: u32 },
    /// Answer to [`Request::Poll`].
    Polled { id: u64, known: bool, done: bool },
    /// Answer to [`Request::Shutdown`], sent after the drain
    /// completed — every admitted job has already produced its
    /// terminal frame by the time this arrives.
    ShuttingDown,
    /// Answer to [`Request::Ping`].
    Pong,
}

const RSP_ACCEPTED: u8 = 1;
const RSP_BUSY: u8 = 2;
const RSP_DRAINING: u8 = 3;
const RSP_REJECTED: u8 = 4;
const RSP_DONE: u8 = 5;
const RSP_FAILED: u8 = 6;
const RSP_CANCELLED: u8 = 7;
const RSP_POLLED: u8 = 8;
const RSP_SHUTTING_DOWN: u8 = 9;
const RSP_PONG: u8 = 10;

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Response::Accepted { id } => {
                w.u8(RSP_ACCEPTED);
                w.u64(*id);
            }
            Response::Busy { id, pending, limit } => {
                w.u8(RSP_BUSY);
                w.u64(*id);
                w.u32(*pending);
                w.u32(*limit);
            }
            Response::Draining { id } => {
                w.u8(RSP_DRAINING);
                w.u64(*id);
            }
            Response::Rejected { id, msg } => {
                w.u8(RSP_REJECTED);
                w.u64(*id);
                w.str(msg);
            }
            Response::Done { id, digest, tasks, micros } => {
                w.u8(RSP_DONE);
                w.u64(*id);
                w.u64(*digest);
                w.u32(*tasks);
                w.u64(*micros);
            }
            Response::Failed { id, attempts, task, op, msg } => {
                w.u8(RSP_FAILED);
                w.u64(*id);
                w.u32(*attempts);
                w.u32(*task);
                w.str(op);
                w.str(msg);
            }
            Response::Cancelled { id, ran } => {
                w.u8(RSP_CANCELLED);
                w.u64(*id);
                w.u32(*ran);
            }
            Response::Polled { id, known, done } => {
                w.u8(RSP_POLLED);
                w.u64(*id);
                w.u8(u8::from(*known));
                w.u8(u8::from(*done));
            }
            Response::ShuttingDown => w.u8(RSP_SHUTTING_DOWN),
            Response::Pong => w.u8(RSP_PONG),
        }
        w.into_inner()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let rsp = match r.u8()? {
            RSP_ACCEPTED => Response::Accepted { id: r.u64()? },
            RSP_BUSY => Response::Busy {
                id: r.u64()?,
                pending: r.u32()?,
                limit: r.u32()?,
            },
            RSP_DRAINING => Response::Draining { id: r.u64()? },
            RSP_REJECTED => {
                Response::Rejected { id: r.u64()?, msg: r.str()? }
            }
            RSP_DONE => Response::Done {
                id: r.u64()?,
                digest: r.u64()?,
                tasks: r.u32()?,
                micros: r.u64()?,
            },
            RSP_FAILED => Response::Failed {
                id: r.u64()?,
                attempts: r.u32()?,
                task: r.u32()?,
                op: r.str()?,
                msg: r.str()?,
            },
            RSP_CANCELLED => {
                Response::Cancelled { id: r.u64()?, ran: r.u32()? }
            }
            RSP_POLLED => Response::Polled {
                id: r.u64()?,
                known: r.u8()? != 0,
                done: r.u8()? != 0,
            },
            RSP_SHUTTING_DOWN => Response::ShuttingDown,
            RSP_PONG => Response::Pong,
            t => return Err(WireError::BadTag(t)),
        };
        r.finish()?;
        Ok(rsp)
    }

    /// Map a scheduling [`Error`] for job `id` onto its typed frame.
    /// Total: every error variant has a frame, so a failure path can
    /// never fall back to dropping the connection.
    pub fn failure(id: u64, e: &Error) -> Response {
        match e {
            Error::Submit(SubmitError::Overloaded { pending, limit }) => {
                Response::Busy {
                    id,
                    pending: *pending as u32,
                    limit: *limit as u32,
                }
            }
            Error::Submit(SubmitError::Draining) => {
                Response::Draining { id }
            }
            Error::Job(f) => {
                let last = f.last();
                Response::Failed {
                    id,
                    attempts: f.attempts.len() as u32,
                    task: last.task as u32,
                    op: last.op.to_string(),
                    msg: last.msg.clone(),
                }
            }
            Error::Cancelled { ran } => {
                Response::Cancelled { id, ran: *ran as u32 }
            }
            // GraphTooLarge, ShutDown, UnknownWorkload and the rest
            // are request errors: typed text is enough.
            other => Response::Rejected { id, msg: other.to_string() },
        }
    }

    /// Is this a terminal frame for a submitted id (exactly one per
    /// accepted job / rejection)?
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            Response::Busy { .. }
                | Response::Draining { .. }
                | Response::Rejected { .. }
                | Response::Done { .. }
                | Response::Failed { .. }
                | Response::Cancelled { .. }
        )
    }

    /// The job id this frame speaks about, if any.
    pub fn id(&self) -> Option<u64> {
        match self {
            Response::Accepted { id }
            | Response::Busy { id, .. }
            | Response::Draining { id }
            | Response::Rejected { id, .. }
            | Response::Done { id, .. }
            | Response::Failed { id, .. }
            | Response::Cancelled { id, .. }
            | Response::Polled { id, .. } => Some(*id),
            Response::ShuttingDown | Response::Pong => None,
        }
    }
}

/// FNV-1a over a blocked matrix's shape and f32 bit patterns, block
/// row-major, allocated blocks only (the null pattern is part of the
/// digest by omission). Bit-identical outputs — and only those —
/// digest equal, so comparing digests over the wire is exactly the
/// workload's `verify_bits` check at a distance.
pub fn matrix_digest(a: &BlockedSparseMatrix) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    };
    eat(&(a.nb() as u64).to_le_bytes());
    eat(&(a.bs() as u64).to_le_bytes());
    for ii in 0..a.nb() {
        for jj in 0..a.nb() {
            if let Some(block) = a.block(ii, jj) {
                eat(&(ii as u32).to_le_bytes());
                eat(&(jj as u32).to_le_bytes());
                for v in block {
                    eat(&v.to_bits().to_le_bytes());
                }
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::workload;
    use crate::sched::workload::Params;
    use crate::sched::{FailedAttempt, JobFailure};

    fn round_trip_req(r: Request) {
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
    }

    fn round_trip_rsp(r: Response) {
        assert_eq!(Response::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn every_request_round_trips() {
        round_trip_req(Request::Submit {
            id: 9,
            workload: "sparselu".into(),
            nb: 8,
            bs: 16,
            seed: 3,
            poison_task: None,
            deadline: None,
        });
        round_trip_req(Request::Submit {
            id: u64::MAX,
            workload: "cholesky".into(),
            nb: 1,
            bs: 1,
            seed: 0,
            poison_task: Some(7),
            deadline: Some(0),
        });
        round_trip_req(Request::Poll { id: 4 });
        round_trip_req(Request::Shutdown);
        round_trip_req(Request::Ping);
    }

    #[test]
    fn every_response_round_trips() {
        round_trip_rsp(Response::Accepted { id: 1 });
        round_trip_rsp(Response::Busy { id: 2, pending: 64, limit: 64 });
        round_trip_rsp(Response::Draining { id: 3 });
        round_trip_rsp(Response::Rejected {
            id: 4,
            msg: "unknown workload \"qr\"".into(),
        });
        round_trip_rsp(Response::Done {
            id: 5,
            digest: 0xFEED_FACE_CAFE_BEEF,
            tasks: 120,
            micros: 1_000_000,
        });
        round_trip_rsp(Response::Failed {
            id: 6,
            attempts: 1,
            task: 17,
            op: "lu0".into(),
            msg: "injected fault: panic".into(),
        });
        round_trip_rsp(Response::Cancelled { id: 7, ran: 3 });
        round_trip_rsp(Response::Polled { id: 8, known: true, done: false });
        round_trip_rsp(Response::ShuttingDown);
        round_trip_rsp(Response::Pong);
    }

    #[test]
    fn bad_tags_and_truncation_are_typed_errors() {
        assert_eq!(Request::decode(&[99]), Err(WireError::BadTag(99)));
        assert_eq!(Response::decode(&[99]), Err(WireError::BadTag(99)));
        assert_eq!(Request::decode(&[]), Err(WireError::Truncated));
        let mut buf = Request::Poll { id: 1 }.encode();
        buf.pop();
        assert_eq!(Request::decode(&buf), Err(WireError::Truncated));
        buf = Request::Ping.encode();
        buf.push(0);
        assert_eq!(Request::decode(&buf), Err(WireError::Trailing(1)));
    }

    #[test]
    fn every_scheduling_error_maps_to_a_typed_frame() {
        let cases: Vec<(Error, Response)> = vec![
            (
                Error::Submit(SubmitError::Overloaded {
                    pending: 5,
                    limit: 4,
                }),
                Response::Busy { id: 1, pending: 5, limit: 4 },
            ),
            (
                Error::Submit(SubmitError::Draining),
                Response::Draining { id: 1 },
            ),
            (Error::Cancelled { ran: 2 }, Response::Cancelled { id: 1, ran: 2 }),
        ];
        for (e, want) in cases {
            assert_eq!(Response::failure(1, &e), want);
        }
        let f = JobFailure {
            attempts: vec![
                FailedAttempt {
                    attempt: 1,
                    op: "lu0",
                    task: 0,
                    msg: "a".into(),
                },
                FailedAttempt {
                    attempt: 2,
                    op: "fwd",
                    task: 9,
                    msg: "b".into(),
                },
            ],
        };
        match Response::failure(3, &Error::Job(f)) {
            Response::Failed { id, attempts, task, op, msg } => {
                assert_eq!(
                    (id, attempts, task, op.as_str(), msg.as_str()),
                    (3, 2, 9, "fwd", "b")
                );
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // Everything else degrades to a typed Rejected, never a drop.
        for e in [
            Error::Submit(SubmitError::ShutDown),
            Error::Submit(SubmitError::GraphTooLarge {
                tasks: 10,
                capacity: 4,
            }),
            Error::UnknownWorkload("qr".into()),
            Error::UnknownJob,
        ] {
            assert!(matches!(
                Response::failure(0, &e),
                Response::Rejected { .. }
            ));
        }
    }

    #[test]
    fn terminal_classification_matches_the_protocol_contract() {
        assert!(!Response::Accepted { id: 0 }.is_terminal());
        assert!(!Response::Pong.is_terminal());
        assert!(!Response::Polled { id: 0, known: false, done: false }
            .is_terminal());
        assert!(Response::Busy { id: 0, pending: 0, limit: 0 }
            .is_terminal());
        assert!(Response::Done { id: 0, digest: 0, tasks: 0, micros: 0 }
            .is_terminal());
    }

    #[test]
    fn digest_is_bit_exact_and_shape_sensitive() {
        let w = workload::find("sparselu").unwrap();
        let p = Params::new(5, 4);
        let a = w.make_input(&p, 0);
        let b = w.make_input(&p, 0);
        assert_eq!(matrix_digest(&a), matrix_digest(&b));
        // The digest moves on a single-bit value change…
        let mut c = a.deep_clone();
        {
            let blk = c.block_mut(0, 0).unwrap();
            blk[0] = f32::from_bits(blk[0].to_bits() ^ 1);
        }
        assert_ne!(matrix_digest(&a), matrix_digest(&c));
        // …and the factorised matrix digests differently from the
        // input but identically to the sequential reference.
        let mut f1 = a.deep_clone();
        w.reference_seq(&mut f1);
        let mut f2 = b.deep_clone();
        w.reference_seq(&mut f2);
        assert_ne!(matrix_digest(&a), matrix_digest(&f1));
        assert_eq!(matrix_digest(&f1), matrix_digest(&f2));
    }
}
