//! The serving loop: one shared persistent [`Pool`] behind a
//! [`std::net::TcpListener`], translating wire requests into
//! [`Session`] jobs and streaming typed responses back as jobs
//! complete.
//!
//! # Threading
//!
//! Everything runs under one `std::thread::scope`: an accept loop
//! (non-blocking, polling the stop flag), one reader thread per
//! connection, one writer thread per connection (draining an mpsc
//! channel of responses, so the reader and any number of job waiters
//! can emit frames without interleaving partial writes), and one
//! tiny waiter thread per in-flight job (blocks on
//! [`JobHandle::wait`] *outside* the session lock, then briefly locks
//! the shared [`Session`] to resolve and retire the output). The
//! session mutex is only ever held for non-blocking calls — submits,
//! and resolve/[`Session::take_output`] of already-finished jobs —
//! so the server cannot deadlock on it.
//!
//! # Overload, drain, shutdown
//!
//! Admission control is the pool's own: past
//! [`crate::sched::PoolConfig`]'s `max_pending` the submit returns
//! [`SubmitError::Overloaded`] and the client sees a typed
//! [`Response::Busy`] — the job was refused at the door, and jobs
//! already accepted are never dropped. A [`Request::Shutdown`] frame
//! (or SIGTERM, see [`install_term_handler`]) flips the stop flag and
//! [`Pool::drain`]s: submissions racing the drain get typed
//! [`Response::Draining`] frames, every admitted job still delivers
//! its terminal frame, and the [`Response::ShuttingDown`] ack is sent
//! only after the drain completed. Jobs are retired through
//! [`Session::take_output`] as their terminal frames go out, so a
//! long-running server's memory is bounded by its in-flight jobs.
//!
//! [`SubmitError::Overloaded`]: crate::sched::pool::SubmitError::Overloaded

use super::frame::{read_frame_idle, write_frame, ReadOutcome};
use super::protocol::{matrix_digest, Request, Response};
use crate::sched::workload;
use crate::sched::{
    Error, FaultKind, FaultSet, JobSpec, Pool, PoolConfig, Session,
};
use crate::sched::pool::{JobHandle, SubmitError};
use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server sizing. The pool fields mirror [`PoolConfig`]; `max_nb` /
/// `max_bs` bound a *request's* grid so one hostile submit cannot
/// make the server build an arbitrarily large graph.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    pub task_capacity: usize,
    pub max_jobs: usize,
    /// Shed bound (pool `max_pending`); `None` queues unboundedly.
    pub max_pending: Option<usize>,
    pub domains: usize,
    pub max_nb: usize,
    pub max_bs: usize,
}

impl ServeConfig {
    /// Serving defaults: pool defaults plus a 64-job shed bound (a
    /// server must shed, not queue unboundedly) and a 64×64-block
    /// request ceiling.
    pub fn new(workers: usize) -> Self {
        let p = PoolConfig::new(workers);
        Self {
            workers,
            task_capacity: p.task_capacity,
            max_jobs: p.max_jobs,
            max_pending: Some(64),
            domains: p.domains,
            max_nb: 64,
            max_bs: 64,
        }
    }
}

/// What the server did over its lifetime (returned by
/// [`Server::run`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub connections: usize,
    /// Jobs admitted (each produced exactly one terminal frame).
    pub accepted: usize,
    /// Submissions shed with [`Response::Busy`].
    pub shed: usize,
    /// Submissions refused with [`Response::Draining`].
    pub drained: usize,
    /// Submissions refused with [`Response::Rejected`].
    pub rejected: usize,
    pub completed: usize,
    pub failed: usize,
    pub cancelled: usize,
}

#[derive(Default)]
struct Counters {
    connections: AtomicUsize,
    accepted: AtomicUsize,
    shed: AtomicUsize,
    drained: AtomicUsize,
    rejected: AtomicUsize,
    completed: AtomicUsize,
    failed: AtomicUsize,
    cancelled: AtomicUsize,
}

impl Counters {
    fn snapshot(&self) -> ServeStats {
        let g = |a: &AtomicUsize| a.load(Ordering::SeqCst);
        ServeStats {
            connections: g(&self.connections),
            accepted: g(&self.accepted),
            shed: g(&self.shed),
            drained: g(&self.drained),
            rejected: g(&self.rejected),
            completed: g(&self.completed),
            failed: g(&self.failed),
            cancelled: g(&self.cancelled),
        }
    }
}

/// Process-wide SIGTERM latch (see [`install_term_handler`]).
static TERM: AtomicBool = AtomicBool::new(false);

extern "C" fn on_term(_sig: i32) {
    // Async-signal-safe: a single relaxed store.
    TERM.store(true, Ordering::Relaxed);
}

/// Install a SIGTERM handler that asks every [`Server::run`] loop in
/// the process to drain gracefully (same path as a
/// [`Request::Shutdown`] frame: admitted jobs finish, then the server
/// exits). No-op off Unix.
#[cfg(unix)]
pub fn install_term_handler() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        let _ = signal(SIGTERM, on_term);
    }
}

/// No-op off Unix.
#[cfg(not(unix))]
pub fn install_term_handler() {
    let _ = on_term; // keep the handler referenced on every target
}

/// Has SIGTERM been received (after [`install_term_handler`])?
pub fn term_requested() -> bool {
    TERM.load(Ordering::Relaxed)
}

fn stopping(stop: &AtomicBool) -> bool {
    stop.load(Ordering::SeqCst) || term_requested()
}

/// A bound, not-yet-running server. [`Server::bind`] on port 0 picks
/// an ephemeral loopback port — [`Server::local_addr`] reports it —
/// which is how the tests and the in-process harness avoid port
/// collisions.
pub struct Server {
    listener: TcpListener,
    cfg: ServeConfig,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(addr: &str, cfg: ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A flag that makes [`Server::run`] wind down as if a
    /// [`Request::Shutdown`] frame had arrived (for embedding the
    /// server in tests/benches).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until a [`Request::Shutdown`] frame, the
    /// [`Server::stop_flag`], or SIGTERM. Blocks. On return every
    /// accepted job has completed and delivered its terminal frame.
    pub fn run(self) -> ServeStats {
        self.listener
            .set_nonblocking(true)
            .expect("serve listener nonblocking");
        let cfg = self.cfg;
        let stop = &*self.stop;
        let counters = Counters::default();
        let pool = Pool::with_config(PoolConfig {
            workers: cfg.workers,
            task_capacity: cfg.task_capacity,
            max_jobs: cfg.max_jobs,
            max_pending: cfg.max_pending,
            domains: cfg.domains,
        });
        let session = Mutex::new(Session::new(&pool));
        std::thread::scope(|s| {
            while !stopping(stop) {
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        counters
                            .connections
                            .fetch_add(1, Ordering::SeqCst);
                        let sess = &session;
                        let ctr = &counters;
                        let pl = &pool;
                        let cf = &cfg;
                        s.spawn(move || {
                            handle_conn(
                                s, stream, pl, sess, stop, ctr, cf,
                            )
                        });
                    }
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            // The scope now waits for every connection (and its job
            // waiters) to finish — each terminal frame is delivered
            // before the writer threads exit.
        });
        // Quiesce regardless of how we stopped (flag/SIGTERM paths
        // have not drained yet; after a Shutdown frame this returns
        // immediately).
        pool.drain();
        counters.snapshot()
    }
}

/// One connection: decode requests, answer small ones inline, fan
/// submits out to per-job waiter threads. Never drops the connection
/// on a request error — undecodable bytes get a final typed
/// [`Response::Rejected`] (the stream is beyond resync at that
/// point).
#[allow(clippy::too_many_arguments)]
fn handle_conn<'scope, 'env, 'p: 'env>(
    s: &'scope std::thread::Scope<'scope, 'env>,
    stream: TcpStream,
    pool: &'env Pool,
    session: &'env Mutex<Session<'p>>,
    stop: &'env AtomicBool,
    ctr: &'env Counters,
    cfg: &'env ServeConfig,
) {
    let mut ws = match stream.try_clone() {
        Ok(x) => x,
        Err(_) => return,
    };
    let (tx, rx) = std::sync::mpsc::channel::<Response>();
    s.spawn(move || {
        let mut alive = true;
        for rsp in rx {
            if alive && write_frame(&mut ws, &rsp.encode()).is_err() {
                // Keep draining so senders' frames are consumed, but
                // stop touching the dead socket.
                alive = false;
            }
        }
    });
    let mut rs = stream;
    // A short read timeout lets the reader poll the stop flag
    // between frames without busy-spinning.
    rs.set_read_timeout(Some(Duration::from_millis(5))).ok();
    let inflight = Arc::new(AtomicUsize::new(0));
    let tracked: Arc<Mutex<HashMap<u64, JobHandle>>> =
        Arc::new(Mutex::new(HashMap::new()));
    // After a stop is observed with nothing in flight, keep reading
    // for a grace window so a submit racing the drain still gets its
    // typed Draining frame instead of a closed socket.
    let mut stop_seen: Option<Instant> = None;
    loop {
        match read_frame_idle(&mut rs) {
            Ok(ReadOutcome::Frame(buf)) => match Request::decode(&buf) {
                Ok(req) => serve_request(
                    s, req, pool, session, stop, ctr, cfg, &tx,
                    &inflight, &tracked,
                ),
                Err(e) => {
                    ctr.rejected.fetch_add(1, Ordering::SeqCst);
                    let _ = tx.send(Response::Rejected {
                        id: u64::MAX,
                        msg: format!("undecodable request: {e}"),
                    });
                    break;
                }
            },
            Ok(ReadOutcome::Eof) => break,
            Ok(ReadOutcome::Idle) => {
                if stopping(stop)
                    && inflight.load(Ordering::SeqCst) == 0
                {
                    let since =
                        *stop_seen.get_or_insert_with(Instant::now);
                    if since.elapsed() > Duration::from_millis(100) {
                        break;
                    }
                } else {
                    stop_seen = None;
                }
            }
            Err(_) => break,
        }
    }
    // Dropping the reader's sender lets the writer exit once the
    // remaining waiters have sent their terminal frames.
}

#[allow(clippy::too_many_arguments)]
fn serve_request<'scope, 'env, 'p: 'env>(
    s: &'scope std::thread::Scope<'scope, 'env>,
    req: Request,
    pool: &'env Pool,
    session: &'env Mutex<Session<'p>>,
    stop: &'env AtomicBool,
    ctr: &'env Counters,
    cfg: &ServeConfig,
    tx: &Sender<Response>,
    inflight: &Arc<AtomicUsize>,
    tracked: &Arc<Mutex<HashMap<u64, JobHandle>>>,
) {
    match req {
        Request::Ping => {
            let _ = tx.send(Response::Pong);
        }
        Request::Poll { id } => {
            let done = tracked
                .lock()
                .unwrap()
                .get(&id)
                .map(|h| h.is_done());
            let _ = tx.send(Response::Polled {
                id,
                known: done.is_some(),
                done: done.unwrap_or(false),
            });
        }
        Request::Shutdown => {
            // Stop accepting, finish everything admitted (across
            // *all* connections), then acknowledge. Late submits
            // racing this drain get typed Draining frames.
            stop.store(true, Ordering::SeqCst);
            pool.drain();
            let _ = tx.send(Response::ShuttingDown);
        }
        Request::Submit {
            id,
            workload,
            nb,
            bs,
            seed,
            poison_task,
            deadline,
        } => {
            let w = match workload::find(&workload) {
                Some(w) => w,
                None => {
                    ctr.rejected.fetch_add(1, Ordering::SeqCst);
                    let e = Error::UnknownWorkload(workload);
                    let _ = tx.send(Response::failure(id, &e));
                    return;
                }
            };
            if nb == 0
                || bs == 0
                || nb as usize > cfg.max_nb
                || bs as usize > cfg.max_bs
            {
                ctr.rejected.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(Response::Rejected {
                    id,
                    msg: format!(
                        "grid {nb}x{nb} blocks of {bs}x{bs} outside \
                         the server's limit {}x{} blocks of {}x{}",
                        cfg.max_nb, cfg.max_nb, cfg.max_bs, cfg.max_bs
                    ),
                });
                return;
            }
            let t0 = Instant::now();
            let submitted = {
                let mut sess = session.lock().unwrap();
                let mut b = sess
                    .job(JobSpec::new(w, nb as usize, bs as usize))
                    .seed(seed);
                if let Some(t) = poison_task {
                    b = b.inject(FaultSet::single(
                        t as usize,
                        FaultKind::Panic,
                    ));
                }
                if let Some(d) = deadline {
                    b = b.deadline(d as usize);
                }
                b.submit()
            };
            let h = match submitted {
                Ok(h) => h,
                Err(e) => {
                    match &e {
                        Error::Submit(SubmitError::Overloaded {
                            ..
                        }) => {
                            ctr.shed.fetch_add(1, Ordering::SeqCst);
                        }
                        Error::Submit(SubmitError::Draining) => {
                            ctr.drained
                                .fetch_add(1, Ordering::SeqCst);
                        }
                        _ => {
                            ctr.rejected
                                .fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    let _ = tx.send(Response::failure(id, &e));
                    return;
                }
            };
            ctr.accepted.fetch_add(1, Ordering::SeqCst);
            inflight.fetch_add(1, Ordering::SeqCst);
            tracked.lock().unwrap().insert(id, h.clone());
            let _ = tx.send(Response::Accepted { id });
            let tx2 = tx.clone();
            let tracked2 = tracked.clone();
            let inflight2 = inflight.clone();
            s.spawn(move || {
                // Wait at the pool level, outside the session lock —
                // other submits and waiters proceed meanwhile.
                let _ = h.wait();
                let rsp = {
                    let mut sess = session.lock().unwrap();
                    match sess.resolve_handle(&h) {
                        Ok(stats) => match sess.take_output(&h) {
                            Ok(out) => Response::Done {
                                id,
                                digest: matrix_digest(&out),
                                tasks: stats.executed as u32,
                                micros: t0.elapsed().as_micros()
                                    as u64,
                            },
                            Err(e) => Response::failure(id, &e),
                        },
                        Err(e) => {
                            // Retire the failed job's state too.
                            let _ = sess.take_output(&h);
                            Response::failure(id, &e)
                        }
                    }
                };
                match &rsp {
                    Response::Done { .. } => {
                        ctr.completed.fetch_add(1, Ordering::SeqCst);
                    }
                    Response::Failed { .. } => {
                        ctr.failed.fetch_add(1, Ordering::SeqCst);
                    }
                    Response::Cancelled { .. } => {
                        ctr.cancelled.fetch_add(1, Ordering::SeqCst);
                    }
                    _ => {}
                }
                tracked2.lock().unwrap().remove(&id);
                let _ = tx2.send(rsp);
                inflight2.fetch_sub(1, Ordering::SeqCst);
            });
        }
    }
}
