//! A minimal blocking client for the serve wire protocol — the
//! building block of [`super::loadgen`], the loopback tests, and any
//! external tooling that wants to talk to `gprm serve`.
//!
//! One [`Client`] wraps one TCP connection. Requests and responses
//! are *decoupled*: [`Client::send`] writes a frame and returns,
//! [`Client::recv`] blocks for the next response frame whoever it
//! belongs to (the server interleaves terminal frames of concurrent
//! jobs in completion order). [`Client::request`] is the simple
//! lock-step helper for callers that keep at most one request in
//! flight.

use super::frame::{read_frame, write_frame};
use super::protocol::{Request, Response, WireError};
use std::io;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};

/// One connection to a serve loop.
pub struct Client {
    stream: TcpStream,
}

/// Client-side receive errors: transport vs. protocol decode.
#[derive(Debug)]
pub enum RecvError {
    /// The socket failed or closed mid-frame.
    Io(io::Error),
    /// The server closed the connection cleanly (EOF between frames).
    Closed,
    /// The bytes arrived but did not decode as a [`Response`].
    Wire(WireError),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Io(e) => write!(f, "serve transport: {e}"),
            RecvError::Closed => write!(f, "server closed connection"),
            RecvError::Wire(e) => write!(f, "bad response frame: {e}"),
        }
    }
}

impl std::error::Error for RecvError {}

impl From<io::Error> for RecvError {
    fn from(e: io::Error) -> Self {
        RecvError::Io(e)
    }
}

impl Client {
    /// Connect to a serve loop (e.g. `"127.0.0.1:7979"`).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        // Latency harness: don't batch tiny frames.
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    /// Write one request frame.
    pub fn send(&mut self, req: &Request) -> io::Result<()> {
        write_frame(&mut self.stream, &req.encode())
    }

    /// Block for the next response frame, in server send order.
    pub fn recv(&mut self) -> Result<Response, RecvError> {
        match read_frame(&mut self.stream)? {
            None => Err(RecvError::Closed),
            Some(buf) => {
                Response::decode(&buf).map_err(RecvError::Wire)
            }
        }
    }

    /// Lock-step helper: send, then block for one response. Only
    /// sound when no other request of this client is still pending a
    /// frame.
    pub fn request(
        &mut self,
        req: &Request,
    ) -> Result<Response, RecvError> {
        self.send(req)?;
        self.recv()
    }

    /// Half-close the write side: tells the server this client is
    /// done submitting, while terminal frames of in-flight jobs can
    /// still be received.
    pub fn finish_sending(&mut self) -> io::Result<()> {
        self.stream.shutdown(Shutdown::Write)
    }
}
