//! Factorisation-as-a-service: a serving front-end over the
//! persistent [`Pool`](crate::sched::Pool).
//!
//! The paper benchmarks one factorisation at a time; this subsystem
//! turns the same scheduler into a long-running service and measures
//! it the way services are measured — offered load swept through
//! saturation, tail latency percentiles, typed overload behaviour.
//!
//! * [`frame`] — length-delimited framing and byte-level codecs over
//!   plain `std::net` (no external dependencies).
//! * [`protocol`] — typed [`Request`](protocol::Request) /
//!   [`Response`](protocol::Response) frames, the total mapping from
//!   scheduler errors onto typed refusals, and the FNV-1a
//!   [`matrix_digest`](protocol::matrix_digest) that lets a client
//!   check a result bit-exactly without shipping the matrix.
//! * [`server`] — the `gprm serve` loop: one shared pool + session,
//!   per-connection reader/writer threads, per-job waiters, graceful
//!   drain on `Shutdown` frames or SIGTERM.
//! * [`client`] — a minimal blocking client.
//! * [`loadgen`] — the `gprm loadgen` open-loop load generator
//!   (coordinated-omission-free arrivals, shared log-bucketed
//!   latency histogram, digest verification, poison/deadline
//!   injection).
//! * [`model`] — the deterministic virtual-time serving model behind
//!   `gprm exp serve` and the committed BENCH rows.
//!
//! See the crate-level "Serving front-end" section for the wire
//! format and a loopback quickstart.

pub mod client;
pub mod frame;
pub mod loadgen;
pub mod model;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use loadgen::{LoadConfig, LoadReport};
pub use model::{ModelOutcome, ServeModel};
pub use protocol::{matrix_digest, Request, Response};
pub use server::{
    install_term_handler, ServeConfig, Server, ServeStats,
};
