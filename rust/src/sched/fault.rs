//! Fault injection & recovery: deterministic, seeded failure as a
//! first-class input to the pool.
//!
//! The paper's case for GPRM-style task management is "efficiency,
//! stability, and flexibility" — but a runtime that only *contains*
//! failure (PR 4's per-job poisoning) has no story for recovering
//! from it, bounding it, or shedding it. This module makes every
//! failure mode a replayable `(plan, seed)` pair, exactly like the
//! scenario engine made adversarial load one:
//!
//! * A [`FaultKind`] names one way a kernel can misbehave: die
//!   ([`FaultKind::Panic`]), die a fixed number of times and then
//!   recover ([`FaultKind::TransientPanic`]), straggle
//!   ([`FaultKind::Delay`]), or silently produce wrong bits
//!   ([`FaultKind::Corrupt`] — caught by the workload's own
//!   bit-identity verifier, never by the runtime).
//! * A [`FaultSet`] pins faults to task coordinates inside one job;
//!   [`faulty_kernel_runner`] wraps the ordinary
//!   [`kernel_runner`] dispatch with the injection. Transient
//!   counters are shared across retry attempts (an [`std::sync::Arc`]
//!   of atomics), so "fails twice, then succeeds" means exactly that
//!   even though every retry rebuilds the runner from pristine input.
//! * A [`RetryPolicy`] tells the [`Session`] how often to resubmit a
//!   poisoned job and how long to back off between attempts; the
//!   deadline/cancel/shed/drain controls live on the pool itself
//!   (see [`super::pool::CancelToken`], [`PoolConfig::max_pending`]
//!   and [`Pool::drain`]).
//! * [`FAULT_SCENARIOS`] is a second scenario registry — same
//!   [`Scenario`] machinery, same SplitMix64 keying, same
//!   invariant vocabulary — whose plans inject faults, deadlines,
//!   cancellation, shedding and drain, each replayable via
//!   `gprm exp --fault <name> --seed N`.
//!
//! Fault coordinates are stored raw in plans and wrapped onto the
//! job's graph (`task % graph.len()`) when the runner is built, so a
//! plan never needs to know a graph's exact size to be valid.
//!
//! Deadline- and cancel-flagged plan jobs only use workloads whose
//! input pre-allocates every block the graph touches (Cholesky,
//! matmul): a cancelled job skips an arbitrary suffix of its tasks,
//! and SparseLU's skipped fill-in allocations would turn a clean
//! cancellation into a missing-block panic downstream.
//!
//! [`kernel_runner`]: super::workload::kernel_runner
//! [`Session`]: super::session::Session
//! [`PoolConfig::max_pending`]: super::pool::PoolConfig::max_pending
//! [`Pool::drain`]: super::pool::Pool::drain

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::graph::{TaskGraph, TaskId};
use super::scenario::{
    self, BatchPacing, CapacityPlan, JobPlan, Scenario, ScenarioPlan,
};
use super::workload::{kernel_runner, registry, BlockKernel, Workload};
use crate::linalg::blocked::SharedBlocked;
use crate::util::prng::SplitMix64;

// --- fault vocabulary ----------------------------------------------------

/// One named way a kernel invocation can misbehave.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The kernel panics on every attempt (a persistent fault —
    /// retries exhaust into a typed [`super::error::JobFailure`]).
    Panic,
    /// The kernel panics on the first `fails` attempts and runs
    /// cleanly afterwards (a transient fault — recoverable under a
    /// [`RetryPolicy`] with `max_attempts > fails`).
    TransientPanic { fails: u32 },
    /// The kernel straggles: spin `spin` iterations, then run
    /// normally. Harmless to correctness by construction.
    Delay { spin: u32 },
    /// The kernel runs normally, then flips the task's own write
    /// block by `+1.0` at element `elem % block_len` — a silent
    /// wrong-answer fault only the workload's bit-identity verifier
    /// can catch.
    Corrupt { elem: usize },
}

/// One fault pinned to a task coordinate inside a job. `task` is a
/// raw coordinate; it is wrapped onto the job's graph
/// (`task % graph.len()`) when the runner is built.
#[derive(Debug)]
pub struct InjectedFault {
    pub task: usize,
    pub kind: FaultKind,
    /// Remaining panics for [`FaultKind::TransientPanic`]; shared
    /// across retry attempts via the [`FaultSet`]'s `Arc`.
    remaining: AtomicU32,
}

impl InjectedFault {
    fn new(task: usize, kind: FaultKind) -> Self {
        let remaining = match kind {
            FaultKind::TransientPanic { fails } => fails,
            _ => 0,
        };
        Self { task, kind, remaining: AtomicU32::new(remaining) }
    }

    /// Panics left before a transient fault heals (diagnostics).
    pub fn remaining(&self) -> u32 {
        self.remaining.load(Ordering::Acquire)
    }
}

/// The faults injected into one job. Cloning shares the transient
/// counters, which is exactly what retry resubmission needs: the
/// healed/unhealed state survives the rebuild of the runner.
#[derive(Clone, Debug, Default)]
pub struct FaultSet {
    inner: Arc<Vec<InjectedFault>>,
}

impl FaultSet {
    pub fn new(faults: &[(usize, FaultKind)]) -> Self {
        Self {
            inner: Arc::new(
                faults
                    .iter()
                    .map(|&(t, k)| InjectedFault::new(t, k))
                    .collect(),
            ),
        }
    }

    pub fn single(task: usize, kind: FaultKind) -> Self {
        Self::new(&[(task, kind)])
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// The fault (if any) landing on task `id` of an `n`-task graph.
    fn at(&self, id: usize, n: usize) -> Option<&InjectedFault> {
        self.inner.iter().find(|f| f.task % n == id)
    }
}

/// The fault-injecting counterpart of [`kernel_runner`]: identical
/// dispatch, plus the [`FaultSet`]'s misbehaviour at its pinned
/// coordinates. Used by [`super::session::JobBuilder::inject`].
pub fn faulty_kernel_runner<'a>(
    graph: &'a TaskGraph,
    kernels: &'a [BlockKernel<'a>],
    shared: &'a SharedBlocked,
    bs: usize,
    faults: FaultSet,
) -> impl Fn(TaskId) + Send + Sync + 'a {
    let base = kernel_runner(graph, kernels, shared, bs);
    let n = graph.len().max(1);
    move |id: TaskId| match faults.at(id.0, n) {
        None => base(id),
        Some(f) => match f.kind {
            FaultKind::Panic => {
                panic!("injected fault: kernel panic at task {}", id.0)
            }
            FaultKind::TransientPanic { .. } => {
                let armed = f
                    .remaining
                    .fetch_update(
                        Ordering::AcqRel,
                        Ordering::Acquire,
                        |v| v.checked_sub(1),
                    )
                    .is_ok();
                if armed {
                    panic!(
                        "injected fault: transient kernel panic at \
                         task {}",
                        id.0
                    );
                }
                base(id)
            }
            FaultKind::Delay { spin } => {
                for _ in 0..spin {
                    std::hint::spin_loop();
                }
                base(id)
            }
            FaultKind::Corrupt { elem } => {
                base(id);
                let t = *graph.task(id);
                // SAFETY: same exclusivity argument as
                // `kernel_runner` — the graph chains every touch of
                // the written block, and this task still owns it.
                let m = unsafe { shared.get_mut() };
                let w = m
                    .block_mut(t.write.0, t.write.1)
                    .expect("corrupt targets the task's own write block");
                let e = elem % w.len();
                w[e] += 1.0;
            }
        },
    }
}

// --- recovery policy -----------------------------------------------------

/// Sleep schedule between retry attempts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetryBackoff {
    /// Resubmit immediately.
    None,
    /// A fixed pause before every retry.
    Fixed { millis: u64 },
    /// `base_millis · 2^(k)` before the `k`-th retry (capped).
    Exponential { base_millis: u64 },
}

/// How the [`super::session::Session`] retries a poisoned job:
/// resubmit the cached graph over a fresh copy of the retained
/// pristine input, up to `max_attempts` total attempts, sleeping per
/// `backoff` between them. Cancelled jobs are never retried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    pub backoff: RetryBackoff,
}

impl RetryPolicy {
    /// Retry up to `max_attempts` total attempts, no backoff.
    pub fn attempts(max_attempts: usize) -> Self {
        Self { max_attempts: max_attempts.max(1), backoff: RetryBackoff::None }
    }

    pub fn with_backoff(mut self, backoff: RetryBackoff) -> Self {
        self.backoff = backoff;
        self
    }

    /// The pause before (2-based) attempt number `attempt`, if any.
    pub fn delay_before(&self, attempt: usize) -> Option<Duration> {
        match self.backoff {
            RetryBackoff::None => None,
            RetryBackoff::Fixed { millis } => {
                Some(Duration::from_millis(millis))
            }
            RetryBackoff::Exponential { base_millis } => {
                let shift = attempt.saturating_sub(2).min(16) as u32;
                Some(Duration::from_millis(
                    base_millis.saturating_mul(1u64 << shift),
                ))
            }
        }
    }
}

// --- the fault-scenario registry -----------------------------------------

/// A registry entry whose input pre-allocates every block its graph
/// touches (no fill-in): the only workloads a deadline or
/// cancellation may legally truncate (see module docs).
fn pick_dense(rng: &mut SplitMix64) -> &'static dyn Workload {
    let d: Vec<&'static dyn Workload> = registry()
        .iter()
        .copied()
        .filter(|w| w.name() != "sparselu")
        .collect();
    d[rng.range(0, d.len())]
}

fn plan_transient_storm(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let jobs: Vec<JobPlan> = (0..8)
        .map(|i| {
            let w = scenario::pick(rng);
            let mut j = scenario::job(rng, w, rng.range(4, 7), bs);
            j.fault_task = rng.next_below(1 << 16) as usize;
            match i % 4 {
                0 => {
                    let fails = rng.range(1, 3) as u32;
                    j.fault = Some(FaultKind::TransientPanic { fails });
                    j.retry = Some(RetryPolicy::attempts(4));
                }
                1 => {
                    j.fault = Some(FaultKind::Panic);
                    j.retry = Some(
                        RetryPolicy::attempts(2).with_backoff(
                            RetryBackoff::Fixed { millis: 1 },
                        ),
                    );
                }
                2 => {
                    j.fault = Some(FaultKind::Corrupt {
                        elem: rng.next_below(64) as usize,
                    });
                }
                _ => {
                    j.fault = Some(FaultKind::Delay { spin: 1 << 12 });
                }
            }
            j
        })
        .collect();
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_deadline_churn(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let jobs: Vec<JobPlan> = (0..9)
        .map(|i| match i % 3 {
            0 => {
                let w = pick_dense(rng);
                let mut j = scenario::job(rng, w, rng.range(4, 7), bs);
                // Far below any registry graph size at nb >= 4, so the
                // deadline always fires.
                j.deadline = Some(rng.range(1, 4));
                j
            }
            1 => {
                let w = pick_dense(rng);
                let mut j = scenario::job(rng, w, rng.range(4, 7), bs);
                // Effectively infinite: the job completes in full.
                j.deadline = Some(1 << 20);
                j
            }
            _ => {
                let w = scenario::pick(rng);
                scenario::job(rng, w, rng.range(4, 7), bs)
            }
        })
        .collect();
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::HalfStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_shed_at_capacity(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    // The head is big enough to run for milliseconds while the tail
    // submits in microseconds; its dependents are pinned pending
    // behind it, so the shed bound trips deterministically — the same
    // pressure construction capacity-churn uses.
    let head = scenario::pick_factorisation(rng);
    let mut jobs = vec![scenario::job(rng, head, 10, bs)];
    for _ in 0..6 {
        let w = scenario::pick(rng);
        let mut j = scenario::job(rng, w, rng.range(3, 6), bs);
        j.deps = vec![0];
        jobs.push(j);
    }
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: Some(rng.range(2, 4)),
        drain_after: None,
        jobs,
    }
}

fn plan_cancel_mid_stream(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let head = scenario::pick_factorisation(rng);
    let mut jobs = vec![scenario::job(rng, head, 9, bs)];
    for i in 0..6 {
        let w = pick_dense(rng);
        let mut j = scenario::job(rng, w, rng.range(3, 6), bs);
        j.deps = vec![0];
        j.cancel = i % 2 == 0;
        jobs.push(j);
    }
    for _ in 0..2 {
        let w = scenario::pick(rng);
        jobs.push(scenario::job(rng, w, rng.range(3, 6), bs));
    }
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: Some(7),
        jobs,
    }
}

/// The fault scenarios, in documentation order — a second registry on
/// the same [`Scenario`] machinery, kept separate from
/// [`scenario::ALL_SCENARIOS`] because its plans exercise controls
/// (shedding, drain, cancellation) the generic host/sim agreement
/// harness deliberately does not model.
pub static FAULT_SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "transient-storm-with-retry",
        reason: "a storm of transient, persistent, corrupting and \
                 straggling kernels in one stream: retries must heal \
                 exactly the transient jobs bit-identically, exhaust \
                 into typed attempt histories on the persistent ones, \
                 and the verifier must catch every silent corruption",
        invariants: &[
            "retry-bit-identity",
            "retry-exhaustion",
            "corruption-detected",
            "no-starvation",
        ],
        plan_fn: plan_transient_storm,
    },
    Scenario {
        name: "deadline-misses-under-churn",
        reason: "deadlines expressed in completed-task counts must \
                 fire after exactly their budget and drain to a typed \
                 cancellation without poisoning the pool, even while \
                 the admission budget churns",
        invariants: &[
            "deadline-cancellation",
            "no-retry-of-cancelled",
            "bit-identity",
            "no-starvation",
        ],
        plan_fn: plan_deadline_churn,
    },
    Scenario {
        name: "shed-at-capacity",
        reason: "a bounded pending queue must reject overflow with a \
                 typed error at submission time and never drop a job \
                 it already accepted",
        invariants: &[
            "shed-never-drops-admitted",
            "bit-identity",
            "no-starvation",
        ],
        plan_fn: plan_shed_at_capacity,
    },
    Scenario {
        name: "cancel-mid-stream",
        reason: "cancelling queued jobs and draining the pool \
                 mid-stream must complete everything already admitted, \
                 reject everything after the drain, and never retry a \
                 cancelled job",
        invariants: &[
            "no-retry-of-cancelled",
            "drain-completes-all-admitted",
            "bit-identity",
        ],
        plan_fn: plan_cancel_mid_stream,
    },
];

/// Look a fault scenario up by name.
pub fn find(name: &str) -> Option<&'static Scenario> {
    FAULT_SCENARIOS.iter().find(|s| s.name == name)
}

/// All fault-scenario names, in registry order (CLI error messages).
pub fn names() -> Vec<&'static str> {
    FAULT_SCENARIOS.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::workload::{find as find_workload, Params};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn registry_shape_holds() {
        assert_eq!(FAULT_SCENARIOS.len(), 4);
        for (i, sc) in FAULT_SCENARIOS.iter().enumerate() {
            assert!(!sc.reason.is_empty(), "{}", sc.name);
            assert!(sc.invariants.len() >= 2, "{}", sc.name);
            for later in &FAULT_SCENARIOS[i + 1..] {
                assert_ne!(sc.name, later.name, "duplicate scenario");
            }
            assert_eq!(find(sc.name).unwrap().name, sc.name);
            // The two registries must not shadow each other.
            assert!(scenario::find(sc.name).is_none(), "{}", sc.name);
        }
        assert!(find("no-such-fault").is_none());
        assert_eq!(names().len(), FAULT_SCENARIOS.len());
    }

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        for sc in FAULT_SCENARIOS {
            let (a, b) = (sc.plan(9), sc.plan(9));
            assert_eq!(a.workers, b.workers, "{}", sc.name);
            assert_eq!(a.max_pending, b.max_pending, "{}", sc.name);
            assert_eq!(a.drain_after, b.drain_after, "{}", sc.name);
            assert_eq!(a.jobs.len(), b.jobs.len(), "{}", sc.name);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.workload.name(), y.workload.name());
                assert_eq!((x.nb, x.bs, x.seed), (y.nb, y.bs, y.seed));
                assert_eq!(x.fault, y.fault);
                assert_eq!(x.fault_task, y.fault_task);
                assert_eq!(x.retry, y.retry);
                assert_eq!(x.deadline, y.deadline);
                assert_eq!((x.cancel, x.deps.clone()), (y.cancel, y.deps.clone()));
            }
            let c = sc.plan(10);
            let differs = a.workers != c.workers
                || a.jobs.iter().zip(&c.jobs).any(|(x, y)| {
                    x.nb != y.nb
                        || x.seed != y.seed
                        || x.fault_task != y.fault_task
                        || x.workload.name() != y.workload.name()
                });
            assert!(differs, "{}: seed-insensitive plan", sc.name);
        }
    }

    #[test]
    fn truncatable_jobs_avoid_fill_in_workloads() {
        // A deadline or cancellation skips an arbitrary task suffix;
        // that is only panic-free for workloads without fill-in
        // allocation (see module docs).
        for sc in FAULT_SCENARIOS {
            for seed in [1u64, 7, 23] {
                for j in sc.plan(seed).jobs {
                    let truncatable = j.cancel
                        || j.deadline.map_or(false, |d| d < (1 << 20));
                    if truncatable {
                        assert_ne!(
                            j.workload.name(),
                            "sparselu",
                            "{}: truncatable sparselu job",
                            sc.name
                        );
                    }
                }
            }
        }
    }

    /// Run a graph's tasks in program order (a valid topological
    /// order by construction) through a runner.
    fn run_seq(graph: &TaskGraph, run: impl Fn(TaskId)) {
        for t in 0..graph.len() {
            run(TaskId(t));
        }
    }

    #[test]
    fn corrupt_is_caught_by_bit_identity_and_delay_is_not() {
        let w = find_workload("cholesky").unwrap();
        let p = Params::new(4, 4);
        let graph = w.graph(&p);
        let mut want = w.make_input(&p, 0);
        w.reference_seq(&mut want);

        for (kind, clean) in [
            (FaultKind::Corrupt { elem: 5 }, false),
            (FaultKind::Delay { spin: 64 }, true),
        ] {
            let shared = SharedBlocked::new(w.make_input(&p, 0));
            let run = faulty_kernel_runner(
                &graph,
                w.kernels(),
                &shared,
                p.bs,
                FaultSet::single(7, kind),
            );
            run_seq(&graph, run);
            let got = shared.into_inner();
            assert_eq!(
                w.verify_bits(&got, &want).is_ok(),
                clean,
                "{kind:?}"
            );
        }
    }

    #[test]
    fn transient_counter_heals_across_rebuilds() {
        let w = find_workload("cholesky").unwrap();
        let p = Params::new(3, 4);
        let graph = w.graph(&p);
        let faults =
            FaultSet::single(0, FaultKind::TransientPanic { fails: 2 });

        // Attempts 1 and 2 panic at the fault task; attempt 3 runs
        // clean and bit-identical — with the runner rebuilt from
        // pristine input each time, exactly like a session retry.
        for attempt in 1..=3 {
            let shared = SharedBlocked::new(w.make_input(&p, 0));
            let run = faulty_kernel_runner(
                &graph,
                w.kernels(),
                &shared,
                p.bs,
                faults.clone(),
            );
            let hit = catch_unwind(AssertUnwindSafe(|| {
                run_seq(&graph, run);
            }));
            if attempt <= 2 {
                assert!(hit.is_err(), "attempt {attempt} must panic");
            } else {
                assert!(hit.is_ok(), "attempt {attempt} must heal");
                let got = shared.into_inner();
                let mut want = w.make_input(&p, 0);
                w.reference_seq(&mut want);
                w.verify_bits(&got, &want).unwrap();
            }
        }
        assert_eq!(faults.inner[0].remaining(), 0);
    }

    #[test]
    fn fault_coordinates_wrap_onto_the_graph() {
        let w = find_workload("matmul").unwrap();
        let p = Params::new(2, 3);
        let graph = w.graph(&p); // 8 tasks
        let n = graph.len();
        let shared = SharedBlocked::new(w.make_input(&p, 0));
        // A coordinate far past the graph lands on task (coord % n).
        let coord = 5 * n + 3;
        let run = faulty_kernel_runner(
            &graph,
            w.kernels(),
            &shared,
            p.bs,
            FaultSet::single(coord, FaultKind::Panic),
        );
        for t in 0..n {
            let r = catch_unwind(AssertUnwindSafe(|| run(TaskId(t))));
            assert_eq!(r.is_err(), t == coord % n, "task {t}");
        }
    }

    #[test]
    fn retry_policy_backoff_schedule() {
        let none = RetryPolicy::attempts(3);
        assert_eq!(none.max_attempts, 3);
        assert_eq!(none.delay_before(2), None);

        let fixed = RetryPolicy::attempts(3)
            .with_backoff(RetryBackoff::Fixed { millis: 7 });
        assert_eq!(fixed.delay_before(2), Some(Duration::from_millis(7)));
        assert_eq!(fixed.delay_before(5), Some(Duration::from_millis(7)));

        let exp = RetryPolicy::attempts(5)
            .with_backoff(RetryBackoff::Exponential { base_millis: 3 });
        assert_eq!(exp.delay_before(2), Some(Duration::from_millis(3)));
        assert_eq!(exp.delay_before(3), Some(Duration::from_millis(6)));
        assert_eq!(exp.delay_before(4), Some(Duration::from_millis(12)));

        // Zero clamps to one attempt: "no retry", not "no run".
        assert_eq!(RetryPolicy::attempts(0).max_attempts, 1);
    }
}
