//! A hand-rolled Chase–Lev work-stealing deque over task indices.
//!
//! One deque per worker: the **owner** pushes and pops at the bottom
//! (LIFO — the task it just released is the cache-hot one), **thieves**
//! steal from the top (FIFO — the oldest, coldest task, which is also
//! the one closest to the critical path in a depth-first schedule).
//! This is the owner-LIFO/stealer-FIFO policy that preserves the
//! depth-first locality of the PR-1 mutex scoreboard without any lock.
//!
//! The implementation follows the C11 formulation of Chase–Lev
//! (Lê, Pop, Cohen & Zappa Nardelli, *Correct and Efficient
//! Work-Stealing for Weak Memory Models*, PPoPP'13) with one
//! simplification: the buffer is sized up front for the whole task
//! graph (`with_capacity(graph.len())`), so the resize path — the only
//! part of Chase–Lev requiring memory reclamation — is statically
//! unreachable. `top` and `bottom` grow monotonically apart by at most
//! the capacity, which the owner `debug_assert`s on every push.
//!
//! Memory-ordering contract (verified against the paper's fences):
//!
//! * `push` publishes the slot with a `Release` fence before the
//!   `bottom` store, so a thief that observes the new `bottom`
//!   (`Acquire`) also observes the slot contents — this is the edge
//!   that hands a task's released block writes to its stealer.
//! * `pop` and `steal` race on the last element through a `SeqCst`
//!   CAS on `top`; the loser observes the CAS failure and retries
//!   elsewhere. The `SeqCst` fences order the owner's `bottom`
//!   decrement against the thief's `top` read exactly as in the paper.

use std::sync::atomic::{fence, AtomicIsize, AtomicUsize, Ordering};

/// What a steal attempt returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Steal {
    /// A task was stolen.
    Taken(usize),
    /// The deque was observed empty.
    Empty,
    /// Lost a race with the owner or another thief; worth retrying.
    Abort,
}

/// Fixed-capacity Chase–Lev deque of `usize` task ids.
pub struct StealDeque {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: Box<[AtomicUsize]>,
    mask: isize,
}

impl StealDeque {
    /// A deque able to hold `min_cap` tasks at once (rounded up to a
    /// power of two). Executors size this to the task-graph length, so
    /// overflow is impossible by construction.
    pub fn with_capacity(min_cap: usize) -> Self {
        let cap = min_cap.max(2).next_power_of_two();
        let buf: Vec<AtomicUsize> =
            (0..cap).map(|_| AtomicUsize::new(0)).collect();
        Self {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: buf.into_boxed_slice(),
            mask: (cap - 1) as isize,
        }
    }

    #[inline]
    fn slot(&self, i: isize) -> &AtomicUsize {
        &self.buf[(i & self.mask) as usize]
    }

    /// Owner-only: push `task` at the bottom (LIFO end).
    ///
    /// Panics if the deque is full — a hard assert even in release:
    /// wrapping onto a live slot would silently lose the overwritten
    /// task (executor hang) or let a thief claim it twice (a data
    /// race on the block it writes). Executors size the deque to the
    /// whole task graph, so the branch never fires for them; the cost
    /// is one cold compare per push. Callers that cannot statically
    /// rule overflow out (the multi-job [`super::pool`]) use
    /// [`Self::try_push`] and divert the task instead.
    pub fn push(&self, task: usize) {
        assert!(
            self.try_push(task).is_ok(),
            "StealDeque over capacity: sized below graph length"
        );
    }

    /// Owner-only: push `task` at the bottom (LIFO end), or hand it
    /// back if the deque is full — the lossless form of [`Self::push`]
    /// (a task is never overwritten or dropped; the caller reroutes
    /// it, e.g. to the pool's injector queue).
    pub fn try_push(&self, task: usize) -> Result<(), usize> {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b - t > self.mask {
            return Err(task);
        }
        self.slot(b).store(task, Ordering::Relaxed);
        // Publish the slot before the new bottom becomes visible.
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
        Ok(())
    }

    /// Owner-only: pop from the bottom (LIFO end).
    pub fn pop(&self) -> Option<usize> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        // Order the bottom decrement against concurrent top reads.
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let task = self.slot(b).load(Ordering::Relaxed);
            if t == b {
                // Last element: race any thief through top.
                let won = self
                    .top
                    .compare_exchange(
                        t,
                        t + 1,
                        Ordering::SeqCst,
                        Ordering::Relaxed,
                    )
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                return won.then_some(task);
            }
            Some(task)
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief: steal from the top (FIFO end). Any thread but the owner.
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let task = self.slot(t).load(Ordering::Relaxed);
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                Steal::Taken(task)
            } else {
                Steal::Abort
            }
        } else {
            Steal::Empty
        }
    }

    /// Approximate occupancy (racy; diagnostics only).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        b <= t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn lifo_for_owner() {
        let d = StealDeque::with_capacity(8);
        d.push(1);
        d.push(2);
        d.push(3);
        assert_eq!(d.len(), 3);
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), Some(1));
        assert_eq!(d.pop(), None);
        assert!(d.is_empty());
    }

    #[test]
    fn fifo_for_thief() {
        let d = StealDeque::with_capacity(8);
        d.push(1);
        d.push(2);
        d.push(3);
        assert_eq!(d.steal(), Steal::Taken(1));
        assert_eq!(d.steal(), Steal::Taken(2));
        // Owner takes the newest, thief took the oldest.
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.steal(), Steal::Empty);
    }

    #[test]
    fn try_push_full_hands_task_back() {
        let d = StealDeque::with_capacity(2);
        assert_eq!(d.try_push(1), Ok(()));
        assert_eq!(d.try_push(2), Ok(()));
        // Capacity 2: the third push must hand the task back losslessly.
        assert_eq!(d.try_push(3), Err(3));
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.try_push(3), Ok(()));
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(1));
    }

    #[test]
    fn capacity_rounds_up_and_wraps() {
        let d = StealDeque::with_capacity(3); // rounds to 4
        for round in 0..10 {
            d.push(round);
            d.push(round + 100);
            assert_eq!(d.pop(), Some(round + 100));
            assert_eq!(d.steal(), Steal::Taken(round));
        }
        assert!(d.is_empty());
    }

    #[test]
    fn concurrent_owner_and_thieves_lose_nothing() {
        // The owner pushes N tasks and pops; 3 thieves steal. Every
        // task must be claimed exactly once.
        const N: usize = 20_000;
        let d = Arc::new(StealDeque::with_capacity(N));
        let claimed: Arc<Vec<AtomicU64>> =
            Arc::new((0..N).map(|_| AtomicU64::new(0)).collect());
        let mut thieves = Vec::new();
        for _ in 0..3 {
            let d = d.clone();
            let claimed = claimed.clone();
            thieves.push(std::thread::spawn(move || loop {
                match d.steal() {
                    Steal::Taken(x) => {
                        claimed[x].fetch_add(1, Ordering::Relaxed);
                    }
                    Steal::Empty => {
                        if claimed[N - 1].load(Ordering::Relaxed) > 0
                            || claimed
                                .iter()
                                .map(|c| c.load(Ordering::Relaxed))
                                .sum::<u64>()
                                == N as u64
                        {
                            // Owner finished pushing and the deque
                            // drained; double-check then exit.
                            if d.is_empty() {
                                return;
                            }
                        }
                        std::hint::spin_loop();
                    }
                    Steal::Abort => std::hint::spin_loop(),
                }
            }));
        }
        // Owner: push all, interleaving pops.
        for i in 0..N {
            d.push(i);
            if i % 3 == 0 {
                if let Some(x) = d.pop() {
                    claimed[x].fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        while let Some(x) = d.pop() {
            claimed[x].fetch_add(1, Ordering::Relaxed);
        }
        for th in thieves {
            th.join().unwrap();
        }
        for (i, c) in claimed.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                1,
                "task {i} claimed {} times",
                c.load(Ordering::Relaxed)
            );
        }
    }
}
