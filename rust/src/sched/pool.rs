//! The persistent multi-job dataflow runtime: **one** long-lived
//! worker pool executing **many** concurrent [`TaskGraph`]s.
//!
//! The one-shot executors ([`super::exec`]) spawn workers, drain a
//! single graph, and join — which means a stream of factorisation
//! requests pays full thread-team latency per request and can never
//! overlap independent jobs. The GPRM companion paper
//! (arXiv:1312.2703) instead keeps a *persistent* machine of
//! communicating threads alive across requests, and the tiled-algorithm
//! line (Buttari et al., arXiv:0709.1272) assumes a long-lived
//! scheduler fed a stream of DAGs. [`Pool`] is that service:
//!
//! * **one worker team for the process lifetime** — spawned once, fed
//!   jobs forever, with the same Chase–Lev deques
//!   ([`super::deque::StealDeque`]) and atomic in-degree countdowns as
//!   the one-shot executor;
//! * **job-tagged tasks** — a deque entry packs `(slot, generation,
//!   task)` into one `usize`, so workers steal across job boundaries
//!   exactly like within a job: an idle worker finishing job A's tail
//!   immediately picks up job B's tasks;
//! * **fair admission** — submissions are admitted FIFO while the
//!   in-flight task total fits the deque capacity; jobs that do not
//!   fit yet queue (never panic, never drop) and are admitted as
//!   running jobs retire. A job larger than the capacity itself is
//!   rejected up front with the typed
//!   [`SubmitError::GraphTooLarge`];
//! * **per-job completion countdowns and poisoning** — a panicking
//!   task poisons *its job only* (siblings of that job skip their
//!   kernels, the countdown still drains, the waiter gets `Err`);
//!   other jobs and the pool itself are untouched;
//! * **graceful shutdown** — admitted jobs drain, queued jobs are
//!   failed with a typed error, workers then exit and join;
//! * **inter-job dependencies** — a submission may name prior
//!   [`JobHandle`]s as predecessors ([`PoolScope::submit_after`], or
//!   [`super::session::Session`]'s fluent `.after(&h)`): the pool
//!   defers *admission* of the job until every named predecessor
//!   completed, so none of its tasks is even published before the
//!   predecessors' results are final. Handles can only name
//!   earlier-submitted jobs, so the dependency relation is acyclic by
//!   construction and the FIFO head's predecessors are never queued
//!   behind it — deferred admission cannot deadlock (handles from a
//!   *different* pool are rejected with a typed error for the same
//!   reason). Dependencies are ordering-only: a dependent still runs
//!   (on whatever state its predecessor left) if the predecessor was
//!   poisoned. An empty graph completes at its admission point, so it
//!   serves as a join/barrier node in dependency chains.
//!
//! # Submission and borrow safety
//!
//! Workers are `'static` threads, but jobs borrow their graph, their
//! matrix and their kernel closures from the caller's stack. The
//! scoped API makes that sound the same way `std::thread::scope`
//! does: [`Pool::scope`] hands out a [`PoolScope`] whose submissions
//! may borrow anything outliving the scope (`'env`), and the scope
//! **blocks at the end until every submitted job completed** — even
//! if the caller never called [`JobHandle::wait`], leaked the handle,
//! or panicked. Internally the erased closure is freed by the
//! completing worker *before* the waiter is released, so no borrow is
//! touched after `scope` returns.
//!
//! # Slot/generation protocol (why the hot path needs no lock)
//!
//! A deque entry's `(slot, generation)` prefix identifies the job in
//! the pool's slot registry. The registry entry (an
//! `Arc<JobInner>`) is cleared only at job completion — and a job
//! cannot complete while any of its tasks sits unexecuted in a deque,
//! because completion *is* the count of executed tasks reaching the
//! graph size. A popped task therefore always resolves to the live
//! job of its generation; each worker caches the `(slot, generation) →
//! Arc` mapping so resolving costs one compare on the hot path and
//! takes the slot mutex only on first contact with a job (the
//! generation tag makes stale cache entries self-evident when a slot
//! is recycled).
//!
//! The per-dependency happens-before contract of the one-shot
//! executor is preserved verbatim: in-degree decrements `Release`, the
//! zero-observer fences `Acquire`, and the deque/injector publish
//! edges carry the predecessor's block writes to whichever worker —
//! of whichever job — claims the successor (see the `SharedBlocked`
//! `Sync` notes in `linalg/blocked.rs`).
//!
//! Schedule auditing (the opt-in event log) stays with the one-shot
//! executors; the pool's hot path records only the per-job
//! `executed`/`peak_ready` stats.

use super::deque::{Steal, StealDeque};
use super::error::{Error, JobFailure};
use super::exec::{Backoff, ExecStats};
use super::graph::{TaskGraph, TaskId};
use super::topo::Topology;
use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{
    fence, AtomicBool, AtomicUsize, Ordering,
};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

// Packed deque entries need slot + generation + task in one usize.
const _: () = assert!(usize::BITS >= 64, "pool task tags need 64-bit usize");

/// Bit layout of a deque entry: `[slot:8][generation:32][task:24]`.
const TASK_BITS: u32 = 24;
const SLOT_SHIFT: u32 = 56;
const TASK_MASK: usize = (1 << TASK_BITS) - 1;
/// Hard ceiling on per-job task count (packing limit; the admission
/// capacity is far below this in practice).
pub const MAX_JOB_TASKS: usize = 1 << TASK_BITS;
/// Hard ceiling on concurrently-admitted jobs (slot bits).
pub const MAX_SLOTS: usize = 1 << (64 - SLOT_SHIFT);

#[inline]
fn pack_base(slot: usize, gen: u32) -> usize {
    (slot << SLOT_SHIFT) | ((gen as usize) << TASK_BITS)
}

/// Fixed seed for the pool's victim-ring rotations: reproducible
/// victim orders, still decorrelated across workers (the seed is
/// mixed with the worker id).
const VICTIM_SEED: u64 = 0x9001_5eed_0f_a5_7e11;

/// Why a submission was not accepted. Typed — capacity pressure never
/// panics and never drops work (jobs that merely do not fit *yet* are
/// queued, not errored).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The graph alone exceeds the pool's task capacity (or the
    /// packing limit), so no amount of draining could ever admit it.
    /// Resize the pool ([`PoolConfig::task_capacity`]) or split the
    /// job.
    GraphTooLarge { tasks: usize, capacity: usize },
    /// [`Pool::shutdown`] already began; the pool accepts no new jobs.
    ShutDown,
    /// The pending queue is at the shed bound
    /// ([`PoolConfig::max_pending`]): the pool rejects the overflow at
    /// submission time instead of queueing unboundedly. Already-
    /// accepted jobs are unaffected.
    Overloaded { pending: usize, limit: usize },
    /// [`Pool::drain`] began: in-flight and queued jobs complete, but
    /// no new job is accepted.
    Draining,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::GraphTooLarge { tasks, capacity } => write!(
                f,
                "graph of {tasks} tasks exceeds the pool task capacity \
                 {capacity}"
            ),
            SubmitError::ShutDown => write!(f, "pool is shut down"),
            SubmitError::Overloaded { pending, limit } => write!(
                f,
                "pool overloaded: {pending} pending jobs at shed \
                 limit {limit}"
            ),
            SubmitError::Draining => write!(
                f,
                "pool is draining and accepting no new jobs"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Pool sizing. The deques are fixed-capacity (the Chase–Lev resize
/// path stays statically unreachable), so capacity is an admission
/// budget: the sum of admitted-but-unfinished graphs' task counts
/// never exceeds `task_capacity`, which is also each worker deque's
/// size — overflow is impossible by admission control, and
/// [`StealDeque::try_push`] diverts to the shared injector as a
/// lossless backstop even so.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Worker threads (≥ 1).
    pub workers: usize,
    /// Max in-flight tasks across all admitted jobs; also each
    /// deque's capacity. Size this from the graphs you will submit
    /// (e.g. `jobs × graph.len()` for full overlap).
    pub task_capacity: usize,
    /// Max concurrently-admitted jobs (slot table size, ≤
    /// [`MAX_SLOTS`]); further jobs queue.
    pub max_jobs: usize,
    /// Overload shed bound: submissions arriving while this many jobs
    /// already queue are rejected with
    /// [`SubmitError::Overloaded`] instead of queueing unboundedly.
    /// `None` (the default) keeps the original queue-everything
    /// behaviour.
    pub max_pending: Option<usize>,
    /// Affinity domains ([`crate::sched::topo::Topology`], clamped to
    /// the worker count): with more than one, workers steal
    /// nearest-domain-first, each admitted job is seeded into its own
    /// preferred domain's injector (round-robin across jobs, so
    /// concurrent jobs stop shredding each other's caches), released
    /// successors follow the domain that last wrote their write-block,
    /// and workers are pinned to cores on Linux. `1` (the default) is
    /// the flat pre-locality pool, bit-for-bit.
    pub domains: usize,
}

impl PoolConfig {
    /// Defaults sized for the evaluation workloads: 32 Ki in-flight
    /// tasks, 64 concurrent jobs, no shed bound, one (flat) domain.
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            task_capacity: 1 << 15,
            max_jobs: 64,
            max_pending: None,
            domains: 1,
        }
    }

    /// Bound the pending queue: reject submissions beyond
    /// `max_pending` queued jobs with [`SubmitError::Overloaded`].
    pub fn shed(mut self, max_pending: usize) -> Self {
        self.max_pending = Some(max_pending);
        self
    }

    /// Split the team into `domains` affinity domains (clamped to the
    /// worker count at spawn).
    pub fn with_domains(mut self, domains: usize) -> Self {
        self.domains = domains;
        self
    }
}

/// The erased per-job work: the graph borrow and the kernel-dispatch
/// closure. Freed by the completing worker (or the shutdown path for
/// never-admitted jobs) *before* the job's waiter is released, so the
/// `'env` borrows inside never outlive their scope.
struct JobWork {
    /// Borrow of the submitted graph; valid until completion (the
    /// scope blocks). Raw so a lingering worker cache entry after
    /// completion holds no dangling reference.
    graph: *const TaskGraph,
    run: Box<dyn Fn(TaskId) + Send + Sync>,
}

/// One submitted job's shared state. `pub(crate)` so the fluent
/// [`super::session::Session`] front end can hold and wait on jobs;
/// every field stays private to this module.
pub(crate) struct JobInner {
    /// `(slot, generation)` prefix of this job's packed task ids; set
    /// at admission (under the admission lock, before any root is
    /// published).
    packed_base: AtomicUsize,
    n_tasks: usize,
    /// `Some` until completion; see [`JobWork`].
    work: UnsafeCell<Option<JobWork>>,
    /// Per-task countdown to readiness (same Release/Acquire contract
    /// as the one-shot executor).
    indegree: Box<[AtomicUsize]>,
    /// Unexecuted-task count; the worker that brings it to zero
    /// completes the job.
    remaining: AtomicUsize,
    /// Set by the first panicking task; later tasks of this job skip
    /// their kernels but still drain the countdown.
    poisoned: AtomicBool,
    /// Where the job died: the first panicking task's op name, task
    /// index and captured message (surfaced through
    /// [`super::error::JobFailure`]).
    poison: Mutex<Option<PoisonInfo>>,
    /// Cooperative cancellation flag, checked at every task boundary:
    /// once set, remaining tasks skip their kernels (the countdown
    /// still drains) and the waiter gets [`Error::Cancelled`]. Shared
    /// with [`CancelToken`]s and, on retry resubmission, with the
    /// original attempt — cancelling a job cancels every attempt.
    cancel: Arc<AtomicBool>,
    /// Deadline in completed-task counts (wall-clock-free): the job
    /// self-cancels once this many of its kernels have started, so
    /// exactly `min(deadline, n_tasks)` kernels execute.
    deadline: Option<usize>,
    /// Deadline tickets drawn (each task draws one before running).
    started: AtomicUsize,
    /// Kernels that actually ran to completion (the `ran` count in
    /// [`Error::Cancelled`]).
    ran: AtomicUsize,
    /// Identity of the owning pool (address of its `PoolShared`):
    /// dependency handles are validated against it at submission, so
    /// a foreign pool's handle is a typed error instead of a stalled
    /// admission.
    pool_id: usize,
    /// Jobs that must complete before this one is admitted
    /// (inter-job dependencies; ordering-only). Fixed at submission.
    deps: Vec<Arc<JobInner>>,
    /// Completion cell: `Some(result)` once finished; `cv` signals.
    done: Mutex<Option<Result<ExecStats, Error>>>,
    cv: Condvar,
    /// Ready-set stats (relaxed, approximate — like the one-shot
    /// stealing executor's).
    ready_len: AtomicUsize,
    peak_ready: AtomicUsize,
    /// Position of this job's admission in the pool-wide event order
    /// ([`SEQ_UNSET`] until admitted). Admission and completion draw
    /// stamps from ONE counter, so "predecessor completed before
    /// dependent was admitted" is a comparison, not a race — the
    /// observability hooks behind [`JobHandle::admission_index`] and
    /// the scenario engine's FIFO/dependency invariants.
    admission_seq: AtomicUsize,
    /// Position of this job's completion in the same event order
    /// ([`SEQ_UNSET`] until finished).
    completion_seq: AtomicUsize,
    /// Preferred affinity domain, assigned round-robin at admission
    /// (cross-job domain partitioning). Always 0 on a flat pool.
    domain: AtomicUsize,
    /// Last-writer table keyed by block id (`row * nb + col`): the
    /// host analogue of the simulator's locality directory. Value 0 =
    /// "no domain wrote this block yet", else `domain + 1`. Relaxed
    /// everywhere — it is a placement *hint*, never a correctness
    /// input (a stale read merely routes a task less locally). Empty
    /// on a flat pool, so the hot path costs nothing there.
    block_home: Box<[AtomicUsize]>,
}

/// Sentinel for "event has not happened yet" in the admission/
/// completion stamps.
const SEQ_UNSET: usize = usize::MAX;

/// The first panicking task's coordinates + message (see
/// [`JobInner::poison`]).
struct PoisonInfo {
    op: &'static str,
    task: usize,
    msg: String,
}

/// Per-job execution controls a front end may attach at submission:
/// a completed-task-count deadline and/or a pre-shared cancellation
/// flag (how a retry resubmission keeps honouring the original
/// attempt's [`CancelToken`]).
#[derive(Default)]
pub(crate) struct JobCtl {
    pub(crate) deadline: Option<usize>,
    pub(crate) cancel: Option<Arc<AtomicBool>>,
}

// SAFETY: `work` holds a raw graph pointer and an erased closure whose
// borrows are kept alive by the scope contract (PoolScope blocks until
// completion). The cell itself is accessed (a) read-only by workers
// while the job has unexecuted tasks, (b) exactly once mutably by the
// single thread that observes `remaining` reach zero — ordered after
// every reader by the AcqRel countdown — and (c) mutably on the
// never-admitted shutdown path, where no worker ever saw the job.
unsafe impl Send for JobInner {}
unsafe impl Sync for JobInner {}

impl JobInner {
    /// SAFETY: caller must hold a popped-but-uncounted task of this
    /// job, or otherwise know the job is not complete.
    unsafe fn work_ref(&self) -> &JobWork {
        (*self.work.get()).as_ref().expect("job work already freed")
    }

    fn finish(&self, result: Result<ExecStats, Error>) {
        let mut done = self.done.lock().unwrap();
        debug_assert!(done.is_none(), "job finished twice");
        *done = Some(result);
        self.cv.notify_all();
    }

    pub(crate) fn wait_done(&self) -> Result<ExecStats, Error> {
        let mut done = self.done.lock().unwrap();
        loop {
            if let Some(r) = done.as_ref() {
                return r.clone();
            }
            done = self.cv.wait(done).unwrap();
        }
    }

    /// Every named predecessor has completed (ordering-only: a failed
    /// predecessor still counts as done). Called under the admission
    /// lock; the `adm → done` lock order is the one `complete` uses.
    fn deps_done(&self) -> bool {
        self.deps
            .iter()
            .all(|d| d.done.lock().unwrap().is_some())
    }

    /// The shared cancellation flag — what a retry resubmission passes
    /// back through [`JobCtl`] so every attempt honours the original
    /// [`CancelToken`].
    pub(crate) fn cancel_flag(&self) -> Arc<AtomicBool> {
        self.cancel.clone()
    }
}

/// FIFO admission state.
struct Admission {
    /// Submitted jobs not yet admitted, in submission order.
    pending: VecDeque<Arc<JobInner>>,
    free_slots: Vec<usize>,
    /// Next generation per slot (bumped on every registration).
    next_gen: Vec<u32>,
    /// Sum of admitted-but-unfinished graphs' task counts.
    inflight: usize,
    /// High-water mark of jobs still pending *after* an admission
    /// pass — i.e. jobs that genuinely queued behind capacity or
    /// dependencies, not ones merely in transit through the queue.
    peak_pending: usize,
    shutting_down: bool,
    /// [`Pool::drain`] began: stop accepting submissions but let
    /// everything already accepted (queued or admitted) complete.
    draining: bool,
}

/// One slot of the job registry: the live job, if any.
type SlotEntry = Mutex<Option<Arc<JobInner>>>;

struct PoolShared {
    deques: Box<[StealDeque]>,
    /// Slot registry: the live job per slot (taken by workers on
    /// cache miss; cleared at completion).
    slots: Box<[SlotEntry]>,
    /// Root-seeding queues, one per affinity domain (a flat pool has
    /// exactly one): deques are owner-push-only, so admission
    /// publishes a job's roots into its preferred domain's queue;
    /// workers drain them — own domain first, then outward by domain
    /// distance — between their own pops and stealing. Also the
    /// lossless overflow backstop for `try_push`, and the
    /// cross-domain hand-off lane for home-domain task seeding.
    injectors: Box<[Mutex<VecDeque<usize>>]>,
    /// Fast emptiness check (total across all domains) so idle scans
    /// skip the injector locks.
    injector_len: AtomicUsize,
    /// Affinity-domain layout of the team.
    topo: Topology,
    /// Per-worker steal-victim orders (own domain first, then by
    /// domain distance, seeded rotation within each ring).
    victims: Box<[Box<[usize]>]>,
    /// Per-worker injector drain order: domains sorted by distance
    /// from the worker's own (own domain first).
    inj_order: Box<[Box<[usize]>]>,
    /// Round-robin cursor assigning each admitted job its preferred
    /// domain.
    next_domain: AtomicUsize,
    adm: Mutex<Admission>,
    shutdown: AtomicBool,
    /// Admitted-but-unfinished job count; zero means workers may
    /// deep-park (and, with `shutdown`, exit).
    active_jobs: AtomicUsize,
    /// Worker thread handles for deep-idle unparking.
    threads: Mutex<Vec<std::thread::Thread>>,
    task_capacity: usize,
    /// Overload shed bound (see [`PoolConfig::max_pending`]).
    max_pending: Option<usize>,
    /// Pool-wide event clock: admissions and completions each take
    /// one tick, so their stamps are mutually ordered (see
    /// [`JobInner::admission_seq`]).
    event_seq: AtomicUsize,
}

impl PoolShared {
    fn push_injector(&self, packed: usize, domain: usize) {
        let mut inj = self.injectors[domain].lock().unwrap();
        inj.push_back(packed);
        // Inside the lock, so the counter never under-reports a
        // published entry to a popper that takes the same lock.
        self.injector_len.fetch_add(1, Ordering::Release);
    }

    /// Drain one injector entry, scanning domains nearest-first from
    /// worker `w`'s own.
    fn pop_injector(&self, w: usize) -> Option<usize> {
        if self.injector_len.load(Ordering::Acquire) == 0 {
            return None;
        }
        for &d in &self.inj_order[w] {
            let mut inj = self.injectors[d].lock().unwrap();
            if let Some(p) = inj.pop_front() {
                self.injector_len.fetch_sub(1, Ordering::Release);
                return Some(p);
            }
        }
        None
    }

    /// One round of stealing: probe every other deque once in worker
    /// `w`'s precomputed victim order — own affinity domain first,
    /// then outward by domain distance (a flat pool degenerates to
    /// the classic rotated ring).
    fn try_steal(&self, w: usize) -> Option<usize> {
        for &v in &self.victims[w] {
            match self.deques[v].steal() {
                Steal::Taken(t) => return Some(t),
                Steal::Empty | Steal::Abort => {}
            }
        }
        None
    }

    fn wake_all(&self) {
        for th in self.threads.lock().unwrap().iter() {
            th.unpark();
        }
    }

    /// Admit pending jobs FIFO while a slot is free, the in-flight
    /// task budget holds, and the head's inter-job dependencies have
    /// completed; seed their roots through the injector. Head-of-line
    /// blocking is deliberate: admission order equals submission
    /// order (fairness over packing). Dependency stalls resolve
    /// because a predecessor's `complete` re-runs this admission pass
    /// after marking itself done, and predecessors are always
    /// submitted (hence queued) ahead of their dependents.
    fn try_admit(&self) {
        let mut admitted_any = false;
        let mut adm = self.adm.lock().unwrap();
        loop {
            let Some(head) = adm.pending.front() else { break };
            if !head.deps_done() {
                break;
            }
            let n = head.n_tasks;
            if n == 0 {
                // Empty graph: completes at its admission point (no
                // slot, no budget, no worker) — a join/barrier node
                // whose dependents may now follow.
                let job = adm.pending.pop_front().unwrap();
                // SAFETY: never admitted, so no worker ever saw it.
                unsafe {
                    *job.work.get() = None;
                }
                // An empty job's admission IS its completion: stamp
                // both events (in that order) before any waiter or
                // dependent can observe it done.
                let a = self.event_seq.fetch_add(1, Ordering::SeqCst);
                job.admission_seq.store(a, Ordering::Release);
                let c = self.event_seq.fetch_add(1, Ordering::SeqCst);
                job.completion_seq.store(c, Ordering::Release);
                job.finish(Ok(ExecStats::default()));
                continue;
            }
            if adm.free_slots.is_empty()
                || adm.inflight + n > self.task_capacity
            {
                break;
            }
            let job = adm.pending.pop_front().unwrap();
            let slot = adm.free_slots.pop().unwrap();
            let gen = adm.next_gen[slot];
            adm.next_gen[slot] = gen.wrapping_add(1);
            adm.inflight += n;
            let base = pack_base(slot, gen);
            job.packed_base.store(base, Ordering::Release);
            let a = self.event_seq.fetch_add(1, Ordering::SeqCst);
            job.admission_seq.store(a, Ordering::Release);
            *self.slots[slot].lock().unwrap() = Some(job.clone());
            self.active_jobs.fetch_add(1, Ordering::SeqCst);
            // Cross-job domain partitioning: each admitted job gets
            // the next preferred domain round-robin, and its roots go
            // into that domain's injector — so concurrent jobs start
            // (and, via home-domain seeding, largely stay) on
            // disjoint worker subsets. A flat pool has one domain and
            // this degenerates to the old single injector.
            let dom = self.next_domain.fetch_add(1, Ordering::Relaxed)
                % self.topo.domains();
            job.domain.store(dom, Ordering::Relaxed);
            // SAFETY: the job just got admitted — not complete.
            let graph = unsafe { &*job.work_ref().graph };
            let roots = graph.roots();
            job.ready_len.store(roots.len(), Ordering::Relaxed);
            job.peak_ready.store(roots.len(), Ordering::Relaxed);
            {
                let mut inj = self.injectors[dom].lock().unwrap();
                for &t in roots {
                    inj.push_back(base | t);
                }
                self.injector_len
                    .fetch_add(roots.len(), Ordering::Release);
            }
            admitted_any = true;
        }
        // Whatever is still queued after this pass truly waited (on
        // capacity or a dependency) rather than passing through.
        let depth = adm.pending.len();
        if depth > adm.peak_pending {
            adm.peak_pending = depth;
        }
        drop(adm);
        if admitted_any {
            self.wake_all();
        }
    }

    /// Called by the worker whose decrement drained the job: free the
    /// borrowed work (before the waiter can return and end the
    /// scope!), clear the slot, release the admission budget, signal
    /// the waiter, then admit whatever now fits.
    fn complete(&self, job: &JobInner) {
        let base = job.packed_base.load(Ordering::Relaxed);
        let slot = base >> SLOT_SHIFT;
        // SAFETY: remaining reached zero — every task executed, and
        // each execution happens-before the final AcqRel decrement, so
        // no other thread touches the cell again.
        unsafe {
            *job.work.get() = None;
        }
        *self.slots[slot].lock().unwrap() = None;
        {
            let mut adm = self.adm.lock().unwrap();
            adm.free_slots.push(slot);
            adm.inflight -= job.n_tasks;
        }
        self.active_jobs.fetch_sub(1, Ordering::SeqCst);
        // Poison outranks cancellation (a real failure must never be
        // reported as a clean cancel); cancellation outranks success.
        let poison = job.poison.lock().unwrap().take();
        let result = match poison {
            Some(p) => Err(Error::Job(JobFailure::single(
                p.op, p.task, p.msg,
            ))),
            None if job.cancel.load(Ordering::Acquire) => {
                Err(Error::Cancelled {
                    ran: job.ran.load(Ordering::Acquire),
                })
            }
            None => Ok(ExecStats {
                executed: job.n_tasks,
                events: Vec::new(),
                peak_ready: job.peak_ready.load(Ordering::Relaxed),
            }),
        };
        // Completion stamp strictly precedes `finish` — so once a
        // dependent admits (it must first observe `done`), its
        // admission stamp is strictly greater than this one.
        let c = self.event_seq.fetch_add(1, Ordering::SeqCst);
        job.completion_seq.store(c, Ordering::Release);
        job.finish(result);
        self.try_admit();
    }
}

/// Per-worker `(slot, generation) → job` cache (hot-path lock
/// avoidance; see module docs).
type JobCache = [Option<(usize, Arc<JobInner>)>];

fn panic_message(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    }
}

fn run_one(
    shared: &PoolShared,
    me: &StealDeque,
    my_domain: usize,
    cache: &mut JobCache,
    packed: usize,
) {
    let slot = packed >> SLOT_SHIFT;
    let base = packed & !TASK_MASK;
    let task = packed & TASK_MASK;
    let hit = matches!(&cache[slot], Some((b, _)) if *b == base);
    if !hit {
        let arc = shared.slots[slot]
            .lock()
            .unwrap()
            .clone()
            .expect("task popped for an unregistered job");
        debug_assert_eq!(arc.packed_base.load(Ordering::Relaxed), base);
        cache[slot] = Some((base, arc));
    }
    let job: &JobInner = &cache[slot].as_ref().unwrap().1;
    // SAFETY: this task is popped but not yet counted, so the job
    // cannot complete concurrently and the work cell is live.
    let work = unsafe { job.work_ref() };
    let graph = unsafe { &*work.graph };
    job.ready_len.fetch_sub(1, Ordering::Relaxed);
    if !job.poisoned.load(Ordering::Relaxed)
        && !job.cancel.load(Ordering::Acquire)
    {
        // Deadline tickets: each task draws one before running; the
        // drawer of ticket `deadline` flips the shared cancel flag
        // instead of running. Tickets 0..deadline were all granted
        // before the flag could be set, so exactly
        // `min(deadline, n_tasks)` kernels execute — deterministic,
        // schedule-independent.
        let granted = match job.deadline {
            Some(d) => {
                let n = job.started.fetch_add(1, Ordering::Relaxed);
                if n >= d {
                    job.cancel.store(true, Ordering::Release);
                    false
                } else {
                    true
                }
            }
            None => true,
        };
        if granted {
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    (work.run)(TaskId(task))
                }),
            );
            match r {
                Ok(()) => {
                    job.ran.fetch_add(1, Ordering::Release);
                }
                Err(e) => {
                    // Poison the *job*, never the pool: siblings of
                    // this job skip their kernels, the countdown still
                    // drains (so the slot recycles and the waiter
                    // unblocks), and every other job is untouched. The
                    // first failure's coordinates are the poison
                    // record.
                    let msg = panic_message(e);
                    let mut m = job.poison.lock().unwrap();
                    if m.is_none() {
                        let op =
                            graph.ops()[graph.task(TaskId(task)).op.0]
                                .name;
                        *m = Some(PoisonInfo { op, task, msg });
                    }
                    drop(m);
                    job.poisoned.store(true, Ordering::Release);
                }
            }
        }
    }
    // Home-domain task seeding (locality pools only): record that
    // this domain wrote the task's write-block, so a successor whose
    // write-block lives in another domain is handed to that domain's
    // injector instead of our deque. Pure hint — Relaxed, and never
    // consulted on a flat pool (`block_home` is empty there).
    if !job.block_home.is_empty() {
        let (wi, wj) = graph.task(TaskId(task)).write;
        job.block_home[wi * graph.nb() + wj]
            .store(my_domain + 1, Ordering::Relaxed);
    }
    let mut batch_peak = 0usize;
    for &s in graph.succs(TaskId(task)) {
        // Release: our block writes become visible to whichever worker
        // observes this counter reach zero (same contract as the
        // one-shot executor).
        if job.indegree[s].fetch_sub(1, Ordering::Release) == 1 {
            fence(Ordering::Acquire);
            let len = job.ready_len.fetch_add(1, Ordering::Relaxed) + 1;
            batch_peak = batch_peak.max(len);
            let p = base | s;
            let home = if job.block_home.is_empty() {
                my_domain
            } else {
                let (si, sj) = graph.task(TaskId(s)).write;
                match job.block_home[si * graph.nb() + sj]
                    .load(Ordering::Relaxed)
                {
                    0 => my_domain,
                    d => d - 1,
                }
            };
            if home != my_domain {
                // Cross-domain release: seed the task toward the
                // domain that last wrote its write-block.
                shared.push_injector(p, home);
                continue;
            }
            // Admission bounds in-flight tasks to the deque capacity,
            // so the overflow arm is unreachable in practice; it stays
            // lossless regardless (never panic, never drop).
            if me.try_push(p).is_err() {
                shared.push_injector(p, my_domain);
            }
        }
    }
    if batch_peak > 0 {
        job.peak_ready.fetch_max(batch_peak, Ordering::Relaxed);
    }
    if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        shared.complete(job);
    }
}

fn worker_loop(shared: Arc<PoolShared>, w: usize) {
    let me = &shared.deques[w];
    let my_domain = shared.topo.domain_of(w);
    if shared.topo.domains() > 1 {
        // Locality pools pin workers so the affinity domains describe
        // actual cores (non-fatal, no-op off Linux — same FFI the
        // coordinator uses).
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        crate::coordinator::pool::pin_to_core(
            shared.topo.core_of(w, cores),
        );
    }
    let mut cache: Vec<Option<(usize, Arc<JobInner>)>> =
        (0..shared.slots.len()).map(|_| None).collect();
    let mut backoff = Backoff::new();
    loop {
        let task = me
            .pop()
            .or_else(|| shared.pop_injector(w))
            .or_else(|| shared.try_steal(w));
        match task {
            Some(p) => {
                backoff.reset();
                run_one(&shared, me, my_domain, &mut cache, p);
            }
            None => {
                if shared.active_jobs.load(Ordering::SeqCst) == 0 {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    // Deep idle: no admitted job anywhere, so work can
                    // only arrive through an admission, and admissions
                    // unpark every worker after publishing the job —
                    // the park token makes this check-then-park
                    // lossless. A persistent pool must not burn CPU
                    // between job bursts, so this park is unbounded.
                    // It is also the moment to drop the cached job
                    // Arcs: with no job active every entry is stale,
                    // and a process-lifetime pool must not pin
                    // completed jobs' countdown state while parked
                    // (during a stream, staleness is bounded to one
                    // completed job per slot until this lull).
                    for c in cache.iter_mut() {
                        *c = None;
                    }
                    std::thread::park();
                    backoff.reset();
                } else {
                    backoff.idle();
                }
            }
        }
    }
}

/// The persistent worker pool. See module docs.
pub struct Pool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with default capacities
    /// ([`PoolConfig::new`]).
    pub fn new(workers: usize) -> Self {
        Self::with_config(PoolConfig::new(workers))
    }

    /// Spawn a pool with explicit sizing.
    pub fn with_config(cfg: PoolConfig) -> Self {
        assert!(cfg.workers >= 1, "pool needs at least one worker");
        let max_jobs = cfg.max_jobs.clamp(1, MAX_SLOTS);
        let cap = cfg.task_capacity.clamp(1, MAX_JOB_TASKS - 1);
        let topo = Topology::new(cfg.workers, cfg.domains);
        let victims: Box<[Box<[usize]>]> = (0..cfg.workers)
            .map(|w| topo.victim_order(w, VICTIM_SEED).into_boxed_slice())
            .collect();
        let inj_order: Box<[Box<[usize]>]> = (0..cfg.workers)
            .map(|w| {
                let my = topo.domain_of(w);
                let mut order: Vec<usize> = (0..topo.domains()).collect();
                order.sort_by_key(|&d| (d.abs_diff(my), d));
                order.into_boxed_slice()
            })
            .collect();
        let shared = Arc::new(PoolShared {
            deques: (0..cfg.workers)
                .map(|_| StealDeque::with_capacity(cap))
                .collect(),
            slots: (0..max_jobs).map(|_| Mutex::new(None)).collect(),
            injectors: (0..topo.domains())
                .map(|_| Mutex::new(VecDeque::new()))
                .collect(),
            injector_len: AtomicUsize::new(0),
            topo,
            victims,
            inj_order,
            next_domain: AtomicUsize::new(0),
            adm: Mutex::new(Admission {
                pending: VecDeque::new(),
                free_slots: (0..max_jobs).rev().collect(),
                next_gen: vec![0; max_jobs],
                inflight: 0,
                peak_pending: 0,
                shutting_down: false,
                draining: false,
            }),
            shutdown: AtomicBool::new(false),
            active_jobs: AtomicUsize::new(0),
            threads: Mutex::new(Vec::new()),
            task_capacity: cap,
            max_pending: cfg.max_pending,
            event_seq: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let sh = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pool-worker-{w}"))
                    .spawn(move || worker_loop(sh, w))
                    .expect("spawn pool worker"),
            );
        }
        *shared.threads.lock().unwrap() =
            handles.iter().map(|h| h.thread().clone()).collect();
        // A submission may have raced the handle registration only in
        // test-sized interleavings of this constructor's caller; no
        // job can exist yet, so nothing to wake.
        Self { shared, handles }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    pub fn task_capacity(&self) -> usize {
        self.shared.task_capacity
    }

    /// Admitted-but-unfinished jobs right now (racy; diagnostics).
    pub fn active_jobs(&self) -> usize {
        self.shared.active_jobs.load(Ordering::SeqCst)
    }

    /// Submitted-but-unadmitted jobs right now (racy; diagnostics).
    /// Zero once a stream has fully drained.
    pub fn pending_jobs(&self) -> usize {
        self.shared.adm.lock().unwrap().pending.len()
    }

    /// High-water mark of the pending queue, counted *after* each
    /// admission pass — so it measures jobs that genuinely waited on
    /// capacity or dependencies, not jobs merely in transit. A
    /// half-capacity stream of `n` jobs must show `0 < peak ≤ n-1`.
    pub fn peak_pending(&self) -> usize {
        self.shared.adm.lock().unwrap().peak_pending
    }

    /// Run `f` with a submission scope. Jobs submitted through the
    /// scope may borrow anything that outlives `'env`; the scope
    /// blocks until every one of them completed (even on leak or
    /// panic), which is what makes the borrows sound — see module
    /// docs.
    pub fn scope<'env, R>(
        &'env self,
        f: impl FnOnce(&PoolScope<'_, 'env>) -> R,
    ) -> R {
        let scope = PoolScope {
            pool: self,
            jobs: Mutex::new(Vec::new()),
            _env: PhantomData,
        };
        // The guard waits even when `f` unwinds.
        struct Guard<'g>(&'g Mutex<Vec<Arc<JobInner>>>);
        impl Drop for Guard<'_> {
            fn drop(&mut self) {
                for job in self.0.lock().unwrap().drain(..) {
                    let _ = job.wait_done();
                }
            }
        }
        let guard = Guard(&scope.jobs);
        let r = f(&scope);
        drop(guard);
        r
    }

    /// Submit-and-wait convenience for a single job.
    pub fn run(
        &self,
        graph: &TaskGraph,
        run: impl Fn(TaskId) + Send + Sync,
    ) -> Result<ExecStats, Error> {
        self.scope(|s| s.submit(graph, run)?.wait())
    }

    /// Core submission path shared by [`PoolScope`] and the fluent
    /// [`super::session::Session`]: register a job whose `graph` and
    /// `run` borrows have already been erased to `'static`, naming
    /// `deps` as admission predecessors.
    ///
    /// # Safety
    ///
    /// The caller must guarantee every borrow behind `graph` and
    /// inside `run` stays valid until the job completes — `complete`
    /// frees both before releasing any waiter, so "completes" is the
    /// exact lifetime bound. Both front ends uphold it: a `PoolScope`
    /// blocks at scope end, a `Session` waits in its destructor.
    pub(crate) unsafe fn submit_erased(
        &self,
        graph: *const TaskGraph,
        run: Box<dyn Fn(TaskId) + Send + Sync + 'static>,
        deps: Vec<Arc<JobInner>>,
        ctl: JobCtl,
    ) -> Result<Arc<JobInner>, Error> {
        let shared = &self.shared;
        let pool_id = Arc::as_ptr(shared) as usize;
        if deps.iter().any(|d| d.pool_id != pool_id) {
            // A foreign predecessor's completion would never re-run
            // this pool's admission pass: reject instead of stalling.
            return Err(Error::CrossPoolDependency);
        }
        let n = (*graph).len();
        if n > shared.task_capacity || n >= MAX_JOB_TASKS {
            return Err(Error::Submit(SubmitError::GraphTooLarge {
                tasks: n,
                capacity: shared.task_capacity.min(MAX_JOB_TASKS - 1),
            }));
        }
        let job = Arc::new(JobInner {
            packed_base: AtomicUsize::new(0),
            n_tasks: n,
            work: UnsafeCell::new(Some(JobWork { graph, run })),
            indegree: (*graph)
                .indegrees()
                .iter()
                .map(|&d| AtomicUsize::new(d))
                .collect(),
            remaining: AtomicUsize::new(n),
            poisoned: AtomicBool::new(false),
            poison: Mutex::new(None),
            cancel: ctl
                .cancel
                .unwrap_or_else(|| Arc::new(AtomicBool::new(false))),
            deadline: ctl.deadline,
            started: AtomicUsize::new(0),
            ran: AtomicUsize::new(0),
            pool_id,
            deps,
            done: Mutex::new(None),
            cv: Condvar::new(),
            ready_len: AtomicUsize::new(0),
            peak_ready: AtomicUsize::new(0),
            admission_seq: AtomicUsize::new(SEQ_UNSET),
            completion_seq: AtomicUsize::new(SEQ_UNSET),
            domain: AtomicUsize::new(0),
            block_home: if shared.topo.domains() > 1 {
                let nb = (*graph).nb();
                (0..nb * nb).map(|_| AtomicUsize::new(0)).collect()
            } else {
                Vec::new().into_boxed_slice()
            },
        });
        // Every job — including an empty graph — goes through the
        // FIFO queue: an empty job completes at its *admission* point
        // (once its dependencies drained), so it works as a join/
        // barrier node and keeps transitive ordering intact.
        {
            let mut adm = shared.adm.lock().unwrap();
            if adm.shutting_down {
                return Err(Error::Submit(SubmitError::ShutDown));
            }
            if adm.draining {
                return Err(Error::Submit(SubmitError::Draining));
            }
            if let Some(limit) = shared.max_pending {
                if adm.pending.len() >= limit {
                    // Shed at the door: an accepted job is never
                    // dropped, so overload is refused before
                    // acceptance, with the queue depth in the error.
                    return Err(Error::Submit(SubmitError::Overloaded {
                        pending: adm.pending.len(),
                        limit,
                    }));
                }
            }
            adm.pending.push_back(job.clone());
        }
        shared.try_admit();
        Ok(job)
    }

    /// Graceful drain: stop accepting new submissions (they fail with
    /// [`SubmitError::Draining`]) and block until every accepted job
    /// — queued or admitted — has completed. The workers stay alive:
    /// unlike [`Pool::shutdown`] this does not end the pool, it
    /// quiesces it; queued jobs are *completed*, never failed.
    pub fn drain(&self) {
        self.shared.adm.lock().unwrap().draining = true;
        loop {
            let pending: Vec<Arc<JobInner>> = {
                let adm = self.shared.adm.lock().unwrap();
                adm.pending.iter().cloned().collect()
            };
            let running: Vec<Arc<JobInner>> = self
                .shared
                .slots
                .iter()
                .filter_map(|s| s.lock().unwrap().clone())
                .collect();
            if pending.is_empty() && running.is_empty() {
                // A completing job clears its slot before dropping
                // `active_jobs`; spin the brief window out.
                if self.shared.active_jobs.load(Ordering::SeqCst) == 0 {
                    return;
                }
                std::thread::yield_now();
                continue;
            }
            // No new submissions can arrive, so waiting out this
            // snapshot monotonically shrinks the accepted set.
            for job in pending.into_iter().chain(running) {
                let _ = job.wait_done();
            }
        }
    }

    /// Graceful shutdown: stop accepting jobs, fail anything still
    /// queued, let admitted jobs drain, then join the workers. Also
    /// runs on drop.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        let failed: Vec<Arc<JobInner>> = {
            let mut adm = self.shared.adm.lock().unwrap();
            adm.shutting_down = true;
            adm.pending.drain(..).collect()
        };
        for job in failed {
            // SAFETY: drained from `pending` under the admission lock
            // — never admitted, so no worker ever saw this job.
            unsafe {
                *job.work.get() = None;
            }
            job.finish(Err(Error::Submit(SubmitError::ShutDown)));
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.wake_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Submission capability of one [`Pool::scope`] invocation.
pub struct PoolScope<'p, 'env> {
    pool: &'p Pool,
    jobs: Mutex<Vec<Arc<JobInner>>>,
    /// Invariant over `'env`, like `std::thread::Scope`.
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> PoolScope<'_, 'env> {
    /// Submit `graph` for execution; `run` is invoked once per task
    /// (from any worker, concurrently across tasks) exactly like the
    /// one-shot executors' `run`. Returns immediately; the job runs
    /// as capacity allows. Never blocks, never panics on capacity:
    /// jobs that do not fit *yet* queue FIFO, only impossible jobs
    /// are rejected (typed [`Error`]).
    pub fn submit(
        &self,
        graph: &'env TaskGraph,
        run: impl Fn(TaskId) + Send + Sync + 'env,
    ) -> Result<JobHandle, Error> {
        self.submit_after(graph, run, &[])
    }

    /// [`Self::submit`], with inter-job dependencies: the pool defers
    /// this job's admission until every job in `after` completed, so
    /// cross-job read-after-write chains (job B consuming job A's
    /// output) are ordered without any caller-side waiting — the
    /// handles themselves are the dependency declaration.
    ///
    /// Dependencies are ordering-only (a poisoned predecessor still
    /// releases its dependents). A handle from a *different* pool is
    /// rejected with [`Error::CrossPoolDependency`] — a foreign
    /// completion could never re-run this pool's admission. Handles
    /// can only name earlier submissions, so cycles are impossible by
    /// construction. An empty graph submitted with dependencies acts
    /// as a join/barrier node: it completes once its predecessors
    /// drained, and jobs named `after` it stay transitively ordered.
    pub fn submit_after(
        &self,
        graph: &'env TaskGraph,
        run: impl Fn(TaskId) + Send + Sync + 'env,
        after: &[&JobHandle],
    ) -> Result<JobHandle, Error> {
        // SAFETY (lifetime erasure): the scope blocks until this job
        // completes, and `complete` frees the closure and graph borrow
        // before releasing the waiter — so nothing borrowed is touched
        // after `'env` ends. Same pattern as the host runtimes'
        // region erasure (omp/runtime.rs, coordinator par_invoke).
        let run: Box<dyn Fn(TaskId) + Send + Sync + 'env> = Box::new(run);
        let run: Box<dyn Fn(TaskId) + Send + Sync + 'static> = unsafe {
            std::mem::transmute::<
                Box<dyn Fn(TaskId) + Send + Sync + 'env>,
                Box<dyn Fn(TaskId) + Send + Sync + 'static>,
            >(run)
        };
        let deps: Vec<Arc<JobInner>> =
            after.iter().map(|h| h.job.clone()).collect();
        // SAFETY: the scope guard waits for completion before `'env`
        // ends (even on leak or panic), which is exactly the
        // `submit_erased` contract.
        let job = unsafe {
            self.pool.submit_erased(
                graph as *const TaskGraph,
                run,
                deps,
                JobCtl::default(),
            )
        }?;
        self.jobs.lock().unwrap().push(job.clone());
        Ok(JobHandle { job })
    }
}

/// Handle to one submitted job. Dropping it does **not** detach or
/// cancel the job — the owning scope still waits for completion;
/// `wait` just surfaces this job's result early, and the handle is
/// how later submissions name this job as a predecessor
/// ([`PoolScope::submit_after`]).
#[must_use = "a JobHandle is how a job's result (or failure) is \
              observed and how later jobs depend on it"]
pub struct JobHandle {
    job: Arc<JobInner>,
}

/// Cloning a handle is cheap (one `Arc` bump) and safe: dropping a
/// handle never cancels the job, so any clone can wait on or resolve
/// it. The serving layer relies on this to track one job from both a
/// waiter thread and a poll map.
impl Clone for JobHandle {
    fn clone(&self) -> Self {
        Self { job: self.job.clone() }
    }
}

impl JobHandle {
    pub(crate) fn from_inner(job: Arc<JobInner>) -> Self {
        Self { job }
    }

    pub(crate) fn inner(&self) -> &Arc<JobInner> {
        &self.job
    }

    /// Block until the job finishes; returns its stats, or
    /// [`Error::Job`] if the job was poisoned. Idempotent. Must not
    /// be called from inside a pool task (the worker would wait on
    /// itself).
    pub fn wait(&self) -> Result<ExecStats, Error> {
        self.job.wait_done()
    }

    pub fn is_done(&self) -> bool {
        self.job.done.lock().unwrap().is_some()
    }

    /// Position of this job's admission on the pool-wide event clock,
    /// or `None` while it still queues. Admissions are stamped FIFO
    /// under the admission lock, so across any set of handles from one
    /// pool these indices strictly follow submission order.
    pub fn admission_index(&self) -> Option<usize> {
        match self.job.admission_seq.load(Ordering::Acquire) {
            SEQ_UNSET => None,
            s => Some(s),
        }
    }

    /// Position of this job's completion on the same event clock, or
    /// `None` while it runs or queues. A dependent's
    /// [`Self::admission_index`] is strictly greater than each of its
    /// predecessors' completion indices — the machine-checkable form
    /// of the `submit_after` ordering contract.
    pub fn completion_index(&self) -> Option<usize> {
        match self.job.completion_seq.load(Ordering::Acquire) {
            SEQ_UNSET => None,
            s => Some(s),
        }
    }

    /// A clonable cancellation token for this job (see
    /// [`CancelToken::cancel`]).
    pub fn cancel_token(&self) -> CancelToken {
        CancelToken { flag: self.job.cancel.clone() }
    }
}

/// Cooperative cancellation for one job. [`CancelToken::cancel`] asks
/// the job to stop at the next task boundary: tasks not yet started
/// skip their kernels (the completion countdown still drains, so the
/// slot recycles and waiters unblock), tasks already running finish,
/// and the waiter gets [`Error::Cancelled`] with the count of kernels
/// that ran. Cancelling a never-started (queued) job deterministically
/// runs zero kernels. Cancellation is sticky and shared across every
/// retry attempt of the job; cancelling an already-finished job is a
/// no-op on its result.
#[derive(Clone)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat_pattern;
    use crate::sched::GraphBuilder;
    use std::sync::atomic::AtomicUsize;

    fn lu_graph(nb: usize) -> TaskGraph {
        TaskGraph::sparselu(&genmat_pattern(nb), nb)
    }

    #[test]
    fn pack_roundtrip() {
        let base = pack_base(MAX_SLOTS - 1, u32::MAX);
        let p = base | (MAX_JOB_TASKS - 1);
        assert_eq!(p >> SLOT_SHIFT, MAX_SLOTS - 1);
        assert_eq!(p & !TASK_MASK, base);
        assert_eq!(p & TASK_MASK, MAX_JOB_TASKS - 1);
        // No bit overlap between the three fields.
        assert_eq!(pack_base(0, 0), 0);
    }

    #[test]
    fn single_job_runs_every_task_once() {
        let pool = Pool::new(4);
        let g = lu_graph(8);
        let hits: Vec<AtomicUsize> =
            (0..g.len()).map(|_| AtomicUsize::new(0)).collect();
        let stats = pool
            .run(&g, |t| {
                hits[t.0].fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        assert_eq!(stats.executed, g.len());
        assert!(stats.events.is_empty());
        assert!(stats.peak_ready >= 1);
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
        pool.shutdown();
    }

    #[test]
    fn pool_survives_many_sequential_jobs() {
        // The whole point: one spawn, many graphs.
        let pool = Pool::new(3);
        for nb in [2usize, 5, 8, 3, 6] {
            let g = lu_graph(nb);
            let n = AtomicUsize::new(0);
            let stats = pool
                .run(&g, |_| {
                    n.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            assert_eq!(stats.executed, g.len());
            assert_eq!(n.load(Ordering::Relaxed), g.len());
        }
        pool.shutdown();
    }

    #[test]
    fn concurrent_jobs_all_drain() {
        let pool = Pool::new(4);
        let graphs: Vec<TaskGraph> =
            [4usize, 6, 8, 5, 7, 3, 9, 2].iter().map(|&nb| lu_graph(nb)).collect();
        let counts: Vec<AtomicUsize> =
            graphs.iter().map(|_| AtomicUsize::new(0)).collect();
        pool.scope(|s| {
            let handles: Vec<JobHandle> = graphs
                .iter()
                .zip(&counts)
                .map(|(g, c)| {
                    s.submit(g, move |_| {
                        c.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap()
                })
                .collect();
            for (h, g) in handles.iter().zip(&graphs) {
                assert_eq!(h.wait().unwrap().executed, g.len());
            }
        });
        for (c, g) in counts.iter().zip(&graphs) {
            assert_eq!(c.load(Ordering::Relaxed), g.len());
        }
        assert_eq!(pool.active_jobs(), 0);
        pool.shutdown();
    }

    #[test]
    fn scope_waits_even_without_explicit_wait() {
        let pool = Pool::new(2);
        let g = lu_graph(10);
        let n = AtomicUsize::new(0);
        pool.scope(|s| {
            // Handle dropped immediately; scope end must still block
            // until the job drained (this is the borrow-soundness
            // contract).
            let _ = s
                .submit(&g, |_| {
                    n.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
        });
        assert_eq!(n.load(Ordering::Relaxed), g.len());
        pool.shutdown();
    }

    #[test]
    fn graph_too_large_is_typed_and_harmless() {
        let pool = Pool::with_config(PoolConfig {
            workers: 2,
            task_capacity: 10,
            max_jobs: 4,
            max_pending: None,
            domains: 1,
        });
        let big = lu_graph(8); // hundreds of tasks
        let small = lu_graph(2);
        pool.scope(|s| {
            let err = s.submit(&big, |_| {}).unwrap_err();
            assert_eq!(
                err,
                Error::Submit(SubmitError::GraphTooLarge {
                    tasks: big.len(),
                    capacity: 10
                })
            );
            assert!(err.to_string().contains("exceeds"));
            // Pool still fully functional for jobs that fit.
            let h = s.submit(&small, |_| {}).unwrap();
            assert_eq!(h.wait().unwrap().executed, small.len());
        });
        pool.shutdown();
    }

    #[test]
    fn over_capacity_jobs_queue_fifo_and_all_finish() {
        // Capacity fits exactly one copy of the graph: three
        // submissions must serialise through admission, not panic,
        // not drop, not deadlock.
        let g = lu_graph(6);
        let pool = Pool::with_config(PoolConfig {
            workers: 3,
            task_capacity: g.len(),
            max_jobs: 8,
            max_pending: None,
            domains: 1,
        });
        let n = AtomicUsize::new(0);
        pool.scope(|s| {
            let hs: Vec<JobHandle> = (0..3)
                .map(|_| {
                    s.submit(&g, |_| {
                        n.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap()
                })
                .collect();
            for h in &hs {
                assert_eq!(h.wait().unwrap().executed, g.len());
            }
        });
        assert_eq!(n.load(Ordering::Relaxed), 3 * g.len());
        pool.shutdown();
    }

    #[test]
    fn locality_domains_complete_saturated_cross_domain_streams() {
        // The satellite's no-starvation check: with one worker per
        // affinity domain and six concurrent jobs round-robined across
        // the two domains, every domain is saturated with pinned work
        // — yet every job must complete with every task executed,
        // because nearest-first stealing still crosses domains once
        // the local sources dry up. Locality is a preference, never a
        // partition.
        let g = lu_graph(6);
        let pool = Pool::with_config(PoolConfig {
            workers: 2,
            task_capacity: 1 << 12,
            max_jobs: 8,
            max_pending: None,
            domains: 2,
        });
        let n = AtomicUsize::new(0);
        pool.scope(|s| {
            let hs: Vec<JobHandle> = (0..6)
                .map(|_| {
                    s.submit(&g, |_| {
                        n.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap()
                })
                .collect();
            for h in &hs {
                assert_eq!(h.wait().unwrap().executed, g.len());
            }
        });
        assert_eq!(n.load(Ordering::Relaxed), 6 * g.len());
        pool.shutdown();
    }

    #[test]
    fn locality_domains_clamp_and_degenerate_to_flat() {
        // More domains than workers must clamp (every domain
        // nonempty), and a single worker with "4 domains" is just the
        // serial pool — the whole stream still drains.
        let g = lu_graph(4);
        let pool = Pool::with_config(PoolConfig {
            workers: 1,
            task_capacity: 1 << 10,
            max_jobs: 4,
            max_pending: None,
            domains: 4,
        });
        let n = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..3 {
                s.submit(&g, |_| {
                    n.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            }
        });
        assert_eq!(n.load(Ordering::Relaxed), 3 * g.len());
        pool.shutdown();
    }

    #[test]
    fn event_clock_orders_admissions_and_completions() {
        // One slot + a gated first job: the rest of the stream is
        // provably queued (pending == 3, no admission stamp) until the
        // gate opens; afterwards the stamps must show FIFO admission,
        // serial completion, and slot-recycling order.
        let g = lu_graph(4);
        let pool = Pool::with_config(PoolConfig {
            workers: 2,
            task_capacity: 1 << 12,
            max_jobs: 1,
            max_pending: None,
            domains: 1,
        });
        let gate = AtomicBool::new(false);
        pool.scope(|s| {
            let h0 = s
                .submit(&g, |_| {
                    while !gate.load(Ordering::Acquire) {
                        std::hint::spin_loop();
                    }
                })
                .unwrap();
            let rest: Vec<JobHandle> =
                (0..3).map(|_| s.submit(&g, |_| {}).unwrap()).collect();
            assert_eq!(pool.pending_jobs(), 3);
            for h in &rest {
                assert!(h.admission_index().is_none(), "still queued");
                assert!(h.completion_index().is_none());
            }
            gate.store(true, Ordering::Release);
            let mut hs = vec![h0];
            hs.extend(rest);
            for h in &hs {
                h.wait().unwrap();
            }
            let adm: Vec<usize> =
                hs.iter().map(|h| h.admission_index().unwrap()).collect();
            let cpl: Vec<usize> =
                hs.iter().map(|h| h.completion_index().unwrap()).collect();
            assert!(adm.windows(2).all(|w| w[0] < w[1]), "FIFO: {adm:?}");
            for (a, c) in adm.iter().zip(&cpl) {
                assert!(a < c, "admission precedes completion");
            }
            // Single slot: job k+1 admits only after job k completed.
            for k in 0..hs.len() - 1 {
                assert!(cpl[k] < adm[k + 1], "{cpl:?} vs {adm:?}");
            }
        });
        assert!(pool.peak_pending() >= 3, "the tail genuinely queued");
        assert_eq!(pool.pending_jobs(), 0);
        pool.shutdown();
    }

    #[test]
    fn dependency_completion_precedes_dependent_admission() {
        let pool = Pool::new(3);
        let g = lu_graph(5);
        pool.scope(|s| {
            let a = s.submit(&g, |_| {}).unwrap();
            let b = s.submit_after(&g, |_| {}, &[&a]).unwrap();
            b.wait().unwrap();
            assert!(
                a.completion_index().unwrap()
                    < b.admission_index().unwrap(),
                "dependent admitted before its predecessor completed"
            );
        });
        pool.shutdown();
    }

    #[test]
    fn slot_exhaustion_queues_and_recycles() {
        // One slot: every job runs alone; generations must recycle
        // the slot safely across many jobs.
        let g = lu_graph(4);
        let pool = Pool::with_config(PoolConfig {
            workers: 2,
            task_capacity: 1 << 12,
            max_jobs: 1,
            max_pending: None,
            domains: 1,
        });
        pool.scope(|s| {
            let hs: Vec<JobHandle> =
                (0..6).map(|_| s.submit(&g, |_| {}).unwrap()).collect();
            for h in &hs {
                assert_eq!(h.wait().unwrap().executed, g.len());
            }
        });
        pool.shutdown();
    }

    #[test]
    fn panic_poisons_only_its_job() {
        let pool = Pool::new(4);
        let g = lu_graph(8);
        let ok_count = AtomicUsize::new(0);
        pool.scope(|s| {
            let bad = s
                .submit(&g, |t| {
                    if t.0 == 3 {
                        panic!("pool job exploded");
                    }
                })
                .unwrap();
            let good = s
                .submit(&g, |_| {
                    ok_count.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            let e = bad.wait().unwrap_err();
            assert!(
                e.to_string().contains("pool job exploded"),
                "{e}"
            );
            assert!(matches!(e, Error::Job(_)));
            // The poison record names where the job died.
            if let Error::Job(fail) = &e {
                assert_eq!(fail.attempts.len(), 1);
                assert_eq!(fail.last().attempt, 1);
                assert_eq!(fail.last().task, 3);
                assert!(
                    ["lu0", "fwd", "bdiv", "bmod"]
                        .contains(&fail.last().op),
                    "{}",
                    fail.last().op
                );
            }
            // Idempotent error.
            assert!(bad.wait().is_err());
            assert_eq!(good.wait().unwrap().executed, g.len());
        });
        assert_eq!(ok_count.load(Ordering::Relaxed), g.len());
        // Pool survives for the next scope.
        let n = AtomicUsize::new(0);
        pool.run(&g, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(n.load(Ordering::Relaxed), g.len());
        pool.shutdown();
    }

    #[test]
    fn deep_idle_pool_accepts_late_jobs() {
        let pool = Pool::new(2);
        let g = lu_graph(6);
        pool.run(&g, |_| {}).unwrap();
        // Let every worker reach the unbounded park.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let n = AtomicUsize::new(0);
        pool.run(&g, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(n.load(Ordering::Relaxed), g.len());
        pool.shutdown();
    }

    #[test]
    fn empty_graph_completes_immediately() {
        let pool = Pool::new(1);
        let g = GraphBuilder::new(1).build(crate::sched::LU_OPS);
        assert_eq!(g.len(), 0);
        pool.scope(|s| {
            let h = s.submit(&g, |_| unreachable!()).unwrap();
            assert!(h.is_done());
            assert_eq!(h.wait().unwrap().executed, 0);
        });
        pool.shutdown();
    }

    #[test]
    fn shutdown_fails_pending_jobs_with_typed_message() {
        // Fill the single slot with a long job, queue another, then
        // drop the pool from a second thread while the scope waits:
        // the pending job must fail (not hang). Easier deterministic
        // variant: mark shutting_down first, then submit.
        let pool = Pool::new(2);
        let g = lu_graph(4);
        pool.shared.adm.lock().unwrap().shutting_down = true;
        pool.scope(|s| {
            let err = s.submit(&g, |_| {}).unwrap_err();
            assert_eq!(err, Error::Submit(SubmitError::ShutDown));
        });
        pool.shutdown();
    }

    #[test]
    fn cross_job_stealing_spreads_work() {
        // Two single-root jobs on four workers: tasks are pushed to
        // the running worker's own deque, so with slow kernels more
        // than one thread can only be busy via (cross-job) stealing.
        let pool = Pool::new(4);
        let g1 = lu_graph(10);
        let g2 = lu_graph(10);
        let threads = Mutex::new(std::collections::HashSet::new());
        let slow = |_: TaskId| {
            for _ in 0..5_000 {
                std::hint::spin_loop();
            }
            threads.lock().unwrap().insert(std::thread::current().id());
        };
        pool.scope(|s| {
            let a = s.submit(&g1, &slow).unwrap();
            let b = s.submit(&g2, &slow).unwrap();
            a.wait().unwrap();
            b.wait().unwrap();
        });
        assert!(
            threads.lock().unwrap().len() > 1,
            "only one worker ever ran a task — stealing is dead"
        );
        pool.shutdown();
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = Pool::new(3);
        let g = lu_graph(5);
        pool.run(&g, |_| {}).unwrap();
        drop(pool); // must join without hanging
    }

    #[test]
    fn after_dependency_orders_cross_job() {
        // Job B names job A as a predecessor: not one task of B may
        // start before every task of A completed, even though both
        // are submitted back-to-back and A's kernels are slow.
        let pool = Pool::new(4);
        let g1 = lu_graph(8);
        let g2 = lu_graph(8);
        let a_done = AtomicUsize::new(0);
        let violated = AtomicBool::new(false);
        pool.scope(|s| {
            let a = s
                .submit(&g1, |_| {
                    for _ in 0..2_000 {
                        std::hint::spin_loop();
                    }
                    a_done.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            let b = s
                .submit_after(
                    &g2,
                    |_| {
                        if a_done.load(Ordering::SeqCst) != g1.len() {
                            violated.store(true, Ordering::SeqCst);
                        }
                    },
                    &[&a],
                )
                .unwrap();
            assert_eq!(b.wait().unwrap().executed, g2.len());
            assert!(a.is_done(), "predecessor must have completed");
        });
        assert!(
            !violated.load(Ordering::SeqCst),
            "a task of B ran before A drained"
        );
        pool.shutdown();
    }

    #[test]
    fn after_dependency_on_finished_job_admits_immediately() {
        let pool = Pool::new(2);
        let g = lu_graph(5);
        pool.scope(|s| {
            let a = s.submit(&g, |_| {}).unwrap();
            a.wait().unwrap();
            let b = s.submit_after(&g, |_| {}, &[&a]).unwrap();
            assert_eq!(b.wait().unwrap().executed, g.len());
        });
        pool.shutdown();
    }

    #[test]
    fn dependency_chain_of_three_is_fully_serial() {
        let pool = Pool::new(4);
        let g = lu_graph(6);
        let n = g.len();
        let counter = AtomicUsize::new(0);
        let bad = AtomicBool::new(false);
        pool.scope(|s| {
            let check = |lo: usize| {
                let counter = &counter;
                let bad = &bad;
                move |_: TaskId| {
                    let c = counter.fetch_add(1, Ordering::SeqCst);
                    if c < lo {
                        bad.store(true, Ordering::SeqCst);
                    }
                }
            };
            let a = s.submit(&g, check(0)).unwrap();
            let b = s.submit_after(&g, check(n), &[&a]).unwrap();
            let c = s.submit_after(&g, check(2 * n), &[&a, &b]).unwrap();
            assert_eq!(c.wait().unwrap().executed, n);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 3 * g.len());
        assert!(!bad.load(Ordering::SeqCst), "chain order violated");
        pool.shutdown();
    }

    #[test]
    fn poisoned_dependency_still_releases_dependent() {
        // Ordering-only semantics: a failed predecessor completes,
        // so its dependents run (on whatever state it left).
        let pool = Pool::new(3);
        let g = lu_graph(6);
        let ran = AtomicUsize::new(0);
        pool.scope(|s| {
            let a = s
                .submit(&g, |t| {
                    if t.0 == 1 {
                        panic!("dep exploded");
                    }
                })
                .unwrap();
            let b = s
                .submit_after(
                    &g,
                    |_| {
                        ran.fetch_add(1, Ordering::SeqCst);
                    },
                    &[&a],
                )
                .unwrap();
            assert!(a.wait().is_err());
            assert_eq!(b.wait().unwrap().executed, g.len());
        });
        assert_eq!(ran.load(Ordering::SeqCst), g.len());
        pool.shutdown();
    }

    #[test]
    fn cross_pool_dependency_is_rejected_not_deadlocked() {
        let pool_a = Pool::new(2);
        let pool_b = Pool::new(2);
        let g = lu_graph(5);
        pool_a.scope(|sa| {
            let a = sa.submit(&g, |_| {}).unwrap();
            pool_b.scope(|sb| {
                let err = sb.submit_after(&g, |_| {}, &[&a]).unwrap_err();
                assert_eq!(err, Error::CrossPoolDependency);
                // pool_b stays fully usable.
                let ok = sb.submit(&g, |_| {}).unwrap();
                assert_eq!(ok.wait().unwrap().executed, g.len());
            });
            a.wait().unwrap();
        });
        pool_a.shutdown();
        pool_b.shutdown();
    }

    #[test]
    fn empty_job_is_a_barrier_preserving_transitive_order() {
        // A (slow) -> E (empty) -> C: C's tasks must observe all of
        // A's, even though E carries no tasks of its own — the empty
        // job completes at its admission point, after its deps.
        let pool = Pool::new(4);
        let g = lu_graph(7);
        let empty = GraphBuilder::new(1).build(crate::sched::LU_OPS);
        assert_eq!(empty.len(), 0);
        let a_done = AtomicUsize::new(0);
        let violated = AtomicBool::new(false);
        pool.scope(|s| {
            let a = s
                .submit(&g, |_| {
                    for _ in 0..2_000 {
                        std::hint::spin_loop();
                    }
                    a_done.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            let e = s.submit_after(&empty, |_| unreachable!(), &[&a]).unwrap();
            let c = s
                .submit_after(
                    &g,
                    |_| {
                        if a_done.load(Ordering::SeqCst) != g.len() {
                            violated.store(true, Ordering::SeqCst);
                        }
                    },
                    &[&e],
                )
                .unwrap();
            assert_eq!(c.wait().unwrap().executed, g.len());
            assert!(e.is_done());
        });
        assert!(
            !violated.load(Ordering::SeqCst),
            "a task of C ran before A drained (through the empty join)"
        );
        pool.shutdown();
    }

    #[test]
    fn dependency_plus_capacity_pressure_no_deadlock() {
        // Capacity admits only one copy of the graph at a time AND
        // the stream carries dependency edges: admission must stay
        // live (FIFO + deps resolve front-to-back).
        let g = lu_graph(6);
        let pool = Pool::with_config(PoolConfig {
            workers: 3,
            task_capacity: g.len(),
            max_jobs: 8,
            max_pending: None,
            domains: 1,
        });
        pool.scope(|s| {
            let a = s.submit(&g, |_| {}).unwrap();
            let b = s.submit_after(&g, |_| {}, &[&a]).unwrap();
            let c = s.submit(&g, |_| {}).unwrap();
            let d = s.submit_after(&g, |_| {}, &[&b, &c]).unwrap();
            for h in [&a, &b, &c, &d] {
                assert_eq!(h.wait().unwrap().executed, g.len());
            }
        });
        pool.shutdown();
    }

    /// Test-only mirror of [`PoolScope::submit_after`] that attaches an
    /// explicit [`JobCtl`] (the session front end's path to deadlines).
    fn submit_ctl<'env>(
        s: &PoolScope<'_, 'env>,
        graph: &'env TaskGraph,
        run: impl Fn(TaskId) + Send + Sync + 'env,
        ctl: JobCtl,
    ) -> Result<JobHandle, Error> {
        let run: Box<dyn Fn(TaskId) + Send + Sync + 'env> = Box::new(run);
        // SAFETY: same lifetime-erasure contract as `submit_after` —
        // the enclosing scope blocks until the job completes.
        let run: Box<dyn Fn(TaskId) + Send + Sync + 'static> =
            unsafe { std::mem::transmute(run) };
        let job = unsafe {
            s.pool.submit_erased(
                graph as *const TaskGraph,
                run,
                Vec::new(),
                ctl,
            )
        }?;
        s.jobs.lock().unwrap().push(job.clone());
        Ok(JobHandle { job })
    }

    #[test]
    fn cancel_token_on_pending_job_runs_zero_kernels() {
        // Cancel a job while it is provably still queued (its
        // predecessor is gated): not one of its kernels may run, and
        // the waiter gets the typed `Cancelled { ran: 0 }`.
        let pool = Pool::new(2);
        let g = lu_graph(6);
        let gate = AtomicBool::new(false);
        let ran = AtomicUsize::new(0);
        pool.scope(|s| {
            let a = s
                .submit(&g, |_| {
                    while !gate.load(Ordering::Acquire) {
                        std::hint::spin_loop();
                    }
                })
                .unwrap();
            let b = s
                .submit_after(
                    &g,
                    |_| {
                        ran.fetch_add(1, Ordering::SeqCst);
                    },
                    &[&a],
                )
                .unwrap();
            let tok = b.cancel_token();
            assert!(!tok.is_cancelled());
            tok.cancel();
            assert!(tok.is_cancelled());
            gate.store(true, Ordering::Release);
            assert_eq!(
                b.wait().unwrap_err(),
                Error::Cancelled { ran: 0 }
            );
            // Idempotent, and the sibling is untouched.
            assert!(b.wait().is_err());
            assert_eq!(a.wait().unwrap().executed, g.len());
        });
        assert_eq!(ran.load(Ordering::SeqCst), 0);
        // Cancellation never poisons the pool.
        pool.run(&g, |_| {}).unwrap();
        pool.shutdown();
    }

    #[test]
    fn deadline_caps_execution_at_exactly_d_kernels() {
        // The ticket protocol makes a completed-task-count deadline
        // schedule-independent: exactly `min(d, n)` kernels execute,
        // whatever the worker interleaving.
        let pool = Pool::new(3);
        let g = lu_graph(6);
        let n = g.len();
        for d in [1usize, 3, n, n + 100] {
            let ran = AtomicUsize::new(0);
            pool.scope(|s| {
                let h = submit_ctl(
                    s,
                    &g,
                    |_| {
                        ran.fetch_add(1, Ordering::SeqCst);
                    },
                    JobCtl { deadline: Some(d), cancel: None },
                )
                .unwrap();
                if d >= n {
                    assert_eq!(h.wait().unwrap().executed, n);
                    assert_eq!(ran.load(Ordering::SeqCst), n);
                } else {
                    assert_eq!(
                        h.wait().unwrap_err(),
                        Error::Cancelled { ran: d }
                    );
                    assert_eq!(ran.load(Ordering::SeqCst), d);
                }
            });
        }
        pool.shutdown();
    }

    #[test]
    fn shed_bound_rejects_typed_and_never_drops_admitted() {
        // Pending depth is capped at 2: with the head job gated (so
        // its dependents provably queue), the third dependent is shed
        // with the typed error; everything accepted still completes,
        // and once the backlog drains the pool accepts again.
        let g = lu_graph(5);
        let pool = Pool::with_config(
            PoolConfig {
                workers: 2,
                task_capacity: 1 << 12,
                max_jobs: 8,
                max_pending: None,
                domains: 1,
            }
            .shed(2),
        );
        let gate = AtomicBool::new(false);
        let n = AtomicUsize::new(0);
        pool.scope(|s| {
            let head = s
                .submit(&g, |_| {
                    while !gate.load(Ordering::Acquire) {
                        std::hint::spin_loop();
                    }
                    n.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            let count = |_: TaskId| {
                n.fetch_add(1, Ordering::SeqCst);
            };
            let q1 = s.submit_after(&g, count, &[&head]).unwrap();
            let q2 = s.submit_after(&g, count, &[&head]).unwrap();
            let err = s.submit_after(&g, count, &[&head]).unwrap_err();
            assert_eq!(
                err,
                Error::Submit(SubmitError::Overloaded {
                    pending: 2,
                    limit: 2
                })
            );
            assert!(err.to_string().contains("overloaded"), "{err}");
            gate.store(true, Ordering::Release);
            for h in [&head, &q1, &q2] {
                assert_eq!(h.wait().unwrap().executed, g.len());
            }
            // Backlog drained: the shed bound no longer bites.
            let late = s.submit(&g, count).unwrap();
            late.wait().unwrap();
        });
        assert_eq!(n.load(Ordering::SeqCst), 4 * g.len());
        pool.shutdown();
    }

    #[test]
    fn drain_completes_accepted_then_rejects_late_submissions() {
        let pool = Pool::new(2);
        let g = lu_graph(6);
        let n = AtomicUsize::new(0);
        pool.scope(|s| {
            let count = |_: TaskId| {
                n.fetch_add(1, Ordering::SeqCst);
            };
            let a = s.submit(&g, count).unwrap();
            let b = s.submit_after(&g, count, &[&a]).unwrap();
            pool.drain();
            // Drain returned only once everything accepted completed.
            assert!(a.is_done() && b.is_done());
            assert_eq!(n.load(Ordering::SeqCst), 2 * g.len());
            assert_eq!(pool.active_jobs(), 0);
            let err = s.submit(&g, count).unwrap_err();
            assert_eq!(err, Error::Submit(SubmitError::Draining));
            assert!(err.to_string().contains("draining"), "{err}");
        });
        pool.shutdown();
    }
}
