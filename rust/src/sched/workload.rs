//! First-class workload declarations: the [`Workload`] trait and the
//! [`registry`] that drives every layer from one definition.
//!
//! Before this module, adding a workload to the engine cost six
//! parallel edits: a graph constructor in `sched/graph.rs`, a kernel
//! table in `apps/`, a `*_dataflow_batch` wrapper, a tilesim cost
//! encoder hook, a CLI `--app` arm and a verifier. Following the
//! PLASMA-style separation of algorithm-as-DAG from runtime (Buttari
//! et al., arXiv:0709.1272) and GPRM's task-composition front end
//! (arXiv:1312.2703), a workload is now **declared once** — task
//! stream, kernel table, input generator, sequential reference,
//! verifier, flop pricing and simulator cost — and every consumer
//! reads the declaration:
//!
//! * the **engine** builds the DAG from [`Workload::build`] (access
//!   sets in, RAW/WAW/WAR edges out);
//! * the **drivers and the pool** dispatch through
//!   [`Workload::kernels`] (see
//!   [`crate::apps::dataflow::run_workload`] and
//!   [`super::session::Session`]);
//! * the **simulator** prices every task through
//!   [`Workload::sim_cost`] (see
//!   [`crate::tilesim::workload::dag_sim_task`]) and replays the
//!   paper's level-synchronous straw man from [`Workload::phases`];
//! * the **CLI, harness and benches** iterate [`registry`] instead of
//!   matching on names, so they can never drift from the registered
//!   workloads.
//!
//! Adding workload #4 (tiled QR, triangular solve, …) is now one impl
//! block in this file plus one line in [`registry`] — see the
//! "Defining a workload" walkthrough in the crate docs
//! ([`crate`]).

use super::graph::{
    GraphBuilder, OpId, OpSpec, Task, TaskGraph, TaskId, CHOLESKY_OPS,
    LU_OPS, MATMUL_OPS, OP_BDIV, OP_BMOD, OP_FWD, OP_GEMM, OP_LU0,
    OP_MADD, OP_POTRF, OP_SYRK, OP_TRSM,
};
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use crate::linalg::cholesky::{
    cholesky_seq, gemm_nt, gen_spd, potrf, sym_dense, syrk, trsm,
};
use crate::linalg::dense::DenseMatrix;
use crate::linalg::genmat::{genmat, genmat_pattern};
use crate::linalg::lu::{bdiv, bmod, fwd, lu0, sparselu_seq};
use crate::linalg::microkernel::{
    bmod_mk, gemm_nt_mk, madd_mk, syrk_mk, trsm_mk, KernelMode,
};
use crate::linalg::verify::{chol_residual_sparse, lu_residual_sparse};
use crate::tilesim::workload::Phase;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Problem sizing shared by every workload: `nb` blocks per grid
/// dimension, `bs × bs` elements per block. (For the blocked matmul
/// `nb` counts the *logical* `C` grid; the embedded scheduling grid is
/// `2·nb` wide — see [`Matmul`].)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Params {
    pub nb: usize,
    pub bs: usize,
}

impl Params {
    pub fn new(nb: usize, bs: usize) -> Self {
        Self { nb, bs }
    }
}

/// Simulator-facing cost of one task: useful flops plus the bytes of
/// shared-fabric/DRAM traffic it generates regardless of locality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskCost {
    pub flops: u64,
    pub mem_bytes: u64,
}

impl TaskCost {
    /// The default cost encoding, derived purely from the op table and
    /// the task's access-set shape: flops from the op's pricing
    /// function; shared-fabric bytes are one block for a streaming
    /// kernel, plus one block per read stream beyond the first, plus
    /// one more for materialising a fresh fill-in block
    /// (`alloc_write`). This is byte-for-byte the encoding the PR-2
    /// SparseLU model charged (the committed `BENCH_sched.json`
    /// baseline rows re-derive from it to the digit).
    pub fn from_access_sets(t: &Task, ops: &[OpSpec], bs: usize) -> Self {
        let bb = (bs * bs * 4) as u64;
        let extra = t.n_reads as u64;
        Self {
            flops: (ops[t.op.0].flops)(bs),
            mem_bytes: bb
                * (1 + extra.saturating_sub(1) + u64::from(t.alloc_write)),
        }
    }
}

/// One entry of a workload's executable kernel table: `(reads, write,
/// bs)` — the extra read blocks in task order, then the (exclusive)
/// write block. Indexed by op id, aligned with the workload's
/// [`OpSpec`] table.
pub type BlockKernel<'k> =
    &'k (dyn Fn(&[&[f32]], &mut [f32], usize) + Sync);

/// A workload, declared once: everything the engine, the pool, the
/// simulator, the CLI, the harness and the benches need to run it.
///
/// Implementations are zero-sized registry entries ([`Sparselu`],
/// [`Cholesky`], [`Matmul`]); consumers hold `&'static dyn Workload`
/// from [`registry`] / [`find`]. Only [`Workload::build`],
/// [`Workload::kernels`], [`Workload::make_input`],
/// [`Workload::reference_seq`], [`Workload::residual`] and the naming
/// methods are mandatory — graph assembly, bit-verification, flop
/// pricing and the simulator cost encoding all have derived defaults.
pub trait Workload: Send + Sync {
    /// Registry name — also the CLI `--app` value.
    fn name(&self) -> &'static str;

    /// One-line description for `--list-apps`.
    fn description(&self) -> &'static str;

    /// The kernel vocabulary (display names + flop pricing) the
    /// graph's op ids index into.
    fn ops(&self) -> &'static [OpSpec];

    /// Side of the block grid the graph is built over (defaults to
    /// `p.nb`; the embedded matmul uses `2·nb`).
    fn grid(&self, p: &Params) -> usize {
        p.nb
    }

    /// Declare the task stream in sequential program order: one
    /// `b.add_task(op, reads, write, alloc_write)` per block kernel.
    /// The builder derives every RAW/WAW/WAR edge from the access
    /// sets, which is what keeps any edge-respecting schedule
    /// bit-identical (f32) to [`Workload::reference_seq`].
    fn build(&self, b: &mut GraphBuilder, p: &Params);

    /// Assemble the canonical task graph for `p` (derived from
    /// [`Workload::build`]).
    fn graph(&self, p: &Params) -> TaskGraph {
        let mut b = GraphBuilder::new(self.grid(p));
        self.build(&mut b, p);
        b.build(self.ops())
    }

    /// Assemble the graph matching a *specific* input matrix.
    /// Defaults to the canonical graph for the matrix's sizing;
    /// workloads whose structure depends on the input (SparseLU's
    /// sparsity pattern) override it.
    fn graph_for(&self, a: &BlockedSparseMatrix) -> TaskGraph {
        self.graph(&Params::new(a.nb(), a.bs()))
    }

    /// The executable plain-rust kernel table, indexed by op id and
    /// aligned with [`Workload::ops`].
    fn kernels(&self) -> &'static [BlockKernel<'static>];

    /// The kernel table for an explicit precision policy (see
    /// [`crate::linalg::microkernel`]). `BitIdentical` — the
    /// conformance default everywhere — routes the update kernels
    /// through the microkernel layer, whose bit-identical paths
    /// produce the same f32 bits as [`Workload::kernels`] on every
    /// build and SIMD level; `Fast` swaps in the residual-bounded
    /// paired-accumulator variants (see DIVERGENCES.md). The default
    /// impl ignores the mode, for workloads without microkernel
    /// coverage.
    fn kernels_for(
        &self,
        _mode: KernelMode,
    ) -> &'static [BlockKernel<'static>] {
        self.kernels()
    }

    /// Generate a deterministic input matrix for `p`. `seed` selects
    /// among input families where the generator supports it (the
    /// matmul operands); the BOTS/SPD factorisation generators are
    /// seed-independent by construction.
    fn make_input(&self, p: &Params, seed: u32) -> BlockedSparseMatrix;

    /// The sequential reference: transform `a` in place using exactly
    /// the kernels and per-block order the graph encodes. Every
    /// parallel schedule is bit-compared against this.
    fn reference_seq(&self, a: &mut BlockedSparseMatrix);

    /// Mathematical residual of `result` against ground truth
    /// reconstructed from the untouched input `orig` (e.g.
    /// `‖A − LU‖/‖A‖`). Small (`< 1e-3`) on a correct run.
    fn residual(
        &self,
        orig: &BlockedSparseMatrix,
        result: &BlockedSparseMatrix,
    ) -> f64;

    /// Bit-exactness check of a parallel result against the sequential
    /// reference output (not merely "close": the graph chains every
    /// touch of a block in program order, so f32 equality is the
    /// contract).
    fn verify_bits(
        &self,
        got: &BlockedSparseMatrix,
        reference: &BlockedSparseMatrix,
    ) -> Result<(), String> {
        if got.pattern() != reference.pattern() {
            return Err(format!(
                "{}: result allocation pattern differs from the \
                 sequential reference",
                self.name()
            ));
        }
        if got.to_dense().as_slice() != reference.to_dense().as_slice() {
            return Err(format!(
                "{}: result not bit-identical (f32) to the sequential \
                 reference",
                self.name()
            ));
        }
        Ok(())
    }

    /// Useful flops one `bs×bs` instance of `op` performs (from the op
    /// table).
    fn flops(&self, op: OpId, bs: usize) -> u64 {
        (self.ops()[op.0].flops)(bs)
    }

    /// Total useful flops of a task graph at block size `bs` — the
    /// single FLOP accounting the benches, the harness and the
    /// autotuner all share (no per-consumer copies).
    fn graph_flops(&self, graph: &TaskGraph, bs: usize) -> u64 {
        graph.tasks().iter().map(|t| self.flops(t.op, bs)).sum()
    }

    /// Simulator cost of one task. The default derives it from the op
    /// table and the access-set shape
    /// ([`TaskCost::from_access_sets`]) — the single encoding every
    /// committed `BENCH_sched.json` baseline row was produced by.
    /// Workloads with unusual memory behaviour may override.
    fn sim_cost(&self, t: &Task, bs: usize) -> TaskCost {
        TaskCost::from_access_sets(t, self.ops(), bs)
    }

    /// The paper-style *level-synchronous phase stream* for this
    /// workload, if it has one — the barrier straw man the DAG
    /// schedule is raced against in the `dataflow` experiment. `None`
    /// (the default) skips the workload in phase-vs-DAG comparisons;
    /// it still runs everywhere else.
    fn phases(
        &self,
        _p: &Params,
    ) -> Option<Box<dyn Iterator<Item = Phase>>> {
        None
    }
}

// ---------------------------------------------------------------------
// Generic kernel dispatch (shared by the one-shot drivers, the pool
// batch path and the Session front end)
// ---------------------------------------------------------------------

/// The per-task dispatch closure shared by every host: split-borrow
/// the task's blocks zero-copy from `shared` and fire
/// `kernels[task.op]`. The closure is `Send + Sync` so the pool can
/// run it from any worker; the access-set discipline that makes the
/// unsafe block sound is documented inline.
pub fn kernel_runner<'a>(
    graph: &'a TaskGraph,
    kernels: &'a [BlockKernel<'a>],
    shared: &'a SharedBlocked,
    bs: usize,
) -> impl Fn(TaskId) + Send + Sync + 'a {
    move |id: TaskId| {
        let t = *graph.task(id);
        // SAFETY: the task graph chains every touch of a given block
        // (RAW/WAW/WAR) and every executor host carries a
        // release/acquire edge per dependency (see `SharedBlocked`'s
        // Sync impl), so this task has exclusive access to the block
        // it writes and read-only access to blocks finalised by its
        // predecessors. Fill-in allocation mutates only the written
        // block's own slot. Within the task the borrows split,
        // zero-copy.
        let m = unsafe { shared.get_mut() };
        if t.alloc_write {
            m.allocate_clean_block(t.write.0, t.write.1);
        }
        let kernel = kernels[t.op.0];
        match t.reads() {
            [] => {
                let w = m.block_mut(t.write.0, t.write.1).unwrap();
                kernel(&[], w, bs);
            }
            &[r0] => {
                let (r, w) = m.block_and_mut(r0, t.write).unwrap();
                kernel(&[r], w, bs);
            }
            &[r0, r1] => {
                let (a0, a1, w) = m.read2_write1(r0, r1, t.write).unwrap();
                kernel(&[a0, a1], w, bs);
            }
            _ => unreachable!("tasks carry at most two extra reads"),
        }
    }
}

// ---------------------------------------------------------------------
// SparseLU
// ---------------------------------------------------------------------

fn rk_lu0(_r: &[&[f32]], w: &mut [f32], bs: usize) {
    lu0(w, bs)
}
fn rk_fwd(r: &[&[f32]], w: &mut [f32], bs: usize) {
    fwd(r[0], w, bs)
}
fn rk_bdiv(r: &[&[f32]], w: &mut [f32], bs: usize) {
    bdiv(r[0], w, bs)
}
fn rk_bmod(r: &[&[f32]], w: &mut [f32], bs: usize) {
    bmod(r[0], r[1], w, bs)
}

fn rk_bmod_mk(r: &[&[f32]], w: &mut [f32], bs: usize) {
    bmod_mk(KernelMode::BitIdentical, r[0], r[1], w, bs)
}
fn rk_bmod_fast(r: &[&[f32]], w: &mut [f32], bs: usize) {
    bmod_mk(KernelMode::Fast, r[0], r[1], w, bs)
}

/// The plain-rust SparseLU kernel table, aligned with [`LU_OPS`] —
/// the single definition shared by every driver, the CLI, benches and
/// tests. (The PJRT-dispatching SparseLU driver builds a closure
/// table instead; it must capture the backend.)
pub static LU_RUST_KERNELS: [BlockKernel<'static>; 4] =
    [&rk_lu0, &rk_fwd, &rk_bdiv, &rk_bmod];

/// SparseLU table with the update kernel routed through the
/// microkernel layer, bit-identical mode. The recurrence kernels
/// (`lu0`, `fwd`, `bdiv`) stay on their scalar reference by design.
pub static LU_MK_KERNELS: [BlockKernel<'static>; 4] =
    [&rk_lu0, &rk_fwd, &rk_bdiv, &rk_bmod_mk];

/// SparseLU table in fast (residual-bounded) mode.
pub static LU_MK_FAST_KERNELS: [BlockKernel<'static>; 4] =
    [&rk_lu0, &rk_fwd, &rk_bdiv, &rk_bmod_fast];

/// BOTS SparseLU with fill-in — the paper's §VI workload
/// (registry name `"sparselu"`).
pub struct Sparselu;

impl Sparselu {
    /// Fluent-session job spec for an `nb × nb` grid of `bs × bs`
    /// blocks (see [`super::session::Session`]).
    pub fn params(nb: usize, bs: usize) -> super::session::JobSpec {
        super::session::JobSpec::new(&Sparselu, nb, bs)
    }

    /// Declare the SparseLU task stream for an explicit allocation
    /// `pattern` (row-major booleans), tracking fill-in exactly like
    /// the sequential factorisation. Task order matches
    /// [`sparselu_seq`]; [`TaskGraph::sparselu`] is the assembled
    /// form.
    pub fn build_pattern(
        b: &mut GraphBuilder,
        pattern: &[bool],
        nb: usize,
    ) {
        assert_eq!(pattern.len(), nb * nb, "pattern shape");
        let mut alloc = pattern.to_vec();
        for kk in 0..nb {
            b.add_task(OP_LU0, &[], (kk, kk), false);
            for jj in kk + 1..nb {
                if alloc[kk * nb + jj] {
                    b.add_task(OP_FWD, &[(kk, kk)], (kk, jj), false);
                }
            }
            for ii in kk + 1..nb {
                if alloc[ii * nb + kk] {
                    b.add_task(OP_BDIV, &[(kk, kk)], (ii, kk), false);
                }
            }
            for ii in kk + 1..nb {
                if !alloc[ii * nb + kk] {
                    continue;
                }
                for jj in kk + 1..nb {
                    if !alloc[kk * nb + jj] {
                        continue;
                    }
                    let fill_in = !alloc[ii * nb + jj];
                    alloc[ii * nb + jj] = true;
                    b.add_task(
                        OP_BMOD,
                        &[(ii, kk), (kk, jj)],
                        (ii, jj),
                        fill_in,
                    );
                }
            }
        }
    }
}

impl Workload for Sparselu {
    fn name(&self) -> &'static str {
        "sparselu"
    }

    fn description(&self) -> &'static str {
        "BOTS sparse LU factorisation with fill-in (paper §VI)"
    }

    fn ops(&self) -> &'static [OpSpec] {
        LU_OPS
    }

    fn build(&self, b: &mut GraphBuilder, p: &Params) {
        Self::build_pattern(b, &genmat_pattern(p.nb), p.nb);
    }

    fn graph_for(&self, a: &BlockedSparseMatrix) -> TaskGraph {
        // The DAG depends on the input's sparsity pattern, not just
        // its sizing.
        TaskGraph::sparselu(&a.pattern(), a.nb())
    }

    fn kernels(&self) -> &'static [BlockKernel<'static>] {
        &LU_RUST_KERNELS
    }

    fn kernels_for(
        &self,
        mode: KernelMode,
    ) -> &'static [BlockKernel<'static>] {
        match mode {
            KernelMode::BitIdentical => &LU_MK_KERNELS,
            KernelMode::Fast => &LU_MK_FAST_KERNELS,
        }
    }

    fn make_input(&self, p: &Params, _seed: u32) -> BlockedSparseMatrix {
        genmat(p.nb, p.bs)
    }

    fn reference_seq(&self, a: &mut BlockedSparseMatrix) {
        sparselu_seq(a);
    }

    fn residual(
        &self,
        orig: &BlockedSparseMatrix,
        result: &BlockedSparseMatrix,
    ) -> f64 {
        lu_residual_sparse(&orig.to_dense(), result)
    }

    fn phases(
        &self,
        p: &Params,
    ) -> Option<Box<dyn Iterator<Item = Phase>>> {
        Some(Box::new(crate::tilesim::workload::Workload::sparselu(
            p.nb, p.bs,
        )))
    }
}

// ---------------------------------------------------------------------
// Tiled dense Cholesky
// ---------------------------------------------------------------------

fn rk_potrf(_r: &[&[f32]], w: &mut [f32], bs: usize) {
    potrf(w, bs)
}
fn rk_trsm(r: &[&[f32]], w: &mut [f32], bs: usize) {
    trsm(r[0], w, bs)
}
fn rk_syrk(r: &[&[f32]], w: &mut [f32], bs: usize) {
    syrk(r[0], w, bs)
}
fn rk_gemm(r: &[&[f32]], w: &mut [f32], bs: usize) {
    gemm_nt(r[0], r[1], w, bs)
}

/// The tiled-Cholesky kernel table, aligned with [`CHOLESKY_OPS`].
pub static CHOLESKY_RUST_KERNELS: [BlockKernel<'static>; 4] =
    [&rk_potrf, &rk_trsm, &rk_syrk, &rk_gemm];

fn rk_trsm_mk(r: &[&[f32]], w: &mut [f32], bs: usize) {
    trsm_mk(KernelMode::BitIdentical, r[0], w, bs)
}
fn rk_trsm_fast(r: &[&[f32]], w: &mut [f32], bs: usize) {
    trsm_mk(KernelMode::Fast, r[0], w, bs)
}
fn rk_syrk_mk(r: &[&[f32]], w: &mut [f32], bs: usize) {
    syrk_mk(KernelMode::BitIdentical, r[0], w, bs)
}
fn rk_syrk_fast(r: &[&[f32]], w: &mut [f32], bs: usize) {
    syrk_mk(KernelMode::Fast, r[0], w, bs)
}
fn rk_gemm_mk(r: &[&[f32]], w: &mut [f32], bs: usize) {
    gemm_nt_mk(KernelMode::BitIdentical, r[0], r[1], w, bs)
}
fn rk_gemm_fast(r: &[&[f32]], w: &mut [f32], bs: usize) {
    gemm_nt_mk(KernelMode::Fast, r[0], r[1], w, bs)
}

/// Cholesky table with the update kernels (`trsm`, `syrk`, `gemm`)
/// routed through the microkernel layer, bit-identical mode
/// (`potrf`'s square-root recurrence stays scalar).
pub static CHOLESKY_MK_KERNELS: [BlockKernel<'static>; 4] =
    [&rk_potrf, &rk_trsm_mk, &rk_syrk_mk, &rk_gemm_mk];

/// Cholesky table in fast (residual-bounded) mode.
pub static CHOLESKY_MK_FAST_KERNELS: [BlockKernel<'static>; 4] =
    [&rk_potrf, &rk_trsm_fast, &rk_syrk_fast, &rk_gemm_fast];

/// Tiled dense Cholesky, lower-triangle storage (Buttari et al.'s
/// right-looking tiled algorithm; registry name `"cholesky"`).
pub struct Cholesky;

impl Cholesky {
    /// Fluent-session job spec (see [`super::session::Session`]).
    pub fn params(nb: usize, bs: usize) -> super::session::JobSpec {
        super::session::JobSpec::new(&Cholesky, nb, bs)
    }
}

impl Workload for Cholesky {
    fn name(&self) -> &'static str {
        "cholesky"
    }

    fn description(&self) -> &'static str {
        "tiled dense Cholesky on an SPD lower-triangle block grid"
    }

    fn ops(&self) -> &'static [OpSpec] {
        CHOLESKY_OPS
    }

    fn build(&self, b: &mut GraphBuilder, p: &Params) {
        let nb = p.nb;
        for kk in 0..nb {
            b.add_task(OP_POTRF, &[], (kk, kk), false);
            for ii in kk + 1..nb {
                b.add_task(OP_TRSM, &[(kk, kk)], (ii, kk), false);
            }
            for ii in kk + 1..nb {
                b.add_task(OP_SYRK, &[(ii, kk)], (ii, ii), false);
                for jj in kk + 1..ii {
                    b.add_task(
                        OP_GEMM,
                        &[(ii, kk), (jj, kk)],
                        (ii, jj),
                        false,
                    );
                }
            }
        }
    }

    fn kernels(&self) -> &'static [BlockKernel<'static>] {
        &CHOLESKY_RUST_KERNELS
    }

    fn kernels_for(
        &self,
        mode: KernelMode,
    ) -> &'static [BlockKernel<'static>] {
        match mode {
            KernelMode::BitIdentical => &CHOLESKY_MK_KERNELS,
            KernelMode::Fast => &CHOLESKY_MK_FAST_KERNELS,
        }
    }

    fn make_input(&self, p: &Params, _seed: u32) -> BlockedSparseMatrix {
        gen_spd(p.nb, p.bs)
    }

    fn reference_seq(&self, a: &mut BlockedSparseMatrix) {
        cholesky_seq(a);
    }

    fn residual(
        &self,
        orig: &BlockedSparseMatrix,
        result: &BlockedSparseMatrix,
    ) -> f64 {
        chol_residual_sparse(&sym_dense(orig), result)
    }

    fn phases(
        &self,
        p: &Params,
    ) -> Option<Box<dyn Iterator<Item = Phase>>> {
        Some(Box::new(crate::tilesim::workload::Workload::cholesky(
            p.nb, p.bs,
        )))
    }
}

// ---------------------------------------------------------------------
// Blocked matmul
// ---------------------------------------------------------------------

/// The `madd` reference kernel now lives with its vectorised variants
/// in the microkernel layer; re-exported here so the workload module
/// remains the one-stop import for kernel tables.
pub use crate::linalg::microkernel::madd;

fn rk_madd(r: &[&[f32]], w: &mut [f32], bs: usize) {
    madd(r[0], r[1], w, bs)
}
fn rk_madd_mk(r: &[&[f32]], w: &mut [f32], bs: usize) {
    madd_mk(KernelMode::BitIdentical, r[0], r[1], w, bs)
}
fn rk_madd_fast(r: &[&[f32]], w: &mut [f32], bs: usize) {
    madd_mk(KernelMode::Fast, r[0], r[1], w, bs)
}

/// The blocked-matmul kernel table, aligned with [`MATMUL_OPS`].
pub static MATMUL_RUST_KERNELS: [BlockKernel<'static>; 1] = [&rk_madd];

/// Matmul table routed through the microkernel layer, bit-identical
/// mode.
pub static MATMUL_MK_KERNELS: [BlockKernel<'static>; 1] = [&rk_madd_mk];

/// Matmul table in fast (residual-bounded) mode.
pub static MATMUL_MK_FAST_KERNELS: [BlockKernel<'static>; 1] =
    [&rk_madd_fast];

/// Pack square `a` and `b` (each `nbc·bs` wide) plus a zeroed `C`
/// into the `2·nbc`-grid blocked matrix [`TaskGraph::matmul`]
/// schedules over: `C` in the top-left quadrant, `A` top-right
/// (`A[i,k]` at block `(i, nbc+k)`), `B` bottom-left (`B[k,j]` at
/// `(nbc+k, j)`); the fourth quadrant stays unallocated.
pub fn matmul_blocked_input(
    a: &DenseMatrix,
    b: &DenseMatrix,
    nbc: usize,
    bs: usize,
) -> BlockedSparseMatrix {
    let dim = nbc * bs;
    assert_eq!((a.rows(), a.cols()), (dim, dim), "A shape");
    assert_eq!((b.rows(), b.cols()), (dim, dim), "B shape");
    let mut m = BlockedSparseMatrix::empty(2 * nbc, bs);
    for bi in 0..nbc {
        for bj in 0..nbc {
            m.allocate_clean_block(bi, bj); // C, zeroed
            let ab = m.allocate_clean_block(bi, nbc + bj);
            for r in 0..bs {
                for c in 0..bs {
                    ab[r * bs + c] = a[(bi * bs + r, bj * bs + c)];
                }
            }
            let bb = m.allocate_clean_block(nbc + bi, bj);
            for r in 0..bs {
                for c in 0..bs {
                    bb[r * bs + c] = b[(bi * bs + r, bj * bs + c)];
                }
            }
        }
    }
    m
}

/// Read one `nbc × nbc` quadrant of the embedded layout back out as a
/// dense matrix (`ro`/`co` are the block offsets of the quadrant).
fn extract_quadrant(
    m: &BlockedSparseMatrix,
    nbc: usize,
    ro: usize,
    co: usize,
) -> DenseMatrix {
    let bs = m.bs();
    let mut c = DenseMatrix::zeros(nbc * bs, nbc * bs);
    for bi in 0..nbc {
        for bj in 0..nbc {
            let blk = m.block(ro + bi, co + bj).expect("quadrant block");
            for r in 0..bs {
                for col in 0..bs {
                    c[(bi * bs + r, bj * bs + col)] = blk[r * bs + col];
                }
            }
        }
    }
    c
}

/// Read the `C` quadrant back out of the blocked layout.
pub fn matmul_extract_c(
    m: &BlockedSparseMatrix,
    nbc: usize,
) -> DenseMatrix {
    extract_quadrant(m, nbc, 0, 0)
}

/// Sequential blocked reference: the same [`madd`] kernels in the
/// graph's task order (`k` outer, then `i`, `j`) — the bit-identity
/// baseline for the dataflow matmul.
pub fn matmul_blocked_seq(
    a: &DenseMatrix,
    b: &DenseMatrix,
    nbc: usize,
    bs: usize,
) -> DenseMatrix {
    let mut m = matmul_blocked_input(a, b, nbc, bs);
    Matmul.reference_seq(&mut m);
    matmul_extract_c(&m, nbc)
}

/// Blocked dense `C = A·B`, quadrant-embedded so the access-set
/// machinery applies unchanged (registry name `"matmul"`; the paper's
/// §V workload ported onto the dataflow engine).
pub struct Matmul;

impl Matmul {
    /// Fluent-session job spec: `nb × nb` logical `C` blocks of
    /// `bs × bs` (the scheduling grid is `2·nb` wide).
    pub fn params(nb: usize, bs: usize) -> super::session::JobSpec {
        super::session::JobSpec::new(&Matmul, nb, bs)
    }
}

impl Workload for Matmul {
    fn name(&self) -> &'static str {
        "matmul"
    }

    fn description(&self) -> &'static str {
        "blocked dense C = A·B, quadrant-embedded (paper §V workload \
         on the dataflow engine)"
    }

    fn ops(&self) -> &'static [OpSpec] {
        MATMUL_OPS
    }

    fn grid(&self, p: &Params) -> usize {
        2 * p.nb
    }

    fn build(&self, b: &mut GraphBuilder, p: &Params) {
        let nbc = p.nb;
        assert!(nbc > 0);
        for kk in 0..nbc {
            for ii in 0..nbc {
                for jj in 0..nbc {
                    b.add_task(
                        OP_MADD,
                        &[(ii, nbc + kk), (nbc + kk, jj)],
                        (ii, jj),
                        false,
                    );
                }
            }
        }
    }

    fn graph_for(&self, a: &BlockedSparseMatrix) -> TaskGraph {
        // The embedded grid is twice the logical C grid.
        assert_eq!(a.nb() % 2, 0, "embedded matmul grid must be even");
        self.graph(&Params::new(a.nb() / 2, a.bs()))
    }

    fn kernels(&self) -> &'static [BlockKernel<'static>] {
        &MATMUL_RUST_KERNELS
    }

    fn kernels_for(
        &self,
        mode: KernelMode,
    ) -> &'static [BlockKernel<'static>] {
        match mode {
            KernelMode::BitIdentical => &MATMUL_MK_KERNELS,
            KernelMode::Fast => &MATMUL_MK_FAST_KERNELS,
        }
    }

    fn make_input(&self, p: &Params, seed: u32) -> BlockedSparseMatrix {
        let dim = p.nb * p.bs;
        let a = DenseMatrix::bots_random(
            dim,
            dim,
            41u32.wrapping_add(seed.wrapping_mul(2)),
        );
        let b = DenseMatrix::bots_random(
            dim,
            dim,
            42u32.wrapping_add(seed.wrapping_mul(2)),
        );
        matmul_blocked_input(&a, &b, p.nb, p.bs)
    }

    fn reference_seq(&self, a: &mut BlockedSparseMatrix) {
        let nbc = a.nb() / 2;
        let bs = a.bs();
        for kk in 0..nbc {
            for ii in 0..nbc {
                for jj in 0..nbc {
                    let (ra, rb, w) = a
                        .read2_write1(
                            (ii, nbc + kk),
                            (nbc + kk, jj),
                            (ii, jj),
                        )
                        .unwrap();
                    madd(ra, rb, w, bs);
                }
            }
        }
    }

    fn residual(
        &self,
        orig: &BlockedSparseMatrix,
        result: &BlockedSparseMatrix,
    ) -> f64 {
        let nbc = orig.nb() / 2;
        let a = extract_quadrant(orig, nbc, 0, nbc);
        let b = extract_quadrant(orig, nbc, nbc, 0);
        let want = a.matmul(&b);
        let got = matmul_extract_c(result, nbc);
        let scale = want
            .as_slice()
            .iter()
            .fold(0f32, |m, &v| m.max(v.abs()))
            .max(1e-30);
        f64::from(got.max_abs_diff(&want)) / f64::from(scale)
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// The inventory of registered workloads, in canonical order. This is
/// the single list the CLI (`--app`, `--list-apps`, the `mixed`
/// stream), the harness experiments, the benches and the conformance
/// suite iterate — adding a workload here is the *only* registration
/// step.
static REGISTRY: [&dyn Workload; 3] = [&Sparselu, &Cholesky, &Matmul];

/// Every registered workload, in canonical order.
pub fn registry() -> &'static [&'static dyn Workload] {
    &REGISTRY
}

/// Look a workload up by its registry name.
pub fn find(name: &str) -> Option<&'static dyn Workload> {
    registry().iter().copied().find(|w| w.name() == name)
}

/// The registered names, in canonical order (CLI help / diagnostics).
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|w| w.name()).collect()
}

// ---------------------------------------------------------------------
// Cached tuned block sizes (written by the startup autotuner)
// ---------------------------------------------------------------------

/// Per-registry-slot cached block size from the last autotune pass
/// (0 = untuned). A plain atomic per slot: the autotuner writes once
/// at startup, everyone else reads. Sized with headroom over the
/// current registry.
static TUNED: [AtomicUsize; 8] = [
    AtomicUsize::new(0),
    AtomicUsize::new(0),
    AtomicUsize::new(0),
    AtomicUsize::new(0),
    AtomicUsize::new(0),
    AtomicUsize::new(0),
    AtomicUsize::new(0),
    AtomicUsize::new(0),
];

fn registry_index(name: &str) -> Option<usize> {
    registry().iter().position(|w| w.name() == name)
}

/// Record the autotuner's winning block size for `w`'s registry entry.
pub fn set_tuned_bs(w: &dyn Workload, bs: usize) {
    if let Some(i) = registry_index(w.name()) {
        TUNED[i].store(bs, Ordering::Relaxed);
    }
}

/// The cached tuned block size for `w`, if an autotune pass has run
/// (see [`crate::linalg::autotune`]).
pub fn tuned_bs(w: &dyn Workload) -> Option<usize> {
    registry_index(w.name()).and_then(|i| {
        match TUNED[i].load(Ordering::Relaxed) {
            0 => None,
            bs => Some(bs),
        }
    })
}

/// Drop every cached tuned size (test isolation).
pub fn clear_tuned_bs() {
    for t in &TUNED {
        t.store(0, Ordering::Relaxed);
    }
}

/// Serialises tests that mutate the process-wide tuned-size cache
/// (they run in parallel threads within one test binary).
#[cfg(test)]
pub(crate) static TUNED_LOCK: std::sync::Mutex<()> =
    std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_resolvable() {
        let ns = names();
        assert_eq!(ns.len(), 3);
        for (i, n) in ns.iter().enumerate() {
            assert!(!ns[i + 1..].contains(n), "duplicate name {n}");
            assert_eq!(find(n).unwrap().name(), *n);
        }
        assert!(find("qr").is_none());
        assert_eq!(ns, vec!["sparselu", "cholesky", "matmul"]);
    }

    #[test]
    fn kernel_tables_cover_op_vocabularies() {
        for w in registry() {
            assert_eq!(
                w.kernels().len(),
                w.ops().len(),
                "{}: kernel table must cover the op table",
                w.name()
            );
            for mode in [KernelMode::BitIdentical, KernelMode::Fast] {
                assert_eq!(
                    w.kernels_for(mode).len(),
                    w.ops().len(),
                    "{}: {} table must cover the op table",
                    w.name(),
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn bit_identical_tables_match_the_reference_tables() {
        // The conformance default: for every workload and op, the
        // microkernel bit-identical table produces the same f32 bits
        // as the plain reference table on the same operands.
        let bs = 8usize;
        let rand = |s: u32| {
            DenseMatrix::bots_random(bs, bs, s).as_slice().to_vec()
        };
        let (a, b, c0) = (rand(61), rand(62), rand(63));
        let spd = gen_spd(1, bs).block(0, 0).unwrap().to_vec();
        let mut factor = spd.clone();
        potrf(&mut factor, bs);
        for w in registry() {
            for (op, (kref, kmk)) in w
                .kernels()
                .iter()
                .zip(w.kernels_for(KernelMode::BitIdentical))
                .enumerate()
            {
                let name = w.ops()[op].name;
                // Give each op arity-correct, domain-valid operands:
                // the solves read a triangular factor, the pivot
                // kernels factor an SPD block in place.
                let reads: Vec<&[f32]> = match name {
                    "lu0" | "potrf" => vec![],
                    "fwd" | "bdiv" | "trsm" => vec![&factor],
                    "syrk" => vec![&a],
                    _ => vec![&a, &b],
                };
                let seed = if matches!(name, "lu0" | "potrf") {
                    &spd
                } else {
                    &c0
                };
                let mut want = seed.clone();
                kref(&reads, &mut want, bs);
                let mut got = seed.clone();
                kmk(&reads, &mut got, bs);
                assert_eq!(
                    got,
                    want,
                    "{}: op {} not bit-identical",
                    w.name(),
                    name
                );
            }
        }
    }

    #[test]
    fn tuned_bs_cache_round_trips() {
        let _g = TUNED_LOCK.lock().unwrap();
        clear_tuned_bs();
        for w in registry() {
            assert_eq!(tuned_bs(*w), None, "{} starts untuned", w.name());
        }
        set_tuned_bs(&Cholesky, 16);
        assert_eq!(tuned_bs(&Cholesky), Some(16));
        assert_eq!(tuned_bs(&Sparselu), None);
        set_tuned_bs(&Cholesky, 8);
        assert_eq!(tuned_bs(&Cholesky), Some(8));
        clear_tuned_bs();
        assert_eq!(tuned_bs(&Cholesky), None);
    }

    #[test]
    fn graph_flops_sums_the_op_table() {
        let p = Params::new(5, 8);
        for w in registry() {
            let g = w.graph(&p);
            let manual: u64 = g
                .tasks()
                .iter()
                .map(|t| (w.ops()[t.op.0].flops)(p.bs))
                .sum();
            assert_eq!(w.graph_flops(&g, p.bs), manual, "{}", w.name());
            assert!(manual > 0);
        }
    }

    #[test]
    fn graphs_match_legacy_constructors() {
        let p = Params::new(8, 4);
        let lu = Sparselu.graph(&p);
        let legacy = TaskGraph::sparselu(&genmat_pattern(8), 8);
        assert_eq!(lu.len(), legacy.len());
        assert_eq!(lu.n_edges(), legacy.n_edges());
        let ch = Cholesky.graph(&p);
        assert_eq!(ch.len(), TaskGraph::cholesky(8).len());
        let mm = Matmul.graph(&p);
        assert_eq!(mm.len(), TaskGraph::matmul(8).len());
        assert_eq!(mm.nb(), 16);
    }

    #[test]
    fn graph_for_reads_the_input_pattern() {
        let a = genmat(6, 4);
        let g = Sparselu.graph_for(&a);
        assert_eq!(g.len(), TaskGraph::sparselu(&a.pattern(), 6).len());
        let m = Matmul.make_input(&Params::new(3, 4), 0);
        assert_eq!(Matmul.graph_for(&m).len(), 27);
    }

    #[test]
    fn sim_cost_reproduces_the_access_set_encoding() {
        // The default must charge exactly what the PR-2 encoder did:
        // one block for a streaming kernel, +1 per extra read stream
        // beyond the first, +1 for fill-in materialisation.
        let bs = 16usize;
        let bb = (bs * bs * 4) as u64;
        let lu0 = Task::new(OP_LU0, &[], (0, 0), false);
        assert_eq!(
            Sparselu.sim_cost(&lu0, bs),
            TaskCost { flops: (LU_OPS[0].flops)(bs), mem_bytes: bb }
        );
        let fwd = Task::new(OP_FWD, &[(0, 0)], (0, 1), false);
        assert_eq!(Sparselu.sim_cost(&fwd, bs).mem_bytes, bb);
        let bmod = Task::new(OP_BMOD, &[(1, 0), (0, 1)], (1, 1), false);
        assert_eq!(Sparselu.sim_cost(&bmod, bs).mem_bytes, 2 * bb);
        let fill = Task::new(OP_BMOD, &[(1, 0), (0, 1)], (1, 1), true);
        assert_eq!(Sparselu.sim_cost(&fill, bs).mem_bytes, 3 * bb);
        assert_eq!(
            Sparselu.flops(OP_BMOD, bs),
            (LU_OPS[OP_BMOD.0].flops)(bs)
        );
    }

    #[test]
    fn references_are_deterministic_and_verify() {
        for w in registry() {
            let p = Params::new(5, 4);
            let orig = w.make_input(&p, 0);
            let mut r1 = orig.deep_clone();
            let mut r2 = orig.deep_clone();
            w.reference_seq(&mut r1);
            w.reference_seq(&mut r2);
            w.verify_bits(&r1, &r2).unwrap();
            let res = w.residual(&orig, &r1);
            assert!(res < 1e-3, "{}: residual {res}", w.name());
        }
    }

    #[test]
    fn matmul_seed_selects_operands() {
        let p = Params::new(3, 4);
        let a = Matmul.make_input(&p, 0);
        let b = Matmul.make_input(&p, 7);
        assert_ne!(a.to_dense().as_slice(), b.to_dense().as_slice());
    }

    #[test]
    fn phases_available_exactly_for_the_factorisations() {
        let p = Params::new(6, 4);
        for w in registry() {
            let has = w.phases(&p).is_some();
            assert_eq!(has, w.name() != "matmul", "{}", w.name());
        }
        // And the stream matches the DAG's task count.
        let total: usize = Sparselu
            .phases(&p)
            .unwrap()
            .map(|ph| ph.task_count())
            .sum();
        assert_eq!(total, Sparselu.graph(&p).len());
    }
}
