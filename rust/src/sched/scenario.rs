//! Scenario engine: seeded adversarial workload streams with
//! executable invariants, replayed on the host pool *and* the
//! virtual-time simulator.
//!
//! The paper's claim is not just speed but task-management
//! **stability** — the pool must keep its contracts under any mix of
//! job sizes, submission rhythms, dependency shapes and failures, not
//! only under the uniform 8-job streams the stress tests drive. This
//! module turns that claim into a deterministic test surface:
//!
//! * A [`Scenario`] is a *named, seeded stream generator* over the
//!   [`registry`]: [`Scenario::plan`] expands `(scenario, seed)` into
//!   a [`ScenarioPlan`] — a concrete job list (sizes, workloads,
//!   per-job seeds, `submit_after` dependency edges, poisoned jobs,
//!   submission batches) plus pool sizing. Same seed, same plan,
//!   always; the PRNG is [`SplitMix64`] keyed by scenario name and
//!   seed.
//! * Each scenario declares a `reason` (why it exists — what it would
//!   catch) and the names of its machine-checked `invariants`,
//!   evaluated by [`check_invariants`] over a replay's
//!   [`ScenarioOutcome`]. Invariants only use *deterministic
//!   observables*: per-job f32 bit-identity against the workload's
//!   own sequential reference, poison containment, the pool's
//!   admission/completion event clock
//!   ([`JobHandle::admission_index`]), pending-queue bounds, and
//!   completion structure — never wall-clock or completion *timing*,
//!   which a host thread scheduler is free to vary.
//! * [`run_host`] replays a plan through the fluent [`Session`] API
//!   on a real [`Pool`], in either [`ExecMode`]: `Overlapped` (the
//!   whole stream in flight at once — cross-job stealing, capacity
//!   churn, dependency deferral all live) or `Serial` (one job at a
//!   time — the reference execution of the same stream). Every
//!   invariant must hold in both modes.
//! * [`run_sim`] replays the same plan's job stream through
//!   [`DataflowSim::run_scenario`] under both launch models (and any
//!   [`SchedModel`]); [`host_sim_agreement`] asserts host and
//!   simulator agree on the completion structure (every job drains
//!   its full graph — identical task totals on both substrates).
//!
//! Poisoned jobs need no special kernel hook: the plan submits the
//! *canonical* input with its `(0,0)` block removed
//! ([`BlockedSparseMatrix::take_block`]), so the first factorisation
//! kernel to touch the missing diagonal panics inside the worker —
//! exactly the documented poison path
//! ([`super::session::JobBuilder::canonical_input`]) — and the pool
//! must contain the failure to that one job.
//!
//! # Declaring a new scenario (the one-file recipe)
//!
//! Add one entry to [`ALL_SCENARIOS`]: a `name`, a one-line `reason`
//! to exist, the list of invariant names it must uphold (see
//! [`check_invariants`] for the vocabulary), and a `plan_fn` that
//! derives a [`ScenarioPlan`] from the provided PRNG. Everything else
//! — the conformance suite (`tests/scenarios.rs`), the `scenario`
//! harness experiment, and the CLI one-off repro
//! (`gprm exp scenario --scenario <name> --seed N`) — picks the new
//! scenario up from the slice; no other file changes.
//!
//! [`registry`]: super::workload::registry
//! [`JobHandle::admission_index`]: super::pool::JobHandle::admission_index
//! [`BlockedSparseMatrix::take_block`]: crate::linalg::blocked::BlockedSparseMatrix::take_block
//! [`DataflowSim::run_scenario`]: crate::tilesim::DataflowSim::run_scenario
//! [`SchedModel`]: crate::tilesim::SchedModel

use super::error::Error;
use super::fault::{FaultKind, FaultSet, RetryPolicy};
use super::pool::{JobHandle, Pool, PoolConfig, SubmitError};
use super::session::{JobSpec, Session};
use super::workload::{registry, Params, Workload};
use crate::linalg::blocked::BlockedSparseMatrix;
use crate::tilesim::{DataflowSim, LaunchModel, SchedModel};
use crate::util::prng::SplitMix64;

// --- the plan: what a (scenario, seed) pair expands to ------------------

/// One planned job of a scenario stream, in submission order.
pub struct JobPlan {
    pub workload: &'static dyn Workload,
    pub nb: usize,
    pub bs: usize,
    /// Input-generator seed (only matmul's generator consults it).
    pub seed: u32,
    /// Indices of earlier jobs this one is submitted `after`
    /// (admission deferred until they complete; ordering-only).
    pub deps: Vec<usize>,
    /// Submit the canonical input with its `(0,0)` block removed: the
    /// first kernel touching the missing diagonal panics and poisons
    /// exactly this job.
    pub poison: bool,
    /// Oversized job meant to run long while small jobs race past it.
    pub straggler: bool,
    /// Submission batch; [`BatchPacing`] says what happens between
    /// batches in an `Overlapped` replay.
    pub batch: usize,
    /// Inject this fault into the job's kernel dispatch
    /// ([`super::fault::FaultSet`]); coordinates come from
    /// `fault_task`.
    pub fault: Option<FaultKind>,
    /// Raw fault coordinate, wrapped onto the job's graph
    /// (`fault_task % tasks`) by the runner.
    pub fault_task: usize,
    /// Retry policy the session applies when this job poisons.
    pub retry: Option<RetryPolicy>,
    /// Completed-task-count deadline ([`JobBuilder::deadline`]).
    ///
    /// [`JobBuilder::deadline`]: super::session::JobBuilder::deadline
    pub deadline: Option<usize>,
    /// Cancel the job (via its [`super::pool::CancelToken`])
    /// immediately after submission.
    pub cancel: bool,
}

impl JobPlan {
    pub fn params(&self) -> Params {
        Params::new(self.nb, self.bs)
    }
}

/// Pool task-budget sizing relative to the stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapacityPlan {
    /// Budget fits the whole stream at once.
    FullStream,
    /// Budget is half the stream's task total (never below the
    /// largest single graph): admission must run in FIFO waves.
    HalfStream,
}

/// What an `Overlapped` replay does at a batch boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPacing {
    /// Nothing: batches are a labelling only.
    Immediate,
    /// Sleep briefly so the workers can reach the deep-idle park
    /// between bursts.
    Gap,
    /// Wait for every prior handle: the next batch hits a drained
    /// pool (fresh-wave semantics).
    Drain,
}

/// A fully-expanded scenario: pool sizing plus the job stream.
pub struct ScenarioPlan {
    pub workers: usize,
    pub capacity: CapacityPlan,
    pub pacing: BatchPacing,
    /// Overload shed bound ([`PoolConfig::max_pending`]).
    pub max_pending: Option<usize>,
    /// Call [`Pool::drain`] before submitting the job at this index:
    /// everything accepted earlier completes, everything at or after
    /// it is rejected with [`SubmitError::Draining`].
    pub drain_after: Option<usize>,
    pub jobs: Vec<JobPlan>,
}

// --- the registry of scenarios ------------------------------------------

/// A named, seeded adversarial stream with machine-checked
/// invariants. See the module docs for the declaration recipe.
pub struct Scenario {
    pub name: &'static str,
    /// Why this scenario exists — what failure it would catch.
    pub reason: &'static str,
    /// Names of the invariants [`check_invariants`] must uphold on
    /// every replay (each scenario declares at least two).
    pub invariants: &'static [&'static str],
    /// Crate-visible so [`super::fault::FAULT_SCENARIOS`] can build on
    /// the same machinery.
    pub(crate) plan_fn: fn(&mut SplitMix64) -> ScenarioPlan,
}

impl Scenario {
    /// Deterministically expand this scenario under `seed`: the PRNG
    /// is keyed by scenario name and seed, so plans never change
    /// between runs, platforms, or replay substrates.
    pub fn plan(&self, seed: u64) -> ScenarioPlan {
        let key = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ name_hash(self.name);
        (self.plan_fn)(&mut SplitMix64::new(key))
    }
}

/// FNV-1a, so each scenario's PRNG stream is decorrelated from its
/// siblings' even under equal seeds.
fn name_hash(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Any registry entry, uniformly.
pub(crate) fn pick(rng: &mut SplitMix64) -> &'static dyn Workload {
    let r = registry();
    r[rng.range(0, r.len())]
}

/// A factorisation entry (phase-capable: SparseLU/Cholesky at the
/// current registry) — the workloads whose root kernel writes the
/// `(0,0)` diagonal, which the poison tamper removes.
pub(crate) fn pick_factorisation(
    rng: &mut SplitMix64,
) -> &'static dyn Workload {
    let p = Params::new(4, 4);
    let f: Vec<&'static dyn Workload> = registry()
        .iter()
        .copied()
        .filter(|w| w.phases(&p).is_some())
        .collect();
    f[rng.range(0, f.len())]
}

pub(crate) fn job(
    rng: &mut SplitMix64,
    workload: &'static dyn Workload,
    nb: usize,
    bs: usize,
) -> JobPlan {
    JobPlan {
        workload,
        nb,
        bs,
        seed: rng.next_below(1 << 30) as u32,
        deps: Vec::new(),
        poison: false,
        straggler: false,
        batch: 0,
        fault: None,
        fault_task: 0,
        retry: None,
        deadline: None,
        cancel: false,
    }
}

fn plan_mixed_sizes(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let jobs = (0..8)
        .map(|i| {
            let nb = if i % 2 == 0 {
                rng.range(2, 4)
            } else {
                rng.range(8, 12)
            };
            let w = pick(rng);
            job(rng, w, nb, bs)
        })
        .collect();
    ScenarioPlan {
        workers: rng.range(2, 9),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_bursty(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let jobs = (0..9)
        .map(|i| {
            let w = pick(rng);
            let mut j = job(rng, w, rng.range(3, 7), bs);
            j.batch = i / 3;
            j
        })
        .collect();
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Gap,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_fan_out_fan_in(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let fan = rng.range(3, 6);
    let root = pick(rng);
    let mut jobs = vec![job(rng, root, rng.range(5, 8), bs)];
    for _ in 0..fan {
        let w = pick(rng);
        let mut j = job(rng, w, rng.range(3, 6), bs);
        j.deps = vec![0];
        jobs.push(j);
    }
    let w = pick(rng);
    let mut joiner = job(rng, w, rng.range(3, 6), bs);
    joiner.deps = (1..=fan).collect();
    jobs.push(joiner);
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_poison_mid_stream(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let bad = rng.range(2, 6);
    let jobs = (0..8)
        .map(|i| {
            let w = if i == bad {
                pick_factorisation(rng)
            } else {
                pick(rng)
            };
            let mut j = job(rng, w, rng.range(4, 8), bs);
            j.poison = i == bad;
            j
        })
        .collect();
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_capacity_churn(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    // Job 0 is big enough to dominate the half-stream budget: while
    // it runs (milliseconds), the whole tail must queue behind it —
    // deterministic pressure, not a submission-speed race.
    let head = pick_factorisation(rng);
    let mut jobs = vec![job(rng, head, 10, bs)];
    for _ in 0..9 {
        let w = pick(rng);
        jobs.push(job(rng, w, rng.range(4, 7), bs));
    }
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::HalfStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_straggler_shadow(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let big = pick_factorisation(rng);
    let mut straggler = job(rng, big, 12, bs);
    straggler.straggler = true;
    let mut jobs = vec![straggler];
    for _ in 0..7 {
        let w = pick(rng);
        jobs.push(job(rng, w, rng.range(2, 4), bs));
    }
    ScenarioPlan {
        workers: rng.range(4, 9),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Immediate,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

fn plan_fresh_wave_after_poison(rng: &mut SplitMix64) -> ScenarioPlan {
    let bs = rng.range(3, 6);
    let bad = rng.range(0, 4);
    let mut jobs: Vec<JobPlan> = (0..4)
        .map(|i| {
            let w = if i == bad {
                pick_factorisation(rng)
            } else {
                pick(rng)
            };
            let mut j = job(rng, w, rng.range(4, 7), bs);
            j.poison = i == bad;
            j
        })
        .collect();
    for _ in 0..4 {
        let w = pick(rng);
        let mut j = job(rng, w, rng.range(4, 7), bs);
        j.batch = 1;
        jobs.push(j);
    }
    ScenarioPlan {
        workers: rng.range(2, 7),
        capacity: CapacityPlan::FullStream,
        pacing: BatchPacing::Drain,
        max_pending: None,
        drain_after: None,
        jobs,
    }
}

/// Every scenario, in documentation order. Tests, the harness
/// experiment and the CLI all iterate this slice.
pub static ALL_SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "mixed-sizes",
        reason: "tiny jobs race huge ones through one team: cross-job \
                 stealing must corrupt neither extreme, and every \
                 admitted job must still complete",
        invariants: &["bit-identity", "fifo-admission", "no-starvation"],
        plan_fn: plan_mixed_sizes,
    },
    Scenario {
        name: "bursty-vs-steady",
        reason: "submission arrives in bursts separated by idle gaps: \
                 the deep-idle park/unpark handshake must not lose a \
                 wakeup between waves",
        invariants: &["no-starvation", "bit-identity", "bounded-pending"],
        plan_fn: plan_bursty,
    },
    Scenario {
        name: "fan-out-fan-in",
        reason: "one producer fans out to several dependents which fan \
                 back into a joiner via submit_after: deferred \
                 admission must respect every edge without deadlock",
        invariants: &[
            "dependency-order",
            "fifo-admission",
            "no-starvation",
            "bit-identity",
        ],
        plan_fn: plan_fan_out_fan_in,
    },
    Scenario {
        name: "poison-mid-stream",
        reason: "a panicking kernel mid-stream must poison exactly its \
                 own job: siblings keep bit-identity and the waiter \
                 gets the typed error",
        invariants: &["poison-containment", "bit-identity", "no-starvation"],
        plan_fn: plan_poison_mid_stream,
    },
    Scenario {
        name: "capacity-churn",
        reason: "a stream larger than the admission budget must queue \
                 FIFO behind the head (never drop, never deadlock) and \
                 drain in submission order as the budget recycles",
        invariants: &[
            "fifo-admission",
            "bounded-pending",
            "queued-under-pressure",
            "no-starvation",
        ],
        plan_fn: plan_capacity_churn,
    },
    Scenario {
        name: "straggler-shadow",
        reason: "one oversized straggler admitted first must not shadow \
                 the tail: with spare workers, small jobs overtake it \
                 (admission is FIFO, execution overlaps)",
        invariants: &[
            "no-starvation",
            "bit-identity",
            "overlap-completion",
            "fifo-admission",
        ],
        plan_fn: plan_straggler_shadow,
    },
    Scenario {
        name: "fresh-wave-after-poison",
        reason: "the pool must serve a clean wave after a poisoned one: \
                 slot recycling and admission state survive a failed \
                 job",
        invariants: &["poison-containment", "bit-identity", "no-starvation"],
        plan_fn: plan_fresh_wave_after_poison,
    },
];

/// Look a scenario up by name.
pub fn find(name: &str) -> Option<&'static Scenario> {
    ALL_SCENARIOS.iter().find(|s| s.name == name)
}

/// All scenario names, in registry order (CLI error messages).
pub fn names() -> Vec<&'static str> {
    ALL_SCENARIOS.iter().map(|s| s.name).collect()
}

// --- host replay ---------------------------------------------------------

/// How the host replay drives the stream through the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// The whole stream in flight at once: cross-job stealing,
    /// capacity churn, dependency deferral and batch pacing all live.
    Overlapped,
    /// One job at a time (submit, wait, next): the reference
    /// execution of the same stream, against which `Overlapped` must
    /// show no observable difference in any invariant.
    Serial,
}

/// One job's deterministic observables after a host replay.
pub struct JobOutcome {
    pub workload: &'static str,
    /// Canonical graph size — what "fully drained" means for this job
    /// on either substrate.
    pub tasks: usize,
    /// Event-clock stamps ([`JobHandle::admission_index`]), for the
    /// job's first attempt. `None` for submissions the pool rejected
    /// (shed/drain).
    pub admission: Option<usize>,
    pub completion: Option<usize>,
    /// Executed-task count, or the typed failure — from
    /// [`Session::resolve_handle`], so retry policies have run their
    /// course; rejected submissions carry their [`Error::Submit`].
    pub result: Result<usize, Error>,
    /// Attempts the session consumed (1 = no retries; 0 = the
    /// submission was rejected outright).
    pub attempts: usize,
    /// Bit-identity vs the workload's own sequential reference
    /// (`None` for poisoned, corrupted, rejected and truncated jobs —
    /// their output is partial or tampered by design).
    pub bits: Option<Result<(), String>>,
    /// For [`FaultKind::Corrupt`] jobs: did the workload's verifier
    /// catch the silent corruption?
    pub tamper_detected: Option<bool>,
}

/// Everything [`check_invariants`] looks at after a host replay.
pub struct ScenarioOutcome {
    pub scenario: &'static str,
    pub seed: u64,
    pub mode: ExecMode,
    pub workers: usize,
    pub task_capacity: usize,
    pub plan: ScenarioPlan,
    pub jobs: Vec<JobOutcome>,
    pub peak_pending: usize,
    pub final_pending: usize,
    pub final_active: usize,
}

/// Canonical input with the `(0,0)` block removed — the deterministic
/// poison tamper (see module docs).
fn tampered_input(
    w: &'static dyn Workload,
    p: &Params,
    seed: u32,
) -> BlockedSparseMatrix {
    let mut m = w.make_input(p, seed);
    let _ = m.take_block(0, 0);
    m
}

/// Replay `sc` under `seed` through the fluent [`Session`] API on a
/// fresh [`Pool`], collecting every deterministic observable. Panics
/// only on engine misuse (a plan whose submissions cannot be
/// accepted), never on job failure — poisoned jobs are data.
pub fn run_host(sc: &Scenario, seed: u64, mode: ExecMode) -> ScenarioOutcome {
    let plan = sc.plan(seed);

    // Canonical graph sizes per distinct (workload, nb, bs) — what
    // both substrates must drain per job.
    let mut sizes = Vec::new();
    let mut task_count = |j: &JobPlan| -> usize {
        let key = (j.workload.name(), j.nb, j.bs);
        if let Some((_, n)) = sizes.iter().find(|(k, _)| *k == key) {
            return *n;
        }
        let n = j.workload.graph(&j.params()).len();
        sizes.push((key, n));
        n
    };
    let counts: Vec<usize> = plan.jobs.iter().map(&mut task_count).collect();
    let total: usize = counts.iter().sum();
    let biggest: usize = counts.iter().copied().max().unwrap_or(1);
    let capacity = match plan.capacity {
        CapacityPlan::FullStream => total.max(1),
        CapacityPlan::HalfStream => (total / 2).max(biggest),
    };

    let pool = Pool::with_config(PoolConfig {
        workers: plan.workers,
        task_capacity: capacity,
        max_jobs: 64,
        max_pending: plan.max_pending,
    });
    let mut session = Session::new(&pool);
    // A rejected submission (overload shed, drain) is a first-class
    // observable, not engine misuse — keep the typed error per slot.
    let mut handles: Vec<Result<JobHandle, Error>> =
        Vec::with_capacity(plan.jobs.len());
    for (i, j) in plan.jobs.iter().enumerate() {
        if mode == ExecMode::Overlapped
            && i > 0
            && plan.jobs[i - 1].batch != j.batch
        {
            match plan.pacing {
                BatchPacing::Immediate => {}
                BatchPacing::Gap => std::thread::sleep(
                    std::time::Duration::from_millis(2),
                ),
                BatchPacing::Drain => {
                    for h in handles.iter().flatten() {
                        let _ = h.wait();
                    }
                }
            }
        }
        if plan.drain_after == Some(i) {
            pool.drain();
        }
        let spec = JobSpec::new(j.workload, j.nb, j.bs);
        let mut b = session.job(spec);
        b = if j.poison {
            b.canonical_input(tampered_input(j.workload, &spec.params, j.seed))
        } else {
            b.seed(j.seed)
        };
        for &d in &j.deps {
            if let Ok(h) = &handles[d] {
                b = b.after(h);
            }
        }
        if let Some(kind) = j.fault {
            b = b.inject(FaultSet::single(j.fault_task, kind));
        }
        if let Some(pol) = j.retry {
            b = b.retry(pol);
        }
        if let Some(d) = j.deadline {
            b = b.deadline(d);
        }
        let h = b.submit();
        if let Ok(h) = &h {
            if j.cancel {
                h.cancel_token().cancel();
            }
            if mode == ExecMode::Serial {
                let _ = session.resolve_handle(h);
            }
        }
        handles.push(h);
    }

    let mut jobs: Vec<JobOutcome> = plan
        .jobs
        .iter()
        .zip(&handles)
        .zip(&counts)
        .map(|((j, h), &tasks)| match h {
            Ok(h) => {
                let result =
                    session.resolve_handle(h).map(|s| s.executed);
                JobOutcome {
                    workload: j.workload.name(),
                    tasks,
                    admission: h.admission_index(),
                    completion: h.completion_index(),
                    result,
                    attempts: session.attempts(h).unwrap_or(1),
                    bits: None,
                    tamper_detected: None,
                }
            }
            Err(e) => JobOutcome {
                workload: j.workload.name(),
                tasks,
                admission: None,
                completion: None,
                result: Err(e.clone()),
                attempts: 0,
                bits: None,
                tamper_detected: None,
            },
        })
        .collect();

    // All jobs done: the queue must already be empty, and the peak is
    // final.
    let final_pending = pool.pending_jobs();
    let peak_pending = pool.peak_pending();

    // Take every output through the typed API and verify bit-identity
    // against per-(workload, sizing, seed) sequential references.
    // Poisoned, rejected and truncated jobs have partial output by
    // design; corrupted jobs are checked for tamper *detection*
    // instead of identity.
    let mut refs = Vec::new();
    for (i, j) in plan.jobs.iter().enumerate() {
        let h = match &handles[i] {
            Ok(h) => h,
            Err(_) => continue,
        };
        let out = session
            .take_output(h)
            .expect("the session tracks every accepted scenario job");
        if j.poison || jobs[i].result.is_err() {
            continue;
        }
        let key = (j.workload.name(), j.nb, j.bs, j.seed);
        if !refs.iter().any(|(k, _)| *k == key) {
            let mut want = j.workload.make_input(&j.params(), j.seed);
            j.workload.reference_seq(&mut want);
            refs.push((key, want));
        }
        let want = &refs.iter().find(|(k, _)| *k == key).unwrap().1;
        let check = j.workload.verify_bits(&out, want);
        if let Some(FaultKind::Corrupt { .. }) = j.fault {
            jobs[i].tamper_detected = Some(check.is_err());
        } else {
            jobs[i].bits = Some(check);
        }
    }
    drop(session);
    let final_active = pool.active_jobs();
    let (workers, task_capacity) = (pool.workers(), pool.task_capacity());
    pool.shutdown();

    ScenarioOutcome {
        scenario: sc.name,
        seed,
        mode,
        workers,
        task_capacity,
        plan,
        jobs,
        peak_pending,
        final_pending,
        final_active,
    }
}

// --- invariants ----------------------------------------------------------

/// One invariant's verdict over a replay.
#[derive(Clone, Debug)]
pub struct InvariantResult {
    pub invariant: &'static str,
    pub pass: bool,
    pub detail: String,
}

impl InvariantResult {
    fn ok(invariant: &'static str, detail: String) -> Self {
        Self { invariant, pass: true, detail }
    }

    fn violated(invariant: &'static str, detail: String) -> Self {
        Self {
            invariant,
            pass: false,
            detail: format!("invariant violated: {detail}"),
        }
    }
}

/// Evaluate every invariant `sc` declares against `o`. Unknown
/// invariant names fail loudly — a scenario cannot claim a check this
/// module does not implement.
pub fn check_invariants(
    sc: &Scenario,
    o: &ScenarioOutcome,
) -> Vec<InvariantResult> {
    sc.invariants.iter().map(|&inv| eval(inv, o)).collect()
}

fn eval(inv: &'static str, o: &ScenarioOutcome) -> InvariantResult {
    match inv {
        // Every non-poisoned job's output is f32 bit-identical to its
        // workload's own sequential reference.
        "bit-identity" => {
            let bad: Vec<String> = o
                .jobs
                .iter()
                .enumerate()
                .filter_map(|(i, j)| match &j.bits {
                    Some(Err(e)) => {
                        Some(format!("job {i} ({}): {e}", j.workload))
                    }
                    _ => None,
                })
                .collect();
            if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!(
                        "{} non-poisoned jobs bit-identical to their \
                         sequential references",
                        o.jobs.iter().filter(|j| j.bits.is_some()).count()
                    ),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // Exactly the planned-poison jobs fail, each with the typed
        // job error; every sibling succeeds.
        "poison-containment" => {
            let bad: Vec<String> = o
                .plan
                .jobs
                .iter()
                .zip(&o.jobs)
                .enumerate()
                .filter_map(|(i, (p, j))| match (p.poison, &j.result) {
                    (true, Err(Error::Job(_))) => None,
                    (true, r) => Some(format!(
                        "poisoned job {i} did not fail typed: {r:?}"
                    )),
                    (false, Ok(_)) => None,
                    (false, Err(e)) => {
                        Some(format!("clean job {i} failed: {e}"))
                    }
                })
                .collect();
            if bad.is_empty() {
                let n =
                    o.plan.jobs.iter().filter(|p| p.poison).count();
                InvariantResult::ok(
                    inv,
                    format!("{n} poisoned, all contained"),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // Admission stamps strictly follow submission order.
        "fifo-admission" => {
            let adm: Option<Vec<usize>> =
                o.jobs.iter().map(|j| j.admission).collect();
            match adm {
                None => InvariantResult::violated(
                    inv,
                    "a job was never admitted".into(),
                ),
                Some(v) if v.windows(2).all(|w| w[0] < w[1]) => {
                    InvariantResult::ok(
                        inv,
                        format!("admission stamps {v:?}"),
                    )
                }
                Some(v) => InvariantResult::violated(
                    inv,
                    format!(
                        "admission order differs from submission \
                         order: {v:?}"
                    ),
                ),
            }
        }
        // Every accepted job completes and (if clean) drains its
        // full graph; nothing is left pending or active. Jobs the
        // pool rejected at the door (shed/drain) have no stamps and
        // are exempt — whether the rejection was *correct* is the
        // shed/drain invariants' business.
        "no-starvation" => {
            let mut bad: Vec<String> = Vec::new();
            for (i, j) in o.jobs.iter().enumerate() {
                if matches!(j.result, Err(Error::Submit(_))) {
                    continue;
                }
                if j.completion.is_none() {
                    bad.push(format!("job {i} never completed"));
                }
                if let Ok(executed) = j.result {
                    if executed != j.tasks {
                        bad.push(format!(
                            "job {i} executed {executed} of {} tasks",
                            j.tasks
                        ));
                    }
                }
            }
            if o.final_pending != 0 {
                bad.push(format!("{} jobs left pending", o.final_pending));
            }
            if o.final_active != 0 {
                bad.push(format!("{} jobs left active", o.final_active));
            }
            if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!("all {} jobs completed", o.jobs.len()),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // The pending queue never exceeds the submitted backlog (the
        // first job of an idle pool always admits) and drains to
        // zero.
        "bounded-pending" => {
            let bound = o.jobs.len().saturating_sub(1);
            if o.peak_pending <= bound && o.final_pending == 0 {
                InvariantResult::ok(
                    inv,
                    format!("peak {} <= {bound}, drained", o.peak_pending),
                )
            } else {
                InvariantResult::violated(
                    inv,
                    format!(
                        "peak pending {} (bound {bound}), final {}",
                        o.peak_pending, o.final_pending
                    ),
                )
            }
        }
        // The capacity squeeze really queued jobs (otherwise the
        // scenario tested nothing). Serial replays never queue.
        "queued-under-pressure" => match o.mode {
            ExecMode::Serial => InvariantResult::ok(
                inv,
                "serial replay never queues (not applicable)".into(),
            ),
            ExecMode::Overlapped => {
                if o.peak_pending >= 1 {
                    InvariantResult::ok(
                        inv,
                        format!("peak pending {}", o.peak_pending),
                    )
                } else {
                    InvariantResult::violated(
                        inv,
                        "half-capacity stream never queued".into(),
                    )
                }
            }
        },
        // Every dependency edge: the predecessor's completion stamp
        // precedes the dependent's admission stamp (one event clock).
        "dependency-order" => {
            let mut bad: Vec<String> = Vec::new();
            for (i, p) in o.plan.jobs.iter().enumerate() {
                for &d in &p.deps {
                    match (o.jobs[d].completion, o.jobs[i].admission) {
                        (Some(c), Some(a)) if c < a => {}
                        (c, a) => bad.push(format!(
                            "edge {d}->{i}: completion {c:?} vs \
                             admission {a:?}"
                        )),
                    }
                }
            }
            if bad.is_empty() {
                InvariantResult::ok(inv, "every edge ordered".into())
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // With spare workers, at least one small job completes before
        // the oversized straggler (execution overlaps admission
        // order). Timing-free in serial mode, where the straggler
        // legitimately finishes first.
        "overlap-completion" => match o.mode {
            ExecMode::Serial => InvariantResult::ok(
                inv,
                "serial replay runs jobs back-to-back (not applicable)"
                    .into(),
            ),
            ExecMode::Overlapped => {
                let strag = o
                    .plan
                    .jobs
                    .iter()
                    .position(|j| j.straggler)
                    .expect("scenario declares a straggler");
                let strag_c = o.jobs[strag].completion;
                let first_small = o
                    .jobs
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != strag)
                    .filter_map(|(_, j)| j.completion)
                    .min();
                match (first_small, strag_c) {
                    (Some(s), Some(c)) if s < c => InvariantResult::ok(
                        inv,
                        format!("first small job at {s}, straggler at {c}"),
                    ),
                    (s, c) => InvariantResult::violated(
                        inv,
                        format!(
                            "no small job overtook the straggler \
                             (small {s:?}, straggler {c:?})"
                        ),
                    ),
                }
            }
        },
        // Every transient fault whose retry budget exceeds its panic
        // count heals: full drain, exactly `fails + 1` attempts, and
        // output bit-identical to the fault-free reference.
        "retry-bit-identity" => {
            let mut checked = 0usize;
            let mut bad: Vec<String> = Vec::new();
            for (i, (p, j)) in
                o.plan.jobs.iter().zip(&o.jobs).enumerate()
            {
                let fails = match p.fault {
                    Some(FaultKind::TransientPanic { fails }) => {
                        fails as usize
                    }
                    _ => continue,
                };
                if p.retry.map_or(1, |r| r.max_attempts) <= fails {
                    continue; // under-budgeted: exhausts by design
                }
                checked += 1;
                if j.result != Ok(j.tasks) {
                    bad.push(format!(
                        "job {i} did not heal: {:?}",
                        j.result
                    ));
                } else if j.attempts != fails + 1 {
                    bad.push(format!(
                        "job {i} took {} attempts, expected {}",
                        j.attempts,
                        fails + 1
                    ));
                } else if !matches!(&j.bits, Some(Ok(()))) {
                    bad.push(format!(
                        "job {i} healed but is not bit-identical: {:?}",
                        j.bits
                    ));
                }
            }
            if checked == 0 {
                InvariantResult::violated(
                    inv,
                    "plan injected no recoverable transient fault"
                        .into(),
                )
            } else if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!("{checked} transient jobs healed bit-identically"),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // Every persistent fault exhausts its whole retry budget into
        // a typed failure whose attempt history is complete and
        // 1-based.
        "retry-exhaustion" => {
            let mut checked = 0usize;
            let mut bad: Vec<String> = Vec::new();
            for (i, (p, j)) in
                o.plan.jobs.iter().zip(&o.jobs).enumerate()
            {
                if p.fault != Some(FaultKind::Panic) {
                    continue;
                }
                let budget = p.retry.map_or(1, |r| r.max_attempts);
                checked += 1;
                match &j.result {
                    Err(Error::Job(f))
                        if f.attempts.len() == budget
                            && j.attempts == budget
                            && f.attempts
                                .iter()
                                .enumerate()
                                .all(|(k, a)| a.attempt == k + 1) => {}
                    r => bad.push(format!(
                        "job {i}: expected a {budget}-attempt typed \
                         exhaustion, got {r:?} after {} attempts",
                        j.attempts
                    )),
                }
            }
            if checked == 0 {
                InvariantResult::violated(
                    inv,
                    "plan injected no persistent fault".into(),
                )
            } else if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!(
                        "{checked} persistent faults exhausted with \
                         full attempt histories"
                    ),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // The runtime cannot see a silent wrong-answer fault — the
        // job drains "cleanly" — but the workload's own bit-identity
        // verifier must catch every one.
        "corruption-detected" => {
            let mut checked = 0usize;
            let mut bad: Vec<String> = Vec::new();
            for (i, (p, j)) in
                o.plan.jobs.iter().zip(&o.jobs).enumerate()
            {
                if !matches!(p.fault, Some(FaultKind::Corrupt { .. })) {
                    continue;
                }
                checked += 1;
                if j.result != Ok(j.tasks) {
                    bad.push(format!(
                        "corrupted job {i} did not drain: {:?}",
                        j.result
                    ));
                } else if j.tamper_detected != Some(true) {
                    bad.push(format!(
                        "job {i}: silent corruption escaped the \
                         verifier"
                    ));
                }
            }
            if checked == 0 {
                InvariantResult::violated(
                    inv,
                    "plan injected no corruption".into(),
                )
            } else if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!("{checked} corruptions caught by verifiers"),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // A deadline below the graph size cancels after *exactly* its
        // budget (the started-ticket protocol is schedule-
        // independent); a generous one never truncates.
        "deadline-cancellation" => {
            let mut checked = 0usize;
            let mut bad: Vec<String> = Vec::new();
            for (i, (p, j)) in
                o.plan.jobs.iter().zip(&o.jobs).enumerate()
            {
                let d = match p.deadline {
                    Some(d) => d,
                    None => continue,
                };
                checked += 1;
                if d < j.tasks {
                    match &j.result {
                        Err(Error::Cancelled { ran }) if *ran == d => {}
                        r => bad.push(format!(
                            "job {i} (deadline {d} of {} tasks): {r:?}",
                            j.tasks
                        )),
                    }
                } else if j.result != Ok(j.tasks) {
                    bad.push(format!(
                        "job {i}: generous deadline {d} still \
                         truncated: {:?}",
                        j.result
                    ));
                }
            }
            if checked == 0 {
                InvariantResult::violated(
                    inv,
                    "plan set no deadlines".into(),
                )
            } else if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!("{checked} deadlines fired/held exactly"),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // A cancellation is final: no job that settled as cancelled
        // consumed more than its original attempt.
        "no-retry-of-cancelled" => {
            let mut cancelled = 0usize;
            let mut bad: Vec<String> = Vec::new();
            for (i, j) in o.jobs.iter().enumerate() {
                if matches!(j.result, Err(Error::Cancelled { .. })) {
                    cancelled += 1;
                    if j.attempts != 1 {
                        bad.push(format!(
                            "cancelled job {i} was attempted {} times",
                            j.attempts
                        ));
                    }
                }
            }
            if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!("{cancelled} cancellations, none retried"),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // Shedding happens at the door or not at all: every rejection
        // is the typed overload error, and every accepted job drains
        // its full graph. A serial replay (submit-wait-submit) never
        // has a backlog to shed, so the pressure requirement only
        // binds the overlapped replay.
        "shed-never-drops-admitted" => {
            let mut shed = 0usize;
            let mut bad: Vec<String> = Vec::new();
            for (i, j) in o.jobs.iter().enumerate() {
                match &j.result {
                    Ok(executed) if *executed == j.tasks => {}
                    Ok(executed) => bad.push(format!(
                        "admitted job {i} drained {executed} of {} \
                         tasks",
                        j.tasks
                    )),
                    Err(Error::Submit(SubmitError::Overloaded {
                        ..
                    })) => shed += 1,
                    Err(e) => bad.push(format!(
                        "job {i} failed with a non-shed error: {e}"
                    )),
                }
            }
            if o.mode == ExecMode::Overlapped && shed == 0 {
                bad.push(
                    "the bounded queue never shed (scenario tested \
                     nothing)"
                        .into(),
                );
            }
            if bad.is_empty() {
                InvariantResult::ok(
                    inv,
                    format!(
                        "{shed} typed sheds, every accepted job \
                         drained in full"
                    ),
                )
            } else {
                InvariantResult::violated(inv, bad.join("; "))
            }
        }
        // Everything accepted before the drain point settles (drains
        // in full, or completes as a clean cancellation); everything
        // after it is rejected with the typed drain error.
        "drain-completes-all-admitted" => match o.plan.drain_after {
            None => InvariantResult::violated(
                inv,
                "plan declares no drain point".into(),
            ),
            Some(cut) => {
                let mut bad: Vec<String> = Vec::new();
                for (i, j) in o.jobs.iter().enumerate() {
                    if i < cut {
                        match &j.result {
                            Ok(executed) if *executed == j.tasks => {}
                            Err(Error::Cancelled { .. })
                                if j.completion.is_some() => {}
                            r => bad.push(format!(
                                "admitted job {i} did not settle: \
                                 {r:?}"
                            )),
                        }
                    } else {
                        match &j.result {
                            Err(Error::Submit(
                                SubmitError::Draining,
                            )) => {}
                            r => bad.push(format!(
                                "post-drain job {i} was not rejected: \
                                 {r:?}"
                            )),
                        }
                    }
                }
                if bad.is_empty() {
                    InvariantResult::ok(
                        inv,
                        format!(
                            "{cut} admitted jobs settled, {} post-\
                             drain submissions rejected",
                            o.jobs.len() - cut
                        ),
                    )
                } else {
                    InvariantResult::violated(inv, bad.join("; "))
                }
            }
        },
        other => InvariantResult::violated(
            other,
            "unknown invariant name (see check_invariants)".into(),
        ),
    }
}

/// [`run_host`] + [`check_invariants`] in one call (tests, CLI).
pub fn run_and_check(
    sc: &Scenario,
    seed: u64,
    mode: ExecMode,
) -> (ScenarioOutcome, Vec<InvariantResult>) {
    let o = run_host(sc, seed, mode);
    let inv = check_invariants(sc, &o);
    (o, inv)
}

// --- simulator replay ----------------------------------------------------

/// Virtual-time replay of a scenario's job stream under both launch
/// models (see [`run_sim`]).
pub struct SimReplay {
    /// Tasks drained by the persistent-pool launch model.
    pub tasks: u64,
    /// Tasks drained by the one-shot-per-job launch model.
    pub oneshot_tasks: u64,
    pub pool_cycles: u64,
    pub oneshot_cycles: u64,
}

/// Replay `sc`'s stream on the virtual-time TILEPro64
/// ([`DataflowSim::run_scenario`]) under the given executor model,
/// through both launch models. Fully deterministic: equal inputs give
/// bit-equal cycle counts.
pub fn run_sim(
    sc: &Scenario,
    seed: u64,
    tiles: usize,
    sched: SchedModel,
) -> SimReplay {
    let plan = sc.plan(seed);
    let sim = DataflowSim::with_sched(tiles, sched);
    let pool = sim.run_scenario(&plan, LaunchModel::PersistentPool);
    let oneshot = sim.run_scenario(&plan, LaunchModel::OneShotPerJob);
    SimReplay {
        tasks: pool.tasks,
        oneshot_tasks: oneshot.tasks,
        pool_cycles: pool.cycles,
        oneshot_cycles: oneshot.cycles,
    }
}

/// Host and simulator agree on completion structure: every job drains
/// its full canonical graph on both substrates, so the task totals
/// match exactly (poisoned jobs drain too — their kernels are
/// skipped, not their countdown).
pub fn host_sim_agreement(
    o: &ScenarioOutcome,
    s: &SimReplay,
) -> InvariantResult {
    let host: u64 = o.jobs.iter().map(|j| j.tasks as u64).sum();
    if s.tasks == host && s.oneshot_tasks == host {
        InvariantResult::ok(
            "host-sim-agreement",
            format!("{host} tasks on both substrates"),
        )
    } else {
        InvariantResult::violated(
            "host-sim-agreement",
            format!(
                "host drains {host} tasks, sim pool {} / one-shot {}",
                s.tasks, s.oneshot_tasks
            ),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_shape_holds() {
        assert!(ALL_SCENARIOS.len() >= 6, "at least six named scenarios");
        for (i, sc) in ALL_SCENARIOS.iter().enumerate() {
            assert!(!sc.reason.is_empty(), "{}", sc.name);
            assert!(
                sc.invariants.len() >= 2,
                "{}: needs at least two invariants",
                sc.name
            );
            for later in &ALL_SCENARIOS[i + 1..] {
                assert_ne!(sc.name, later.name, "duplicate scenario");
            }
            assert_eq!(find(sc.name).unwrap().name, sc.name);
        }
        assert!(find("no-such-scenario").is_none());
        assert_eq!(names().len(), ALL_SCENARIOS.len());
    }

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        for sc in ALL_SCENARIOS {
            let (a, b) = (sc.plan(9), sc.plan(9));
            assert_eq!(a.workers, b.workers, "{}", sc.name);
            assert_eq!(a.capacity, b.capacity, "{}", sc.name);
            assert_eq!(a.pacing, b.pacing, "{}", sc.name);
            assert_eq!(a.jobs.len(), b.jobs.len(), "{}", sc.name);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.workload.name(), y.workload.name());
                assert_eq!((x.nb, x.bs, x.seed), (y.nb, y.bs, y.seed));
                assert_eq!(x.deps, y.deps);
                assert_eq!(
                    (x.poison, x.straggler, x.batch),
                    (y.poison, y.straggler, y.batch)
                );
            }
            // Different seeds must not all collapse to one stream.
            let c = sc.plan(10);
            let differs = a.jobs.len() != c.jobs.len()
                || a.workers != c.workers
                || a.jobs.iter().zip(&c.jobs).any(|(x, y)| {
                    x.nb != y.nb
                        || x.seed != y.seed
                        || x.workload.name() != y.workload.name()
                });
            assert!(differs, "{}: seed-insensitive plan", sc.name);
        }
    }

    #[test]
    fn poison_plans_poison_factorisations_only() {
        // The (0,0) tamper is only deterministic for workloads whose
        // root kernel writes the diagonal — the factorisations.
        for sc in ALL_SCENARIOS {
            for seed in [1u64, 7, 23] {
                for j in sc.plan(seed).jobs.iter().filter(|j| j.poison) {
                    assert!(
                        j.workload.phases(&j.params()).is_some(),
                        "{}: poisoned {} is not a factorisation",
                        sc.name,
                        j.workload.name()
                    );
                }
            }
        }
    }

    #[test]
    fn unknown_invariant_names_fail_loudly() {
        let sc = &ALL_SCENARIOS[0];
        let o = run_host(sc, 3, ExecMode::Serial);
        let r = eval("no-such-invariant", &o);
        assert!(!r.pass);
        assert!(r.detail.contains("unknown invariant"));
    }

    #[test]
    fn one_scenario_round_trips_host_and_sim() {
        // The full matrix lives in tests/scenarios.rs; one cheap
        // smoke here keeps the module self-verifying.
        let sc = find("poison-mid-stream").unwrap();
        let (o, inv) = run_and_check(sc, 1, ExecMode::Overlapped);
        for r in &inv {
            assert!(r.pass, "{}: {}", r.invariant, r.detail);
        }
        let s = run_sim(sc, 1, 8, SchedModel::WorkSteal);
        let agree = host_sim_agreement(&o, &s);
        assert!(agree.pass, "{}", agree.detail);
    }
}
