//! The fluent, registry-driven client of the persistent pool:
//! [`Session`] replaces the raw [`Pool::scope`] /
//! [`PoolScope::submit`](super::pool::PoolScope::submit) pairing for
//! workload jobs.
//!
//! A session owns everything a job needs — the input matrix (built
//! from the workload's declaration or supplied by the caller), the
//! task graph (cached per workload/size, so a stream of identical
//! jobs builds it once) and the erased kernel closure — and submits
//! it to a borrowed [`Pool`]. Because the session *owns* the borrows
//! and waits for every job in its destructor, the usual
//! scope-callback shape disappears; submissions read like a plan:
//!
//! ```text
//! let pool = Pool::new(8);
//! let mut s = Session::new(&pool);
//! let a = s.job(Sparselu::params(nb, bs)).submit()?;
//! let b = s.job(Cholesky::params(nb, bs)).after(&a).submit()?;
//! let stats = b.wait()?;                 // b ran strictly after a
//! let results = s.finish()?;             // outputs + stats, in order
//! ```
//!
//! `.after(&handle)` declares an **inter-job dependency**: the pool
//! defers the job's admission until the named predecessors completed
//! (see [`super::pool`] — this is a pool capability, not a client-side
//! wait), so cross-job read-after-write pipelines order themselves.
//! A handle from a different pool is rejected with
//! [`Error::CrossPoolDependency`].
//!
//! # Recovery (PR 7)
//!
//! The session is also the recovery layer:
//!
//! * [`JobBuilder::retry`] attaches a [`RetryPolicy`]. The session
//!   retains a pristine copy of the job's input; when the job is
//!   *resolved* (through [`Session::wait_all`], [`Session::finish`],
//!   [`Session::take_output`] or [`Session::resolve_handle`]) and its
//!   outcome is a poisoning, the session resubmits the cached graph
//!   over a fresh copy of that input, up to `max_attempts` total
//!   attempts, sleeping per the policy's backoff in between. A
//!   transient fault therefore recovers **bit-identically** to a
//!   clean run; a persistent fault exhausts into [`Error::Job`]
//!   carrying the full attempt history. A cancelled job
//!   ([`Error::Cancelled`]) is never retried.
//! * [`JobBuilder::deadline`] bounds the job to a completed-task
//!   count (wall-clock-free; see the pool's ticket protocol), and
//!   [`JobHandle::cancel_token`] cancels cooperatively — both drain
//!   to the typed [`Error::Cancelled`].
//! * [`JobBuilder::inject`] wraps the kernel dispatch in a
//!   [`FaultSet`] ([`super::fault`]), which is how the fault
//!   scenarios and the `faults` harness experiment make failure a
//!   deterministic, replayable input.
//!
//! Plain [`JobHandle::wait`] reports the job's **first attempt** as
//! the pool saw it; the session's resolving accessors are what apply
//! the retry policy.
//!
//! For a long-lived request stream, retire jobs as they finish:
//! [`Session::take_output`] waits for one job, hands its matrix back
//! and **frees all of the session's per-job state** (the completion
//! record and, for per-input graphs, the graph itself), so a
//! steady-state serve loop holds memory for in-flight jobs only.
//!
//! # Borrow safety
//!
//! Submitted closures reference the session-owned graph and matrix
//! allocations. The erasure to `'static` is sound for the same reason
//! [`Pool::scope`]'s is: the pool frees the closure *before*
//! releasing any waiter, and the session waits for a job (in
//! [`Session::finish`], [`Session::take_output`] or its `Drop`)
//! before that job's allocations can drop. Graphs are held behind
//! `Arc` and matrices behind `Box`, so growing or pruning the
//! session's lists never moves a live job's referents. A retry
//! resubmission replaces the job's matrix box only after the failed
//! attempt completed (completion freed its closure), so no borrow of
//! the old allocation survives the swap.
//!
//! [`JobHandle::cancel_token`]: super::pool::JobHandle::cancel_token
//! [`JobHandle::wait`]: super::pool::JobHandle::wait

use super::error::{Error, FailedAttempt, JobFailure};
use super::exec::ExecStats;
use super::fault::{faulty_kernel_runner, FaultSet, RetryPolicy};
use super::graph::TaskGraph;
use super::pool::{JobCtl, JobHandle, JobInner, Pool};
use super::workload::{kernel_runner, Params, Workload};
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use std::sync::Arc;

/// What to run: a registered workload plus its sizing. Construct via
/// the workloads' inherent helpers ([`Sparselu::params`],
/// [`Cholesky::params`], [`Matmul::params`]) or [`JobSpec::new`] for
/// a dynamic registry entry.
///
/// [`Sparselu::params`]: super::workload::Sparselu::params
/// [`Cholesky::params`]: super::workload::Cholesky::params
/// [`Matmul::params`]: super::workload::Matmul::params
#[derive(Clone, Copy)]
pub struct JobSpec {
    pub workload: &'static dyn Workload,
    pub params: Params,
}

impl JobSpec {
    pub fn new(
        workload: &'static dyn Workload,
        nb: usize,
        bs: usize,
    ) -> Self {
        Self { workload, params: Params::new(nb, bs) }
    }
}

/// One finished job's deliverables, in submission order (from
/// [`Session::finish`]).
pub struct JobResult {
    /// The registry entry that defined the job.
    pub workload: &'static dyn Workload,
    /// The transformed matrix (factorised in place / product filled).
    pub output: BlockedSparseMatrix,
    pub stats: ExecStats,
}

/// Retry state retained for one job: the policy, a pristine copy of
/// the input to rebuild attempts from, and the attempt history so
/// far (each failed attempt's coordinates, renumbered 1-based).
struct RecoveryCtx {
    policy: RetryPolicy,
    pristine: BlockedSparseMatrix,
    history: Vec<FailedAttempt>,
}

/// Session-owned state of one submitted job.
struct SessionJob {
    workload: &'static dyn Workload,
    /// Boxed so the erased closure's pointer survives list growth;
    /// consumed by [`Session::take_output`] / [`Session::finish`].
    /// Replaced (never aliased) on a retry resubmission.
    shared: Box<SharedBlocked>,
    /// Keeps the job's graph alive (shared with the canonical cache,
    /// or this job's own for per-input graphs).
    graph: Arc<TaskGraph>,
    /// The first attempt's pool-side job — the stable identity every
    /// [`JobHandle`] for this job carries, and the owner of the
    /// cancellation flag shared across attempts.
    origin: Arc<JobInner>,
    /// The latest attempt's pool-side job (== `origin` until a retry).
    inner: Arc<JobInner>,
    faults: Option<FaultSet>,
    deadline: Option<usize>,
    recovery: Option<RecoveryCtx>,
    /// Attempts submitted so far (1 = the original submission).
    attempts: usize,
    /// The post-recovery outcome, once resolved.
    resolved: Option<Result<ExecStats, Error>>,
}

/// Canonical-graph cache key: `(workload, nb, bs)`.
type GraphKey = (&'static str, usize, usize);

/// Fluent submission front end over a borrowed [`Pool`]. See the
/// module docs.
pub struct Session<'p> {
    pool: &'p Pool,
    jobs: Vec<SessionJob>,
    /// Canonical graphs only; per-input graphs are owned by their
    /// [`SessionJob`] alone (and freed when the job is taken).
    graphs: Vec<(GraphKey, Arc<TaskGraph>)>,
}

impl<'p> Session<'p> {
    pub fn new(pool: &'p Pool) -> Self {
        Self { pool, jobs: Vec::new(), graphs: Vec::new() }
    }

    /// Start describing a job. Chain [`JobBuilder::input`],
    /// [`JobBuilder::canonical_input`], [`JobBuilder::seed`],
    /// [`JobBuilder::after`], [`JobBuilder::retry`],
    /// [`JobBuilder::deadline`] and [`JobBuilder::inject`], then
    /// [`JobBuilder::submit`].
    pub fn job(&mut self, spec: JobSpec) -> JobBuilder<'_, 'p> {
        JobBuilder {
            session: self,
            spec,
            seed: 0,
            input: None,
            canonical: true,
            after: Vec::new(),
            retry: None,
            faults: None,
            deadline: None,
        }
    }

    /// Pre-build (and cache) the canonical graph for `spec`, so later
    /// submissions with canonical inputs pay no graph construction —
    /// keeps timed submission loops down to queue operations.
    pub fn prepare(&mut self, spec: JobSpec) {
        let w = spec.workload;
        let p = spec.params;
        self.canonical_graph(w, &p);
    }

    fn canonical_graph(
        &mut self,
        w: &'static dyn Workload,
        p: &Params,
    ) -> Arc<TaskGraph> {
        let key: GraphKey = (w.name(), p.nb, p.bs);
        if let Some((_, g)) = self.graphs.iter().find(|(k, _)| *k == key)
        {
            return g.clone();
        }
        let g = Arc::new(w.graph(p));
        self.graphs.push((key, g.clone()));
        g
    }

    /// Jobs currently tracked by the session (submitted and not yet
    /// taken).
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// The index of the job `h` names, matching either the original
    /// attempt (what the handle carries) or the latest retry.
    fn find(&self, h: &JobHandle) -> Option<usize> {
        self.jobs.iter().position(|j| {
            Arc::ptr_eq(&j.origin, h.inner())
                || Arc::ptr_eq(&j.inner, h.inner())
        })
    }

    /// Resolve job `idx`: wait for its current attempt and run the
    /// retry policy to completion. Idempotent (the outcome is cached).
    fn resolve_idx(&mut self, idx: usize) -> Result<ExecStats, Error> {
        if let Some(r) = &self.jobs[idx].resolved {
            return r.clone();
        }
        let pool = self.pool;
        let mut result = self.jobs[idx].inner.wait_done();
        loop {
            let job = &mut self.jobs[idx];
            // Only a poisoning is retryable: cancellations are final
            // by policy, everything else is final by nature.
            let Err(Error::Job(failure)) = &result else { break };
            let Some(rec) = &mut job.recovery else { break };
            for a in &failure.attempts {
                let mut a = a.clone();
                a.attempt = rec.history.len() + 1;
                rec.history.push(a);
            }
            if job.attempts >= rec.policy.max_attempts {
                break;
            }
            job.attempts += 1;
            if let Some(d) = rec.policy.delay_before(job.attempts) {
                std::thread::sleep(d);
            }
            // Rebuild the attempt from pristine input: same graph,
            // same faults (transient counters are shared through the
            // FaultSet), same deadline budget, same cancel flag.
            let bs = rec.pristine.bs();
            let shared =
                Box::new(SharedBlocked::new(rec.pristine.deep_clone()));
            let shared_ptr: *const SharedBlocked = &*shared;
            let graph_ptr: *const TaskGraph = &*job.graph;
            let w = job.workload;
            // SAFETY (lifetime erasure): identical to `submit`'s —
            // the allocations are owned by this SessionJob, which the
            // session keeps until the attempt completes.
            let run: Box<dyn Fn(super::graph::TaskId) + Send + Sync> = unsafe {
                match &job.faults {
                    Some(f) => Box::new(faulty_kernel_runner(
                        &*graph_ptr,
                        w.kernels(),
                        &*shared_ptr,
                        bs,
                        f.clone(),
                    )),
                    None => Box::new(kernel_runner(
                        &*graph_ptr,
                        w.kernels(),
                        &*shared_ptr,
                        bs,
                    )),
                }
            };
            let ctl = JobCtl {
                deadline: job.deadline,
                cancel: Some(job.origin.cancel_flag()),
            };
            // SAFETY: see above — the `submit_erased` borrow contract
            // is upheld by the session's resolve-before-drop ordering.
            let submitted = unsafe {
                pool.submit_erased(graph_ptr, run, Vec::new(), ctl)
            };
            match submitted {
                Ok(inner) => {
                    // The failed attempt completed (its closure was
                    // freed), so its matrix box may drop with this
                    // swap.
                    job.shared = shared;
                    job.inner = inner.clone();
                    result = inner.wait_done();
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        // On exhaustion, surface the *whole* attempt history.
        let exhausted = matches!(&result, Err(Error::Job(_)))
            && self.jobs[idx]
                .recovery
                .as_ref()
                .map_or(false, |r| !r.history.is_empty());
        let final_result = if exhausted {
            let rec = self.jobs[idx].recovery.as_ref().unwrap();
            Err(Error::Job(JobFailure {
                attempts: rec.history.clone(),
            }))
        } else {
            result
        };
        self.jobs[idx].resolved = Some(final_result.clone());
        final_result
    }

    /// Resolve every tracked job (waiting and applying retry policies)
    /// and return the per-job outcomes, in submission order. One
    /// failure never hides a sibling's success — every job is drained
    /// and reported, matching the scenario engine's per-job
    /// accounting.
    pub fn wait_all(&mut self) -> Vec<Result<ExecStats, Error>> {
        (0..self.jobs.len()).map(|i| self.resolve_idx(i)).collect()
    }

    /// Resolve the job `h` names (waiting and applying its retry
    /// policy) and return its outcome. [`Error::UnknownJob`] for a
    /// handle the session does not track.
    pub fn resolve_handle(
        &mut self,
        h: &JobHandle,
    ) -> Result<ExecStats, Error> {
        let idx = self.find(h).ok_or(Error::UnknownJob)?;
        self.resolve_idx(idx)
    }

    /// How many attempts the job `h` names has consumed so far
    /// (1 = the original submission only). `None` for an untracked
    /// handle.
    pub fn attempts(&self, h: &JobHandle) -> Option<usize> {
        self.find(h).map(|i| self.jobs[i].attempts)
    }

    /// Wait for `h`'s job (running its retry policy to completion),
    /// move its output matrix out of the session and **retire the
    /// job**: its completion record and (for per-input graphs) its
    /// graph are freed, so a long-lived session serving a stream
    /// stays bounded by its in-flight jobs. [`Error::UnknownJob`] if
    /// the handle does not belong to this session or the job was
    /// already taken — never a panic, so a server loop can treat a
    /// stale handle as a client error. A poisoned or cancelled job's
    /// (partial) matrix is still returned — the typed failure is what
    /// [`Session::resolve_handle`] reports.
    pub fn take_output(
        &mut self,
        h: &JobHandle,
    ) -> Result<BlockedSparseMatrix, Error> {
        let idx = self.find(h).ok_or(Error::UnknownJob)?;
        // Resolve first: completion frees the erased closure (for the
        // final attempt too), so no borrow of the graph or the shared
        // cell survives this point and the whole SessionJob may drop.
        let _ = self.resolve_idx(idx);
        let job = self.jobs.remove(idx);
        Ok(job.shared.into_inner())
    }

    /// Resolve everything and return each (not-yet-taken) job's
    /// output and stats, in submission order. The first job failure
    /// is propagated instead (after all jobs drained and retried).
    pub fn finish(mut self) -> Result<Vec<JobResult>, Error> {
        let outcomes = self.wait_all();
        let mut stats = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            stats.push(o?);
        }
        let mut out = Vec::with_capacity(self.jobs.len());
        for (job, stats) in self.jobs.drain(..).zip(stats) {
            out.push(JobResult {
                workload: job.workload,
                output: job.shared.into_inner(),
                stats,
            });
        }
        Ok(out)
    }
}

impl Drop for Session<'_> {
    /// The borrow-soundness backstop: every tracked job's current
    /// attempt completes (and the pool frees its erased closure)
    /// before the session's graphs and matrices drop — even on panic
    /// or early return. Unresolved retry policies are *not* run here:
    /// dropping a session abandons recovery, it never spawns work.
    fn drop(&mut self) {
        for job in &self.jobs {
            let _ = job.inner.wait_done();
        }
    }
}

/// In-flight description of one job (see [`Session::job`]).
pub struct JobBuilder<'s, 'p> {
    session: &'s mut Session<'p>,
    spec: JobSpec,
    seed: u32,
    input: Option<BlockedSparseMatrix>,
    /// The supplied input is structurally the canonical one, so the
    /// shared graph cache applies.
    canonical: bool,
    after: Vec<Arc<JobInner>>,
    retry: Option<RetryPolicy>,
    faults: Option<FaultSet>,
    deadline: Option<usize>,
}

impl JobBuilder<'_, '_> {
    /// Supply the input matrix instead of generating it from the
    /// workload's declaration. The graph is then derived from *this*
    /// matrix ([`Workload::graph_for`]) and not shared with other
    /// jobs.
    pub fn input(mut self, a: BlockedSparseMatrix) -> Self {
        self.input = Some(a);
        self.canonical = false;
        self
    }

    /// Supply a pre-built input that is structurally identical to the
    /// workload's own `make_input` output for these params (e.g. a
    /// `deep_clone` made outside a timed region): the session's
    /// shared per-`(workload, nb, bs)` graph cache is used, unlike
    /// [`Self::input`] which derives a fresh per-input graph.
    ///
    /// Sizing mismatches are rejected with a typed error at
    /// [`Self::submit`]. The structural part of the promise is the
    /// caller's contract: an input whose sparsity pattern *differs*
    /// from the canonical one either poisons the job typed
    /// ([`Error::Job`], a task names a missing block) or — for a
    /// strict superset pattern — yields a result that is not the
    /// transform of the supplied matrix (exactly as with a stale
    /// graph on the raw [`crate::apps::dataflow::run_dataflow`]
    /// path). When in doubt, use [`Self::input`].
    pub fn canonical_input(mut self, a: BlockedSparseMatrix) -> Self {
        self.input = Some(a);
        self.canonical = true;
        self
    }

    /// Seed for the workload's input generator (default 0; ignored
    /// when an input was supplied).
    pub fn seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    /// Declare an inter-job dependency: this job is not admitted
    /// until `h`'s job completed. May be chained for multiple
    /// predecessors. A handle from a different pool is rejected at
    /// [`Self::submit`] with [`Error::CrossPoolDependency`].
    pub fn after(mut self, h: &JobHandle) -> Self {
        self.after.push(h.inner().clone());
        self
    }

    /// Attach a [`RetryPolicy`]: the session retains a pristine copy
    /// of the input and resubmits on poisoning when the job is
    /// resolved (see the module docs). Cancelled jobs are never
    /// retried.
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    /// Inject deterministic faults into this job's kernel dispatch
    /// (see [`super::fault`]).
    pub fn inject(mut self, faults: FaultSet) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Bound the job to at most `tasks` executed kernels: the pool's
    /// ticket protocol runs exactly `min(tasks, graph len)` of them
    /// and a truncated job resolves to [`Error::Cancelled`] — a
    /// wall-clock-free deadline. The budget is per attempt.
    pub fn deadline(mut self, tasks: usize) -> Self {
        self.deadline = Some(tasks);
        self
    }

    /// Submit the job; returns immediately with the pool's
    /// [`JobHandle`] (capacity pressure queues; impossible jobs,
    /// shutdown, overload shed, drain, sizing mismatches and
    /// cross-pool dependencies are typed [`Error`]s).
    pub fn submit(self) -> Result<JobHandle, Error> {
        let JobBuilder {
            session,
            spec,
            seed,
            input,
            canonical,
            after,
            retry,
            faults,
            deadline,
        } = self;
        let w = spec.workload;
        let p = spec.params;
        let input = match input {
            Some(a) => a,
            None => w.make_input(&p, seed),
        };
        let graph: Arc<TaskGraph> = if canonical {
            session.canonical_graph(w, &p)
        } else {
            Arc::new(w.graph_for(&input))
        };
        // Pre-flight, mirroring `run_dataflow`'s job check: typed
        // errors instead of a poisoned job for sizing mismatches.
        if graph.nb() != input.nb() {
            return Err(Error::GridMismatch {
                graph_nb: graph.nb(),
                matrix_nb: input.nb(),
            });
        }
        if graph.ops().len() != w.kernels().len() {
            return Err(Error::KernelTable {
                ops: graph.ops().len(),
                kernels: w.kernels().len(),
            });
        }
        // A policy allowing retries needs the input retained pristine
        // to rebuild attempts from.
        let recovery = retry
            .filter(|pol| pol.max_attempts > 1)
            .map(|policy| RecoveryCtx {
                policy,
                pristine: input.deep_clone(),
                history: Vec::new(),
            });
        let graph_ptr: *const TaskGraph = &*graph;
        let bs = input.bs();
        let shared = Box::new(SharedBlocked::new(input));
        let shared_ptr: *const SharedBlocked = &*shared;
        // SAFETY (lifetime erasure): both pointers target allocations
        // owned (or co-owned via Arc) by the SessionJob pushed below,
        // and the session waits for this job's completion before that
        // entry drops (Drop / finish / take_output all wait) — the
        // `submit_erased` contract.
        let run: Box<dyn Fn(super::graph::TaskId) + Send + Sync> = unsafe {
            match &faults {
                Some(f) => Box::new(faulty_kernel_runner(
                    &*graph_ptr,
                    w.kernels(),
                    &*shared_ptr,
                    bs,
                    f.clone(),
                )),
                None => Box::new(kernel_runner(
                    &*graph_ptr,
                    w.kernels(),
                    &*shared_ptr,
                    bs,
                )),
            }
        };
        let ctl = JobCtl { deadline, cancel: None };
        let inner = unsafe {
            session.pool.submit_erased(graph_ptr, run, after, ctl)
        }?;
        session.jobs.push(SessionJob {
            workload: w,
            shared,
            graph,
            origin: inner.clone(),
            inner: inner.clone(),
            faults,
            deadline,
            recovery,
            attempts: 1,
            resolved: None,
        });
        Ok(JobHandle::from_inner(inner))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::fault::{FaultKind, RetryBackoff};
    use crate::sched::workload::{registry, Cholesky, Matmul, Sparselu};
    use crate::sched::SubmitError;

    #[test]
    fn fluent_jobs_for_every_registry_entry_verify() {
        let pool = Pool::new(4);
        let mut s = Session::new(&pool);
        let mut handles = Vec::new();
        for w in registry() {
            let h = s.job(JobSpec::new(*w, 6, 4)).submit().unwrap();
            handles.push(h);
        }
        let results = s.finish().unwrap();
        assert_eq!(results.len(), registry().len());
        for (r, w) in results.iter().zip(registry()) {
            assert_eq!(r.workload.name(), w.name());
            assert_eq!(
                r.stats.executed,
                w.graph(&Params::new(6, 4)).len()
            );
            let mut want = w.make_input(&Params::new(6, 4), 0);
            let orig = want.deep_clone();
            w.reference_seq(&mut want);
            w.verify_bits(&r.output, &want).unwrap();
            let res = w.residual(&orig, &r.output);
            assert!(res < 1e-3, "{}: residual {res}", w.name());
        }
        pool.shutdown();
    }

    #[test]
    fn inherent_param_helpers_name_their_workloads() {
        assert_eq!(Sparselu::params(4, 4).workload.name(), "sparselu");
        assert_eq!(Cholesky::params(4, 4).workload.name(), "cholesky");
        assert_eq!(Matmul::params(4, 4).workload.name(), "matmul");
    }

    #[test]
    fn after_orders_jobs_and_outputs_are_takeable() {
        let pool = Pool::new(4);
        let mut s = Session::new(&pool);
        let a = s.job(Sparselu::params(7, 4)).submit().unwrap();
        let b = s
            .job(Cholesky::params(7, 4))
            .after(&a)
            .submit()
            .unwrap();
        b.wait().unwrap();
        assert!(a.is_done(), "dependency must have completed first");
        let out_a = s.take_output(&a).unwrap();
        let mut want = Sparselu.make_input(&Params::new(7, 4), 0);
        Sparselu.reference_seq(&mut want);
        Sparselu.verify_bits(&out_a, &want).unwrap();
        assert_eq!(
            s.take_output(&a).err(),
            Some(Error::UnknownJob),
            "second take must be the typed error"
        );
        assert_eq!(s.len(), 1, "taken job is retired from the session");
        let rest = s.finish().unwrap();
        assert_eq!(rest.len(), 1, "only b's output remains");
        assert_eq!(rest[0].workload.name(), "cholesky");
        pool.shutdown();
    }

    #[test]
    fn cross_pool_after_is_typed_not_deadlocked() {
        let pool_a = Pool::new(2);
        let pool_b = Pool::new(2);
        let mut sa = Session::new(&pool_a);
        let mut sb = Session::new(&pool_b);
        let ha = sa.job(Sparselu::params(5, 4)).submit().unwrap();
        let err = sb
            .job(Sparselu::params(5, 4))
            .after(&ha)
            .submit()
            .unwrap_err();
        assert_eq!(err, Error::CrossPoolDependency);
        ha.wait().unwrap();
        drop(sb);
        drop(sa);
        pool_a.shutdown();
        pool_b.shutdown();
    }

    #[test]
    fn canonical_input_reuses_the_prepared_graph() {
        let pool = Pool::new(2);
        let mut s = Session::new(&pool);
        s.prepare(Sparselu::params(6, 4));
        assert_eq!(s.graphs.len(), 1);
        let m = Sparselu.make_input(&Params::new(6, 4), 0);
        let h = s
            .job(Sparselu::params(6, 4))
            .canonical_input(m)
            .submit()
            .unwrap();
        assert_eq!(s.graphs.len(), 1, "prepared graph must be reused");
        h.wait().unwrap();
        let out = s.take_output(&h).unwrap();
        let mut want = Sparselu.make_input(&Params::new(6, 4), 0);
        Sparselu.reference_seq(&mut want);
        Sparselu.verify_bits(&out, &want).unwrap();
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn custom_input_graphs_are_per_job_and_retired_with_it() {
        let pool = Pool::new(3);
        let mut s = Session::new(&pool);
        // Two canonical jobs share one cached graph; a custom-input
        // job owns its own (nothing enters the cache for it).
        let _h1 = s.job(Sparselu::params(6, 4)).submit().unwrap();
        let _h2 = s.job(Sparselu::params(6, 4)).submit().unwrap();
        let custom = Sparselu.make_input(&Params::new(6, 4), 0);
        let h3 = s
            .job(Sparselu::params(6, 4))
            .input(custom)
            .submit()
            .unwrap();
        assert_eq!(s.graphs.len(), 1, "custom input must not be cached");
        assert_eq!(s.len(), 3);
        let out3 = s.take_output(&h3).unwrap();
        assert_eq!(s.len(), 2, "taken job retired (graph freed with it)");
        let results = s.finish().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].output.to_dense().as_slice(),
            out3.to_dense().as_slice(),
            "custom input was the canonical input — same result"
        );
        pool.shutdown();
    }

    #[test]
    fn sizing_mismatch_is_a_typed_preflight_error() {
        let pool = Pool::new(2);
        let mut s = Session::new(&pool);
        // Canonical-input promise broken on sizing: nb=5 input under
        // an nb=6 spec must be rejected before anything runs.
        let wrong = Sparselu.make_input(&Params::new(5, 4), 0);
        let err = s
            .job(Sparselu::params(6, 4))
            .canonical_input(wrong)
            .submit()
            .unwrap_err();
        assert_eq!(err, Error::GridMismatch { graph_nb: 6, matrix_nb: 5 });
        assert!(s.is_empty());
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn session_drop_waits_without_explicit_finish() {
        let pool = Pool::new(2);
        {
            let mut s = Session::new(&pool);
            let _ = s.job(Sparselu::params(6, 4)).submit().unwrap();
            // Session dropped here: must block until the job drained
            // (borrow soundness), then release cleanly.
        }
        assert_eq!(pool.active_jobs(), 0);
        pool.shutdown();
    }

    #[test]
    fn oversized_job_is_typed_not_fatal() {
        let pool = Pool::with_config(crate::sched::PoolConfig {
            workers: 2,
            task_capacity: 8,
            max_jobs: 2,
            max_pending: None,
        });
        let mut s = Session::new(&pool);
        let err = s.job(Sparselu::params(8, 4)).submit().unwrap_err();
        assert!(matches!(
            err,
            Error::Submit(SubmitError::GraphTooLarge { .. })
        ));
        // Session still usable for jobs that fit (nb=2 → 3 tasks).
        let h = s.job(Sparselu::params(2, 4)).submit().unwrap();
        h.wait().unwrap();
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn transient_retry_heals_bit_identical() {
        // fails=2 with 4 attempts allowed: attempts 1–2 poison,
        // attempt 3 runs clean — and the healed output must be
        // bit-identical to the sequential reference, because every
        // attempt restarts from pristine input.
        let pool = Pool::new(3);
        let mut s = Session::new(&pool);
        let h = s
            .job(Cholesky::params(5, 4))
            .inject(FaultSet::single(
                3,
                FaultKind::TransientPanic { fails: 2 },
            ))
            .retry(RetryPolicy::attempts(4))
            .submit()
            .unwrap();
        let stats = s.resolve_handle(&h).unwrap();
        let g = Cholesky.graph(&Params::new(5, 4));
        assert_eq!(stats.executed, g.len());
        assert_eq!(s.attempts(&h), Some(3), "fails+1 attempts consumed");
        let out = s.take_output(&h).unwrap();
        let mut want = Cholesky.make_input(&Params::new(5, 4), 0);
        Cholesky.reference_seq(&mut want);
        Cholesky.verify_bits(&out, &want).unwrap();
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn persistent_fault_exhausts_with_attempt_history() {
        let pool = Pool::new(2);
        let mut s = Session::new(&pool);
        let h = s
            .job(Matmul::params(4, 4))
            .inject(FaultSet::single(2, FaultKind::Panic))
            .retry(
                RetryPolicy::attempts(3).with_backoff(
                    RetryBackoff::Fixed { millis: 1 },
                ),
            )
            .submit()
            .unwrap();
        let err = s.resolve_handle(&h).unwrap_err();
        let Error::Job(f) = &err else { panic!("{err:?}") };
        assert_eq!(f.attempts.len(), 3, "history covers every attempt");
        for (k, a) in f.attempts.iter().enumerate() {
            assert_eq!(a.attempt, k + 1, "renumbered 1-based");
            assert_eq!(a.task, 2);
            assert!(a.msg.contains("injected fault"), "{}", a.msg);
        }
        assert!(err.to_string().contains("after 3 attempt(s)"));
        assert_eq!(s.attempts(&h), Some(3));
        // Resolution is cached and the partial output still takeable.
        assert_eq!(s.resolve_handle(&h).unwrap_err(), err);
        let _partial = s.take_output(&h).unwrap();
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn deadline_truncates_to_typed_cancellation() {
        let pool = Pool::new(3);
        let mut s = Session::new(&pool);
        let g = Cholesky.graph(&Params::new(5, 4));
        let h = s
            .job(Cholesky::params(5, 4))
            .deadline(2)
            .submit()
            .unwrap();
        let full = s
            .job(Cholesky::params(5, 4))
            .deadline(g.len() + 7)
            .submit()
            .unwrap();
        assert_eq!(
            s.resolve_handle(&h).unwrap_err(),
            Error::Cancelled { ran: 2 }
        );
        assert_eq!(
            s.resolve_handle(&full).unwrap().executed,
            g.len(),
            "a generous deadline never truncates"
        );
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn cancelled_jobs_are_never_retried() {
        // deadline(0) cancels deterministically before any kernel ran;
        // the retry policy must not resurrect the job.
        let pool = Pool::new(2);
        let mut s = Session::new(&pool);
        let h = s
            .job(Matmul::params(4, 4))
            .deadline(0)
            .retry(RetryPolicy::attempts(5))
            .submit()
            .unwrap();
        assert_eq!(
            s.resolve_handle(&h).unwrap_err(),
            Error::Cancelled { ran: 0 }
        );
        assert_eq!(s.attempts(&h), Some(1), "cancelled ⇒ no retries");
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn wait_all_aggregates_instead_of_masking() {
        // One poisoned job in a batch of three: both siblings' stats
        // must still be reported.
        let pool = Pool::new(3);
        let mut s = Session::new(&pool);
        let _a = s.job(Cholesky::params(5, 4)).submit().unwrap();
        let _bad = s
            .job(Matmul::params(4, 4))
            .inject(FaultSet::single(0, FaultKind::Panic))
            .submit()
            .unwrap();
        let _c = s.job(Matmul::params(4, 4)).submit().unwrap();
        let outcomes = s.wait_all();
        assert_eq!(outcomes.len(), 3);
        assert!(outcomes[0].is_ok());
        assert!(matches!(outcomes[1], Err(Error::Job(_))));
        assert!(outcomes[2].is_ok());
        drop(s);
        pool.shutdown();
    }
}
