//! The fluent, registry-driven client of the persistent pool:
//! [`Session`] replaces the raw [`Pool::scope`] /
//! [`PoolScope::submit`](super::pool::PoolScope::submit) pairing for
//! workload jobs.
//!
//! A session owns everything a job needs — the input matrix (built
//! from the workload's declaration or supplied by the caller), the
//! task graph (cached per workload/size, so a stream of identical
//! jobs builds it once) and the erased kernel closure — and submits
//! it to a borrowed [`Pool`]. Because the session *owns* the borrows
//! and waits for every job in its destructor, the usual
//! scope-callback shape disappears; submissions read like a plan:
//!
//! ```text
//! let pool = Pool::new(8);
//! let mut s = Session::new(&pool);
//! let a = s.job(Sparselu::params(nb, bs)).submit()?;
//! let b = s.job(Cholesky::params(nb, bs)).after(&a).submit()?;
//! let stats = b.wait()?;                 // b ran strictly after a
//! let results = s.finish()?;             // outputs + stats, in order
//! ```
//!
//! `.after(&handle)` declares an **inter-job dependency**: the pool
//! defers the job's admission until the named predecessors completed
//! (see [`super::pool`] — this is a pool capability, not a client-side
//! wait), so cross-job read-after-write pipelines order themselves.
//! A handle from a different pool is rejected with
//! [`Error::CrossPoolDependency`].
//!
//! For a long-lived request stream, retire jobs as they finish:
//! [`Session::take_output`] waits for one job, hands its matrix back
//! and **frees all of the session's per-job state** (the completion
//! record and, for per-input graphs, the graph itself), so a
//! steady-state serve loop holds memory for in-flight jobs only.
//!
//! # Borrow safety
//!
//! Submitted closures reference the session-owned graph and matrix
//! allocations. The erasure to `'static` is sound for the same reason
//! [`Pool::scope`]'s is: the pool frees the closure *before*
//! releasing any waiter, and the session waits for a job (in
//! [`Session::finish`], [`Session::take_output`] or its `Drop`)
//! before that job's allocations can drop. Graphs are held behind
//! `Arc` and matrices behind `Box`, so growing or pruning the
//! session's lists never moves a live job's referents.

use super::error::Error;
use super::exec::ExecStats;
use super::graph::TaskGraph;
use super::pool::{JobHandle, JobInner, Pool};
use super::workload::{kernel_runner, Params, Workload};
use crate::linalg::blocked::{BlockedSparseMatrix, SharedBlocked};
use std::sync::Arc;

/// What to run: a registered workload plus its sizing. Construct via
/// the workloads' inherent helpers ([`Sparselu::params`],
/// [`Cholesky::params`], [`Matmul::params`]) or [`JobSpec::new`] for
/// a dynamic registry entry.
///
/// [`Sparselu::params`]: super::workload::Sparselu::params
/// [`Cholesky::params`]: super::workload::Cholesky::params
/// [`Matmul::params`]: super::workload::Matmul::params
#[derive(Clone, Copy)]
pub struct JobSpec {
    pub workload: &'static dyn Workload,
    pub params: Params,
}

impl JobSpec {
    pub fn new(
        workload: &'static dyn Workload,
        nb: usize,
        bs: usize,
    ) -> Self {
        Self { workload, params: Params::new(nb, bs) }
    }
}

/// One finished job's deliverables, in submission order (from
/// [`Session::finish`]).
pub struct JobResult {
    /// The registry entry that defined the job.
    pub workload: &'static dyn Workload,
    /// The transformed matrix (factorised in place / product filled).
    pub output: BlockedSparseMatrix,
    pub stats: ExecStats,
}

/// Session-owned state of one submitted job.
struct SessionJob {
    workload: &'static dyn Workload,
    /// Boxed so the erased closure's pointer survives list growth;
    /// consumed by [`Session::take_output`] / [`Session::finish`].
    shared: Box<SharedBlocked>,
    /// Keeps the job's graph alive (shared with the canonical cache,
    /// or this job's own for per-input graphs).
    graph: Arc<TaskGraph>,
    inner: Arc<JobInner>,
}

/// Canonical-graph cache key: `(workload, nb, bs)`.
type GraphKey = (&'static str, usize, usize);

/// Fluent submission front end over a borrowed [`Pool`]. See the
/// module docs.
pub struct Session<'p> {
    pool: &'p Pool,
    jobs: Vec<SessionJob>,
    /// Canonical graphs only; per-input graphs are owned by their
    /// [`SessionJob`] alone (and freed when the job is taken).
    graphs: Vec<(GraphKey, Arc<TaskGraph>)>,
}

impl<'p> Session<'p> {
    pub fn new(pool: &'p Pool) -> Self {
        Self { pool, jobs: Vec::new(), graphs: Vec::new() }
    }

    /// Start describing a job. Chain [`JobBuilder::input`],
    /// [`JobBuilder::canonical_input`], [`JobBuilder::seed`] and
    /// [`JobBuilder::after`], then [`JobBuilder::submit`].
    pub fn job(&mut self, spec: JobSpec) -> JobBuilder<'_, 'p> {
        JobBuilder {
            session: self,
            spec,
            seed: 0,
            input: None,
            canonical: true,
            after: Vec::new(),
        }
    }

    /// Pre-build (and cache) the canonical graph for `spec`, so later
    /// submissions with canonical inputs pay no graph construction —
    /// keeps timed submission loops down to queue operations.
    pub fn prepare(&mut self, spec: JobSpec) {
        let w = spec.workload;
        let p = spec.params;
        self.canonical_graph(w, &p);
    }

    fn canonical_graph(
        &mut self,
        w: &'static dyn Workload,
        p: &Params,
    ) -> Arc<TaskGraph> {
        let key: GraphKey = (w.name(), p.nb, p.bs);
        if let Some((_, g)) = self.graphs.iter().find(|(k, _)| *k == key)
        {
            return g.clone();
        }
        let g = Arc::new(w.graph(p));
        self.graphs.push((key, g.clone()));
        g
    }

    /// Jobs currently tracked by the session (submitted and not yet
    /// taken).
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Wait for every tracked job; per-job stats in submission order,
    /// or the first job failure (after all jobs drained — a poisoned
    /// job never strands its siblings' results).
    pub fn wait_all(&self) -> Result<Vec<ExecStats>, Error> {
        let results: Vec<Result<ExecStats, Error>> =
            self.jobs.iter().map(|j| j.inner.wait_done()).collect();
        results.into_iter().collect()
    }

    /// Wait for `h`'s job, move its output matrix out of the session
    /// and **retire the job**: its completion record and (for
    /// per-input graphs) its graph are freed, so a long-lived session
    /// serving a stream stays bounded by its in-flight jobs.
    /// [`Error::UnknownJob`] if the handle does not belong to this
    /// session or the job was already taken — never a panic, so a
    /// server loop can treat a stale handle as a client error. A
    /// poisoned job's (partial) matrix is still returned — the typed
    /// failure is what [`JobHandle::wait`] reports.
    pub fn take_output(
        &mut self,
        h: &JobHandle,
    ) -> Result<BlockedSparseMatrix, Error> {
        let idx = self
            .jobs
            .iter()
            .position(|j| Arc::ptr_eq(&j.inner, h.inner()))
            .ok_or(Error::UnknownJob)?;
        // Wait first: completion frees the erased closure, so no
        // borrow of the graph or the shared cell survives this point
        // and the whole SessionJob may drop.
        let _ = self.jobs[idx].inner.wait_done();
        let job = self.jobs.remove(idx);
        Ok(job.shared.into_inner())
    }

    /// Wait for everything and return each (not-yet-taken) job's
    /// output and stats, in submission order. The first job failure
    /// is propagated instead (after all jobs drained).
    pub fn finish(mut self) -> Result<Vec<JobResult>, Error> {
        let stats = self.wait_all()?;
        let mut out = Vec::with_capacity(self.jobs.len());
        for (job, stats) in self.jobs.drain(..).zip(stats) {
            out.push(JobResult {
                workload: job.workload,
                output: job.shared.into_inner(),
                stats,
            });
        }
        Ok(out)
    }
}

impl Drop for Session<'_> {
    /// The borrow-soundness backstop: every tracked job completes
    /// (and the pool frees its erased closure) before the session's
    /// graphs and matrices drop — even on panic or early return.
    fn drop(&mut self) {
        for job in &self.jobs {
            let _ = job.inner.wait_done();
        }
    }
}

/// In-flight description of one job (see [`Session::job`]).
pub struct JobBuilder<'s, 'p> {
    session: &'s mut Session<'p>,
    spec: JobSpec,
    seed: u32,
    input: Option<BlockedSparseMatrix>,
    /// The supplied input is structurally the canonical one, so the
    /// shared graph cache applies.
    canonical: bool,
    after: Vec<Arc<JobInner>>,
}

impl JobBuilder<'_, '_> {
    /// Supply the input matrix instead of generating it from the
    /// workload's declaration. The graph is then derived from *this*
    /// matrix ([`Workload::graph_for`]) and not shared with other
    /// jobs.
    pub fn input(mut self, a: BlockedSparseMatrix) -> Self {
        self.input = Some(a);
        self.canonical = false;
        self
    }

    /// Supply a pre-built input that is structurally identical to the
    /// workload's own `make_input` output for these params (e.g. a
    /// `deep_clone` made outside a timed region): the session's
    /// shared per-`(workload, nb, bs)` graph cache is used, unlike
    /// [`Self::input`] which derives a fresh per-input graph.
    ///
    /// Sizing mismatches are rejected with a typed error at
    /// [`Self::submit`]. The structural part of the promise is the
    /// caller's contract: an input whose sparsity pattern *differs*
    /// from the canonical one either poisons the job typed
    /// ([`Error::Job`], a task names a missing block) or — for a
    /// strict superset pattern — yields a result that is not the
    /// transform of the supplied matrix (exactly as with a stale
    /// graph on the raw [`crate::apps::dataflow::run_dataflow`]
    /// path). When in doubt, use [`Self::input`].
    pub fn canonical_input(mut self, a: BlockedSparseMatrix) -> Self {
        self.input = Some(a);
        self.canonical = true;
        self
    }

    /// Seed for the workload's input generator (default 0; ignored
    /// when an input was supplied).
    pub fn seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    /// Declare an inter-job dependency: this job is not admitted
    /// until `h`'s job completed. May be chained for multiple
    /// predecessors. A handle from a different pool is rejected at
    /// [`Self::submit`] with [`Error::CrossPoolDependency`].
    pub fn after(mut self, h: &JobHandle) -> Self {
        self.after.push(h.inner().clone());
        self
    }

    /// Submit the job; returns immediately with the pool's
    /// [`JobHandle`] (capacity pressure queues; impossible jobs,
    /// shutdown, sizing mismatches and cross-pool dependencies are
    /// typed [`Error`]s).
    pub fn submit(self) -> Result<JobHandle, Error> {
        let JobBuilder { session, spec, seed, input, canonical, after } =
            self;
        let w = spec.workload;
        let p = spec.params;
        let input = match input {
            Some(a) => a,
            None => w.make_input(&p, seed),
        };
        let graph: Arc<TaskGraph> = if canonical {
            session.canonical_graph(w, &p)
        } else {
            Arc::new(w.graph_for(&input))
        };
        // Pre-flight, mirroring `run_dataflow`'s job check: typed
        // errors instead of a poisoned job for sizing mismatches.
        if graph.nb() != input.nb() {
            return Err(Error::GridMismatch {
                graph_nb: graph.nb(),
                matrix_nb: input.nb(),
            });
        }
        if graph.ops().len() != w.kernels().len() {
            return Err(Error::KernelTable {
                ops: graph.ops().len(),
                kernels: w.kernels().len(),
            });
        }
        let graph_ptr: *const TaskGraph = &*graph;
        let bs = input.bs();
        let shared = Box::new(SharedBlocked::new(input));
        let shared_ptr: *const SharedBlocked = &*shared;
        // SAFETY (lifetime erasure): both pointers target allocations
        // owned (or co-owned via Arc) by the SessionJob pushed below,
        // and the session waits for this job's completion before that
        // entry drops (Drop / finish / take_output all wait) — the
        // `submit_erased` contract.
        let run: Box<dyn Fn(super::graph::TaskId) + Send + Sync> =
            unsafe {
                Box::new(kernel_runner(
                    &*graph_ptr,
                    w.kernels(),
                    &*shared_ptr,
                    bs,
                ))
            };
        let inner =
            unsafe { session.pool.submit_erased(graph_ptr, run, after) }?;
        session.jobs.push(SessionJob {
            workload: w,
            shared,
            graph,
            inner: inner.clone(),
        });
        Ok(JobHandle::from_inner(inner))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::workload::{registry, Cholesky, Matmul, Sparselu};
    use crate::sched::SubmitError;

    #[test]
    fn fluent_jobs_for_every_registry_entry_verify() {
        let pool = Pool::new(4);
        let mut s = Session::new(&pool);
        let mut handles = Vec::new();
        for w in registry() {
            let h = s.job(JobSpec::new(*w, 6, 4)).submit().unwrap();
            handles.push(h);
        }
        let results = s.finish().unwrap();
        assert_eq!(results.len(), registry().len());
        for (r, w) in results.iter().zip(registry()) {
            assert_eq!(r.workload.name(), w.name());
            assert_eq!(
                r.stats.executed,
                w.graph(&Params::new(6, 4)).len()
            );
            let mut want = w.make_input(&Params::new(6, 4), 0);
            let orig = want.deep_clone();
            w.reference_seq(&mut want);
            w.verify_bits(&r.output, &want).unwrap();
            let res = w.residual(&orig, &r.output);
            assert!(res < 1e-3, "{}: residual {res}", w.name());
        }
        pool.shutdown();
    }

    #[test]
    fn inherent_param_helpers_name_their_workloads() {
        assert_eq!(Sparselu::params(4, 4).workload.name(), "sparselu");
        assert_eq!(Cholesky::params(4, 4).workload.name(), "cholesky");
        assert_eq!(Matmul::params(4, 4).workload.name(), "matmul");
    }

    #[test]
    fn after_orders_jobs_and_outputs_are_takeable() {
        let pool = Pool::new(4);
        let mut s = Session::new(&pool);
        let a = s.job(Sparselu::params(7, 4)).submit().unwrap();
        let b = s
            .job(Cholesky::params(7, 4))
            .after(&a)
            .submit()
            .unwrap();
        b.wait().unwrap();
        assert!(a.is_done(), "dependency must have completed first");
        let out_a = s.take_output(&a).unwrap();
        let mut want = Sparselu.make_input(&Params::new(7, 4), 0);
        Sparselu.reference_seq(&mut want);
        Sparselu.verify_bits(&out_a, &want).unwrap();
        assert_eq!(
            s.take_output(&a).err(),
            Some(Error::UnknownJob),
            "second take must be the typed error"
        );
        assert_eq!(s.len(), 1, "taken job is retired from the session");
        let rest = s.finish().unwrap();
        assert_eq!(rest.len(), 1, "only b's output remains");
        assert_eq!(rest[0].workload.name(), "cholesky");
        pool.shutdown();
    }

    #[test]
    fn cross_pool_after_is_typed_not_deadlocked() {
        let pool_a = Pool::new(2);
        let pool_b = Pool::new(2);
        let mut sa = Session::new(&pool_a);
        let mut sb = Session::new(&pool_b);
        let ha = sa.job(Sparselu::params(5, 4)).submit().unwrap();
        let err = sb
            .job(Sparselu::params(5, 4))
            .after(&ha)
            .submit()
            .unwrap_err();
        assert_eq!(err, Error::CrossPoolDependency);
        ha.wait().unwrap();
        drop(sb);
        drop(sa);
        pool_a.shutdown();
        pool_b.shutdown();
    }

    #[test]
    fn canonical_input_reuses_the_prepared_graph() {
        let pool = Pool::new(2);
        let mut s = Session::new(&pool);
        s.prepare(Sparselu::params(6, 4));
        assert_eq!(s.graphs.len(), 1);
        let m = Sparselu.make_input(&Params::new(6, 4), 0);
        let h = s
            .job(Sparselu::params(6, 4))
            .canonical_input(m)
            .submit()
            .unwrap();
        assert_eq!(s.graphs.len(), 1, "prepared graph must be reused");
        h.wait().unwrap();
        let out = s.take_output(&h).unwrap();
        let mut want = Sparselu.make_input(&Params::new(6, 4), 0);
        Sparselu.reference_seq(&mut want);
        Sparselu.verify_bits(&out, &want).unwrap();
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn custom_input_graphs_are_per_job_and_retired_with_it() {
        let pool = Pool::new(3);
        let mut s = Session::new(&pool);
        // Two canonical jobs share one cached graph; a custom-input
        // job owns its own (nothing enters the cache for it).
        let _h1 = s.job(Sparselu::params(6, 4)).submit().unwrap();
        let _h2 = s.job(Sparselu::params(6, 4)).submit().unwrap();
        let custom = Sparselu.make_input(&Params::new(6, 4), 0);
        let h3 = s
            .job(Sparselu::params(6, 4))
            .input(custom)
            .submit()
            .unwrap();
        assert_eq!(s.graphs.len(), 1, "custom input must not be cached");
        assert_eq!(s.len(), 3);
        let out3 = s.take_output(&h3).unwrap();
        assert_eq!(s.len(), 2, "taken job retired (graph freed with it)");
        let results = s.finish().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].output.to_dense().as_slice(),
            out3.to_dense().as_slice(),
            "custom input was the canonical input — same result"
        );
        pool.shutdown();
    }

    #[test]
    fn sizing_mismatch_is_a_typed_preflight_error() {
        let pool = Pool::new(2);
        let mut s = Session::new(&pool);
        // Canonical-input promise broken on sizing: nb=5 input under
        // an nb=6 spec must be rejected before anything runs.
        let wrong = Sparselu.make_input(&Params::new(5, 4), 0);
        let err = s
            .job(Sparselu::params(6, 4))
            .canonical_input(wrong)
            .submit()
            .unwrap_err();
        assert_eq!(err, Error::GridMismatch { graph_nb: 6, matrix_nb: 5 });
        assert!(s.is_empty());
        drop(s);
        pool.shutdown();
    }

    #[test]
    fn session_drop_waits_without_explicit_finish() {
        let pool = Pool::new(2);
        {
            let mut s = Session::new(&pool);
            let _ = s.job(Sparselu::params(6, 4)).submit().unwrap();
            // Session dropped here: must block until the job drained
            // (borrow soundness), then release cleanly.
        }
        assert_eq!(pool.active_jobs(), 0);
        pool.shutdown();
    }

    #[test]
    fn oversized_job_is_typed_not_fatal() {
        let pool = Pool::with_config(crate::sched::PoolConfig {
            workers: 2,
            task_capacity: 8,
            max_jobs: 2,
        });
        let mut s = Session::new(&pool);
        let err = s.job(Sparselu::params(8, 4)).submit().unwrap_err();
        assert!(matches!(
            err,
            Error::Submit(SubmitError::GraphTooLarge { .. })
        ));
        // Session still usable for jobs that fit (nb=2 → 3 tasks).
        let h = s.job(Sparselu::params(2, 4)).submit().unwrap();
        h.wait().unwrap();
        drop(s);
        pool.shutdown();
    }
}
