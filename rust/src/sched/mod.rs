//! Dataflow (DAG) task scheduling — the dependency-driven alternative
//! to the paper's phase-barrier SparseLU drivers.
//!
//! The paper's Listings 5–6 separate every elimination step into
//! `lu0 → fwd/bdiv → bmod` phases with a full barrier between phases;
//! whenever a phase has fewer tasks than cores, tiles idle. Scheduling
//! block kernels by their *true data dependencies* instead (Buttari et
//! al., arXiv:0709.1272; Carratalá-Sáez et al., arXiv:1906.00874)
//! recovers that concurrency: a `bmod` may start the moment its row
//! panel, column panel and target block are final, regardless of what
//! the rest of the step is doing.
//!
//! * [`graph`] — [`graph::TaskGraph`]: a **kernel-agnostic** task DAG.
//!   Each [`graph::Task`] is an opaque op id (index into the graph's
//!   [`graph::OpSpec`] vocabulary) plus block read/write access sets;
//!   [`graph::GraphBuilder`] derives RAW/WAW/WAR edges purely from the
//!   access sets. `TaskGraph::sparselu` builds the BOTS SparseLU DAG
//!   with fill-in, `TaskGraph::cholesky` the tiled dense Cholesky DAG,
//!   both laid out in flat CSR form for the executor's atomic hot
//!   path. In-degrees and roots are precomputed and handed out as
//!   slices — nothing allocates per executor launch.
//! * [`deque`] — [`deque::StealDeque`]: a hand-rolled, fixed-capacity
//!   Chase–Lev work-stealing deque (owner-LIFO / stealer-FIFO).
//! * [`topo`] — [`topo::Topology`]: the locality layer — contiguous
//!   affinity domains over the worker team and precomputed
//!   nearest-first steal-victim orders (own domain first, then by
//!   domain distance, seeded rotation within each ring), consulted by
//!   both the one-shot executors and the persistent pool.
//! * [`exec`] — the **one-shot** executors over both host runtimes
//!   ([`exec::execute_omp_opts`], [`exec::execute_gprm_opts`]): the
//!   lock-free work-stealing executor by default, the PR-1 mutex
//!   scoreboard behind [`exec::ExecOpts`] as the measurable baseline,
//!   and an opt-in event log for schedule-validity checks.
//! * [`pool`] — the **persistent multi-job runtime**: one long-lived
//!   worker team ([`pool::Pool`]) accepting concurrent job
//!   submissions ([`pool::Pool::scope`] /
//!   [`pool::PoolScope::submit`] → [`pool::JobHandle::wait`]).
//!   Deque entries are job-tagged so workers steal across jobs;
//!   admission is FIFO under a task-capacity budget (typed
//!   [`pool::SubmitError`], never panic/drop); a panicking task
//!   poisons only its own job; shutdown is graceful. This is the
//!   service layer the one-shot executors lack: a stream of
//!   factorisation requests shares one warm team and overlaps
//!   independent DAGs. Submissions may name prior jobs as
//!   predecessors ([`pool::PoolScope::submit_after`]): admission is
//!   deferred until they complete, ordering cross-job pipelines
//!   without client-side waits.
//! * [`workload`] — the **first-class workload layer**: the
//!   [`workload::Workload`] trait bundles what used to be scattered
//!   (task stream, kernel table, input generator, sequential
//!   reference, verifier, flop pricing, simulator cost, phase straw
//!   man) and [`workload::registry`] is the single inventory the
//!   drivers, simulator, CLI, harness and benches iterate.
//! * [`session`] — the fluent submission front end:
//!   [`session::Session`] owns inputs/graphs, submits registry jobs
//!   (`.job(Sparselu::params(nb, bs)).after(&h).submit()?`) and
//!   collects outputs, replacing the raw scope/submit pairing for
//!   workload jobs.
//! * [`scenario`] — the **scenario engine**: named, seeded
//!   adversarial job streams over the registry
//!   ([`scenario::ALL_SCENARIOS`]), each declaring a reason-to-exist
//!   and machine-checked invariants, replayed on the host pool
//!   ([`scenario::run_host`]) and the virtual-time simulator
//!   ([`scenario::run_sim`]) with host/sim completion-structure
//!   agreement. The module docs carry the one-file recipe for
//!   declaring a new scenario.
//! * [`fault`] — the **fault-injection & recovery layer**: seeded,
//!   deterministic kernel misbehaviour ([`fault::FaultKind`] —
//!   panic, transient panic, straggle, silent corruption) pinned to
//!   task coordinates ([`fault::FaultSet`]), session-level retry with
//!   backoff ([`fault::RetryPolicy`]), and a second scenario registry
//!   ([`fault::FAULT_SCENARIOS`]) whose plans drive retries,
//!   deadlines, cancellation, overload shedding and drain through the
//!   same machine-checked invariant machinery.
//! * [`error`] — [`error::Error`]: the one typed failure surface of
//!   the whole stack (`Display` + `std::error::Error`, never panics
//!   on an error path), including structured per-attempt job-failure
//!   records ([`error::JobFailure`]) and typed cancellation.
//!
//! The simulator counterpart is [`crate::tilesim::sim_dataflow`]
//! (including the pool-vs-one-shot launch models); the drivers wired
//! to this scheduler are in [`crate::apps`]
//! (`sparselu_dataflow`, `cholesky_dataflow`, `matmul_dataflow` and
//! their `_batch` forms, all thin wrappers over the registry-generic
//! [`crate::apps::dataflow::run_workload`]).

pub mod deque;
pub mod error;
pub mod exec;
pub mod fault;
pub mod graph;
pub mod pool;
pub mod scenario;
pub mod session;
pub mod topo;
pub mod workload;

pub use deque::{Steal, StealDeque};
pub use error::{Error, FailedAttempt, JobFailure};
pub use fault::{FaultKind, FaultSet, RetryBackoff, RetryPolicy};
pub use exec::{
    check_event_ordering, execute_gprm, execute_gprm_opts, execute_omp,
    execute_omp_opts, Event, ExecOpts, ExecStats,
};
pub use graph::{
    GraphBuilder, OpId, OpSpec, Task, TaskGraph, TaskId, CHOLESKY_OPS,
    LU_OPS, MATMUL_OPS, OP_BDIV, OP_BMOD, OP_FWD, OP_GEMM, OP_LU0,
    OP_MADD, OP_POTRF, OP_SYRK, OP_TRSM,
};
pub use pool::{
    CancelToken, JobHandle, Pool, PoolConfig, PoolScope, SubmitError,
};
pub use session::{JobBuilder, JobResult, JobSpec, Session};
pub use topo::Topology;
pub use workload::{
    BlockKernel, Cholesky, Matmul, Params, Sparselu, TaskCost, Workload,
};
