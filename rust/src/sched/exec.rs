//! Ready-queue execution of a [`TaskGraph`] on the host runtimes.
//!
//! The executor keeps one shared scoreboard: a countdown in-degree per
//! task and a deque of ready tasks. Workers claim from the front;
//! completing a task decrements its successors and pushes the newly
//! ready ones to the *front* (depth-first — the block a worker just
//! produced is what its successor reads, so the LIFO end is the
//! cache-friendly, work-stealing-style hot path), while blocked
//! workers wake through a condvar. There are **no phase barriers**:
//! a `bmod` of step `kk` can run while `fwd` tasks of step `kk` are
//! still in flight elsewhere, which is exactly the concurrency the
//! paper's level-synchronous Listings 5–6 forfeit.
//!
//! Two backends drive the same scoreboard:
//!
//! * [`execute_omp`] — every team thread of an [`OmpRuntime`] parallel
//!   region runs the worker loop;
//! * [`execute_gprm`] — `CL` GPRM coordinator tasks (one per tile via
//!   [`GprmRuntime::par_invoke`]) each run the worker loop, mapping
//!   ready tasks onto tiles.
//!
//! Every claim and completion is recorded in an event log
//! ([`ExecStats::events`]) so tests can assert edge ordering.

use super::graph::{TaskGraph, TaskId};
use crate::coordinator::GprmRuntime;
use crate::omp::OmpRuntime;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// One scheduler event, in global scoreboard order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Task claimed by a worker (popped from the ready queue).
    Start(TaskId),
    /// Task finished; successors (possibly) released.
    End(TaskId),
}

/// Outcome of one dataflow execution.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Tasks executed (== graph size on success).
    pub executed: usize,
    /// Claim/finish log in scoreboard order.
    pub events: Vec<Event>,
    /// Largest ready-queue length observed.
    pub peak_ready: usize,
}

/// Check that `events` is a legal schedule of `graph`: each task starts
/// exactly once, ends exactly once after its start, and starts only
/// after all its predecessors ended. Used by tests and kept here so
/// every caller checks the same invariant.
pub fn check_event_ordering(graph: &TaskGraph, events: &[Event]) -> Result<(), String> {
    let n = graph.len();
    let mut started = vec![usize::MAX; n];
    let mut ended = vec![usize::MAX; n];
    for (pos, e) in events.iter().enumerate() {
        match *e {
            Event::Start(TaskId(t)) => {
                if started[t] != usize::MAX {
                    return Err(format!("task {t} started twice"));
                }
                started[t] = pos;
            }
            Event::End(TaskId(t)) => {
                if started[t] == usize::MAX {
                    return Err(format!("task {t} ended before starting"));
                }
                if ended[t] != usize::MAX {
                    return Err(format!("task {t} ended twice"));
                }
                ended[t] = pos;
            }
        }
    }
    for t in 0..n {
        if started[t] == usize::MAX || ended[t] == usize::MAX {
            return Err(format!("task {t} never ran"));
        }
        for &p in graph.preds(TaskId(t)) {
            if ended[p] == usize::MAX || ended[p] > started[t] {
                return Err(format!(
                    "task {t} started at {} before predecessor {p} ended at {}",
                    started[t], ended[p]
                ));
            }
        }
    }
    Ok(())
}

struct Scoreboard {
    ready: VecDeque<usize>,
    indegree: Vec<usize>,
    remaining: usize,
    events: Vec<Event>,
    peak_ready: usize,
    poisoned: bool,
}

/// The shared ready-queue scoreboard both backends drive.
struct Dataflow<'g> {
    graph: &'g TaskGraph,
    st: Mutex<Scoreboard>,
    cv: Condvar,
}

impl<'g> Dataflow<'g> {
    fn new(graph: &'g TaskGraph) -> Self {
        let indegree = graph.indegrees();
        let ready: VecDeque<usize> = graph.roots().into();
        let n = graph.len();
        Self {
            graph,
            st: Mutex::new(Scoreboard {
                peak_ready: ready.len(),
                ready,
                indegree,
                remaining: n,
                events: Vec::with_capacity(2 * n),
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Worker loop: claim → run → release successors, until the graph
    /// is drained (or a sibling worker poisoned the scoreboard).
    fn work(&self, run: &(dyn Fn(TaskId) + Sync)) {
        let mut st = self.st.lock().unwrap();
        loop {
            if st.remaining == 0 || st.poisoned {
                return;
            }
            let Some(t) = st.ready.pop_front() else {
                st = self.cv.wait(st).unwrap();
                continue;
            };
            st.events.push(Event::Start(TaskId(t)));
            drop(st);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run(TaskId(t))
            }));
            st = self.st.lock().unwrap();
            if let Err(e) = r {
                // Unblock every waiter, then let the runtime's own
                // panic plumbing report the failure.
                st.poisoned = true;
                self.cv.notify_all();
                drop(st);
                std::panic::resume_unwind(e);
            }
            st.events.push(Event::End(TaskId(t)));
            st.remaining -= 1;
            let mut released = 0usize;
            for &s in self.graph.succs(TaskId(t)) {
                st.indegree[s] -= 1;
                if st.indegree[s] == 0 {
                    // Depth-first: the successor reads what we just
                    // wrote; front of the deque keeps it hot.
                    st.ready.push_front(s);
                    released += 1;
                }
            }
            st.peak_ready = st.peak_ready.max(st.ready.len());
            // Only wake sleepers when there is something new for them:
            // fresh ready tasks, or the drain signal. A completion
            // that releases nothing (fan-in chains late in the
            // factorisation) would otherwise thundering-herd every
            // blocked worker through the mutex for no work.
            if released > 0 || st.remaining == 0 {
                self.cv.notify_all();
            }
        }
    }

    fn into_stats(self) -> ExecStats {
        let st = self.st.into_inner().unwrap();
        ExecStats {
            executed: self.graph.len() - st.remaining,
            events: st.events,
            peak_ready: st.peak_ready,
        }
    }
}

/// Execute `graph` on an OpenMP-style team: every team thread runs the
/// worker loop inside one parallel region. `run` receives the id of a
/// claimed task and must perform its kernel; it may be called from any
/// team thread, one task at a time per thread.
pub fn execute_omp(
    rt: &OmpRuntime,
    graph: &TaskGraph,
    run: impl Fn(TaskId) + Sync,
) -> Result<ExecStats, String> {
    let df = Dataflow::new(graph);
    let dfr = &df;
    let runr: &(dyn Fn(TaskId) + Sync) = &run;
    rt.parallel(|_ctx| dfr.work(runr))?;
    let stats = df.into_stats();
    debug_assert_eq!(stats.executed, graph.len());
    Ok(stats)
}

/// Execute `graph` on the GPRM machine: `CL` coordinator task
/// instances (one per tile, wrapping modulo the tile count) each run
/// the worker loop, pulling ready tasks onto their tile.
pub fn execute_gprm(
    rt: &GprmRuntime,
    graph: &TaskGraph,
    run: impl Fn(TaskId) + Sync,
) -> Result<ExecStats, String> {
    let df = Dataflow::new(graph);
    let dfr = &df;
    let runr: &(dyn Fn(TaskId) + Sync) = &run;
    rt.par_invoke(rt.concurrency_level(), |_ind| dfr.work(runr))?;
    let stats = df.into_stats();
    debug_assert_eq!(stats.executed, graph.len());
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat_pattern;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn lu_graph(nb: usize) -> TaskGraph {
        TaskGraph::sparselu(&genmat_pattern(nb), nb)
    }

    #[test]
    fn omp_executes_every_task_in_edge_order() {
        let rt = OmpRuntime::new(4);
        let g = lu_graph(8);
        let hits = AtomicUsize::new(0);
        let stats = execute_omp(&rt, &g, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), g.len());
        assert_eq!(stats.executed, g.len());
        check_event_ordering(&g, &stats.events).unwrap();
        rt.shutdown();
    }

    #[test]
    fn gprm_executes_every_task_in_edge_order() {
        let rt = GprmRuntime::with_tiles(6);
        let g = lu_graph(8);
        let hits = AtomicUsize::new(0);
        let stats = execute_gprm(&rt, &g, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), g.len());
        check_event_ordering(&g, &stats.events).unwrap();
        rt.shutdown();
    }

    #[test]
    fn single_worker_degenerates_to_topological_order() {
        let rt = OmpRuntime::new(1);
        let g = lu_graph(6);
        let stats = execute_omp(&rt, &g, |_| {}).unwrap();
        check_event_ordering(&g, &stats.events).unwrap();
        // One worker: events strictly alternate Start/End.
        for w in stats.events.chunks(2) {
            assert!(matches!(w[0], Event::Start(_)));
            assert!(matches!(w[1], Event::End(_)));
        }
        rt.shutdown();
    }

    #[test]
    fn more_workers_than_tasks_terminates() {
        let rt = OmpRuntime::new(16);
        let g = lu_graph(2); // 2x2: a handful of tasks
        let stats = execute_omp(&rt, &g, |_| {}).unwrap();
        assert_eq!(stats.executed, g.len());
        rt.shutdown();
    }

    #[test]
    fn panic_in_task_propagates_and_unblocks() {
        let rt = OmpRuntime::new(4);
        let g = lu_graph(8);
        let e = execute_omp(&rt, &g, |t| {
            if t.0 == 3 {
                panic!("dataflow task exploded");
            }
        })
        .unwrap_err();
        assert!(e.contains("dataflow task exploded"), "{e}");
        // Runtime survives.
        rt.parallel(|_| {}).unwrap();
        rt.shutdown();
    }

    #[test]
    fn panic_on_gprm_backend_propagates() {
        let rt = GprmRuntime::with_tiles(4);
        let g = lu_graph(6);
        let e = execute_gprm(&rt, &g, |t| {
            if t.0 == 1 {
                panic!("gprm dataflow task exploded");
            }
        })
        .unwrap_err();
        assert!(e.contains("gprm dataflow task exploded"), "{e}");
        rt.par_invoke(4, |_| {}).unwrap();
        rt.shutdown();
    }

    #[test]
    fn event_checker_rejects_bad_schedules() {
        let g = lu_graph(4);
        // Empty log: nothing ran.
        assert!(check_event_ordering(&g, &[]).is_err());
        // End before start.
        assert!(check_event_ordering(&g, &[Event::End(TaskId(0))]).is_err());
        // A dependent task starting before its predecessor ends.
        let t = (0..g.len())
            .find(|&t| !g.preds(TaskId(t)).is_empty())
            .unwrap();
        let p = g.preds(TaskId(t))[0];
        let bad = vec![
            Event::Start(TaskId(t)),
            Event::End(TaskId(t)),
            Event::Start(TaskId(p)),
            Event::End(TaskId(p)),
        ];
        assert!(check_event_ordering(&g, &bad).is_err());
    }

    #[test]
    fn peak_ready_reflects_available_parallelism() {
        let rt = OmpRuntime::new(2);
        let g = lu_graph(10);
        let stats = execute_omp(&rt, &g, |_| {}).unwrap();
        // After the first lu0, a whole fwd+bdiv front becomes ready.
        assert!(stats.peak_ready > 1, "peak {}", stats.peak_ready);
        rt.shutdown();
    }
}
