//! Execution of a [`TaskGraph`] on the host runtimes — a lock-free
//! work-stealing executor (the default), with the PR-1 single-mutex
//! scoreboard kept as the measurable baseline.
//!
//! # Work-stealing executor ([`ExecOpts::steal`] = `true`)
//!
//! The paper's headline claim is that task-*management* efficiency is
//! what separates GPRM from OpenMP at high core counts. The mutex
//! scoreboard serialised every claim, completion and event append
//! through one lock — exactly the central-queue pathology of the
//! paper's §VI. The lock-free executor removes all of it from the hot
//! path:
//!
//! * **per-worker Chase–Lev deques** ([`super::deque::StealDeque`]):
//!   the owner pushes/pops released tasks at the LIFO end (the block a
//!   worker just produced is what its successor reads — the same
//!   depth-first cache-hot policy the scoreboard's `push_front` had),
//!   thieves take the FIFO end;
//! * **atomic in-degree countdown**: completing a task decrements each
//!   successor's counter with `Release`; the worker that brings a
//!   counter to zero issues an `Acquire` fence before enqueueing the
//!   successor. Together with the deque's publish/consume edge this
//!   re-establishes, per dependency edge, the happens-before the
//!   scoreboard mutex used to provide wholesale — the contract the
//!   `SharedBlocked` `Sync` impl (`linalg/blocked.rs`) relies on;
//! * **idle protocol** spin → yield → `park_timeout`, replacing the
//!   condvar. No completion ever signals anybody: a parked worker
//!   wakes on a bounded timer and re-scans every deque, so the
//!   worst-case added latency is one park quantum, and only when the
//!   whole machine was momentarily out of ready tasks. (The mutex
//!   baseline *does* need wakeups — see the notes in
//!   [`MutexScoreboard::work`].)
//!
//! The event log is **opt-in** ([`ExecOpts::record_events`]). When
//! enabled, each worker appends to its own pre-locked buffer, tagging
//! events with a shared atomic sequence counter; buffers are stitched
//! into one causally-valid order afterwards (the counter is an RMW on
//! a single atomic, so two ordered events — a predecessor's `End`
//! before a successor's `Start` — can never observe inverted tags).
//! When disabled (the default), the hot path allocates nothing and
//! locks nothing.

use super::deque::{Steal, StealDeque};
use super::graph::{TaskGraph, TaskId};
use crate::coordinator::GprmRuntime;
use crate::omp::OmpRuntime;
use std::collections::VecDeque;

/// One worker's tagged event buffer (sequence tag, event).
type EventBuf = Vec<(u64, Event)>;
use std::sync::atomic::{
    fence, AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Condvar, Mutex};

/// One scheduler event, in (stitched) causal order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Task claimed by a worker (popped or stolen).
    Start(TaskId),
    /// Task finished; successors (possibly) released.
    End(TaskId),
}

/// Executor configuration.
#[derive(Clone, Copy, Debug)]
pub struct ExecOpts {
    /// `true` (default): the lock-free work-stealing executor.
    /// `false`: the PR-1 mutex scoreboard, kept as the comparison
    /// baseline (`benches/steal.rs` races the two).
    pub steal: bool,
    /// Record the `Start`/`End` event log. Off by default: benches and
    /// the harness run with a silent hot path; tests that audit
    /// schedules turn it on and feed [`ExecStats::events`] to
    /// [`check_event_ordering`].
    pub record_events: bool,
    /// Affinity domains for locality-aware victim selection
    /// ([`crate::sched::topo::Topology`]): the steal scan probes
    /// own-domain victims first, then outward by domain distance,
    /// seeded-rotated within each ring. `1` (default) keeps a flat
    /// team — the scan degenerates to a rotated ring over everyone.
    /// Ignored by the mutex baseline (no deques to steal from).
    pub domains: usize,
}

impl Default for ExecOpts {
    fn default() -> Self {
        Self { steal: true, record_events: false, domains: 1 }
    }
}

impl ExecOpts {
    /// The mutex-scoreboard baseline, log off.
    pub fn mutex_baseline() -> Self {
        Self { steal: false, record_events: false, domains: 1 }
    }

    /// Same executor, with the event log on.
    pub fn with_events(self) -> Self {
        Self { record_events: true, ..self }
    }

    /// Same executor, with the team split into `domains` affinity
    /// domains (clamped to the worker count at launch).
    pub fn with_domains(self, domains: usize) -> Self {
        Self { domains, ..self }
    }
}

/// Outcome of one dataflow execution.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Tasks executed (== graph size on success).
    pub executed: usize,
    /// Claim/finish log in causal order (empty unless
    /// [`ExecOpts::record_events`]).
    pub events: Vec<Event>,
    /// Largest ready-set size observed (approximate under stealing:
    /// relaxed counters, exact on the mutex baseline).
    pub peak_ready: usize,
}

/// Check that `events` is a legal schedule of `graph`: each task starts
/// exactly once, ends exactly once after its start, and starts only
/// after all its predecessors ended. Used by tests and kept here so
/// every caller checks the same invariant.
pub fn check_event_ordering(graph: &TaskGraph, events: &[Event]) -> Result<(), String> {
    let n = graph.len();
    let mut started = vec![usize::MAX; n];
    let mut ended = vec![usize::MAX; n];
    for (pos, e) in events.iter().enumerate() {
        match *e {
            Event::Start(TaskId(t)) => {
                if started[t] != usize::MAX {
                    return Err(format!("task {t} started twice"));
                }
                started[t] = pos;
            }
            Event::End(TaskId(t)) => {
                if started[t] == usize::MAX {
                    return Err(format!("task {t} ended before starting"));
                }
                if ended[t] != usize::MAX {
                    return Err(format!("task {t} ended twice"));
                }
                ended[t] = pos;
            }
        }
    }
    for t in 0..n {
        if started[t] == usize::MAX || ended[t] == usize::MAX {
            return Err(format!("task {t} never ran"));
        }
        for &p in graph.preds(TaskId(t)) {
            if ended[p] == usize::MAX || ended[p] > started[t] {
                return Err(format!(
                    "task {t} started at {} before predecessor {p} ended at {}",
                    started[t], ended[p]
                ));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Lock-free work-stealing executor
// ---------------------------------------------------------------------

/// Idle backoff: spin with exponentially more `spin_loop` hints, then
/// yield the timeslice, then park on a bounded timer. Nobody ever
/// unparks a worker — new work is discovered by re-scanning the
/// deques, and termination by the `remaining` counter — so the park
/// stage is a pure bounded nap, not a lost-wakeup hazard. (Also the
/// busy-idle protocol of the persistent pool, [`super::pool`], which
/// adds an unbounded park stage of its own for the jobless deep-idle
/// state.)
pub(crate) struct Backoff {
    fails: u32,
}

impl Backoff {
    const SPIN_LIMIT: u32 = 6;
    const YIELD_LIMIT: u32 = 16;
    const PARK_US: u64 = 50;

    pub(crate) fn new() -> Self {
        Self { fails: 0 }
    }

    pub(crate) fn reset(&mut self) {
        self.fails = 0;
    }

    pub(crate) fn idle(&mut self) {
        if self.fails < Self::SPIN_LIMIT {
            for _ in 0..(1u32 << self.fails) {
                std::hint::spin_loop();
            }
        } else if self.fails < Self::YIELD_LIMIT {
            std::thread::yield_now();
        } else {
            std::thread::park_timeout(std::time::Duration::from_micros(
                Self::PARK_US,
            ));
        }
        self.fails = self.fails.saturating_add(1);
    }
}

/// Shared state of one lock-free execution.
struct StealExec<'g> {
    graph: &'g TaskGraph,
    deques: Vec<StealDeque>,
    /// Per-task countdown to readiness. `Release` on decrement,
    /// `Acquire` fence at zero: the claim of a task happens-after
    /// every predecessor's completion (and hence its block writes).
    indegree: Vec<AtomicUsize>,
    /// Unexecuted-task count; reaching zero is the drain signal.
    remaining: AtomicUsize,
    poisoned: AtomicBool,
    /// Ready-set size / high-water mark (relaxed; stats only).
    ready_len: AtomicUsize,
    peak_ready: AtomicUsize,
    /// Event-stitching clock (see module docs).
    seq: AtomicU64,
    /// Per-worker event buffers; each worker locks only its own slot,
    /// once, for the whole run (uncontended by construction). Empty
    /// when the log is off.
    logs: Vec<Mutex<EventBuf>>,
    record: bool,
    /// Per-worker steal-victim orders (nearest affinity domain first;
    /// see [`crate::sched::topo::Topology::victim_order`]).
    victims: Vec<Vec<usize>>,
}

/// Fixed seed for the executor's victim-ring rotations: runs are
/// reproducible, and different workers still rotate differently
/// (the seed is mixed with the worker id).
const VICTIM_SEED: u64 = 0x5eed_10ca_11ce_5a1e;

impl<'g> StealExec<'g> {
    fn new(
        graph: &'g TaskGraph,
        n_workers: usize,
        record: bool,
        domains: usize,
    ) -> Self {
        let n = graph.len();
        let deques: Vec<StealDeque> =
            (0..n_workers).map(|_| StealDeque::with_capacity(n)).collect();
        let indegree: Vec<AtomicUsize> = graph
            .indegrees()
            .iter()
            .map(|&d| AtomicUsize::new(d))
            .collect();
        let roots = graph.roots();
        // Seed roots round-robin across the deques (single-threaded:
        // the runtime's region start publishes them to the workers).
        for (i, &t) in roots.iter().enumerate() {
            deques[i % n_workers].push(t);
        }
        let cap = if record { 2 * n / n_workers.max(1) + 2 } else { 0 };
        let topo = crate::sched::topo::Topology::new(n_workers, domains);
        let victims = (0..n_workers)
            .map(|w| topo.victim_order(w, VICTIM_SEED))
            .collect();
        Self {
            graph,
            deques,
            indegree,
            remaining: AtomicUsize::new(n),
            poisoned: AtomicBool::new(false),
            ready_len: AtomicUsize::new(roots.len()),
            peak_ready: AtomicUsize::new(roots.len()),
            seq: AtomicU64::new(0),
            logs: (0..n_workers)
                .map(|_| Mutex::new(Vec::with_capacity(cap)))
                .collect(),
            record,
            victims,
        }
    }

    /// Worker `w`'s loop: pop own deque (LIFO), else steal (FIFO),
    /// else back off; until the graph drains or a sibling poisons.
    fn work(&self, w: usize, run: &(dyn Fn(TaskId) + Sync)) {
        let me = &self.deques[w];
        let mut log = if self.record {
            Some(self.logs[w].lock().unwrap())
        } else {
            None
        };
        let mut backoff = Backoff::new();
        loop {
            if self.poisoned.load(Ordering::Acquire)
                || self.remaining.load(Ordering::Acquire) == 0
            {
                return;
            }
            let task = me.pop().or_else(|| self.try_steal(w));
            match task {
                Some(t) => {
                    backoff.reset();
                    self.run_one(t, me, run, log.as_deref_mut());
                }
                None => backoff.idle(),
            }
        }
    }

    /// One round of stealing: probe every other deque once in this
    /// worker's precomputed victim order — own affinity domain first,
    /// then outward by domain distance, seeded-rotated within each
    /// ring (`Abort` counts as a miss; the backoff loop retries the
    /// whole scan). With one domain this is the classic rotated ring.
    fn try_steal(&self, w: usize) -> Option<usize> {
        for &v in &self.victims[w] {
            match self.deques[v].steal() {
                Steal::Taken(t) => return Some(t),
                Steal::Empty | Steal::Abort => {}
            }
        }
        None
    }

    fn run_one(
        &self,
        t: usize,
        me: &StealDeque,
        run: &(dyn Fn(TaskId) + Sync),
        mut log: Option<&mut EventBuf>,
    ) {
        self.ready_len.fetch_sub(1, Ordering::Relaxed);
        if let Some(log) = log.as_deref_mut() {
            let s = self.seq.fetch_add(1, Ordering::Relaxed);
            log.push((s, Event::Start(TaskId(t))));
        }
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run(TaskId(t))
        }));
        if let Err(e) = r {
            // Unblock every worker, then let the host runtime's own
            // panic plumbing report the failure.
            self.poisoned.store(true, Ordering::Release);
            std::panic::resume_unwind(e);
        }
        if let Some(log) = log.as_deref_mut() {
            let s = self.seq.fetch_add(1, Ordering::Relaxed);
            log.push((s, Event::End(TaskId(t))));
        }
        let mut batch_peak = 0usize;
        for &s in self.graph.succs(TaskId(t)) {
            // Release: our block writes become visible to whichever
            // worker observes this counter reach zero.
            if self.indegree[s].fetch_sub(1, Ordering::Release) == 1 {
                // Acquire the writes of *all* predecessors (the
                // Arc::drop pattern) before publishing the task.
                fence(Ordering::Acquire);
                // Count the task ready *before* publishing it: a thief
                // may claim it (and fetch_sub ready_len) the instant
                // it lands in the deque, and the counter must never
                // dip below zero (usize would wrap). The fetch_add's
                // return value is the ready-set size at its
                // linearization point — the high-water mark tracks
                // that, not a post-batch re-read a fast thief could
                // already have drained.
                let len = self.ready_len.fetch_add(1, Ordering::Relaxed) + 1;
                batch_peak = batch_peak.max(len);
                me.push(s);
            }
        }
        if batch_peak > 0 {
            self.peak_ready.fetch_max(batch_peak, Ordering::Relaxed);
        }
        self.remaining.fetch_sub(1, Ordering::AcqRel);
    }

    fn into_stats(self) -> ExecStats {
        let executed = self.graph.len() - self.remaining.load(Ordering::Acquire);
        let mut tagged: Vec<(u64, Event)> = Vec::new();
        for slot in &self.logs {
            tagged.extend(slot.lock().unwrap().iter().copied());
        }
        tagged.sort_unstable_by_key(|&(s, _)| s);
        ExecStats {
            executed,
            events: tagged.into_iter().map(|(_, e)| e).collect(),
            peak_ready: self.peak_ready.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------
// Mutex scoreboard (PR-1 baseline)
// ---------------------------------------------------------------------

struct Scoreboard {
    ready: VecDeque<usize>,
    indegree: Vec<usize>,
    remaining: usize,
    events: Vec<Event>,
    peak_ready: usize,
    poisoned: bool,
}

/// The single-mutex ready-queue scoreboard — PR-1's executor, retained
/// behind [`ExecOpts::mutex_baseline`] so the work-stealing gain stays
/// measurable (`benches/steal.rs`, the `dataflow` experiment).
struct MutexScoreboard<'g> {
    graph: &'g TaskGraph,
    st: Mutex<Scoreboard>,
    cv: Condvar,
    record: bool,
}

impl<'g> MutexScoreboard<'g> {
    fn new(graph: &'g TaskGraph, record: bool) -> Self {
        let indegree = graph.indegrees().to_vec();
        let ready: VecDeque<usize> = graph.roots().iter().copied().collect();
        let n = graph.len();
        Self {
            graph,
            st: Mutex::new(Scoreboard {
                peak_ready: ready.len(),
                ready,
                indegree,
                remaining: n,
                events: Vec::with_capacity(if record { 2 * n } else { 0 }),
                poisoned: false,
            }),
            cv: Condvar::new(),
            record,
        }
    }

    /// Worker loop: claim → run → release successors, until the graph
    /// is drained (or a sibling worker poisoned the scoreboard).
    fn work(&self, run: &(dyn Fn(TaskId) + Sync)) {
        let mut st = self.st.lock().unwrap();
        loop {
            if st.remaining == 0 || st.poisoned {
                return;
            }
            let Some(t) = st.ready.pop_front() else {
                st = self.cv.wait(st).unwrap();
                continue;
            };
            if self.record {
                st.events.push(Event::Start(TaskId(t)));
            }
            drop(st);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run(TaskId(t))
            }));
            st = self.st.lock().unwrap();
            if let Err(e) = r {
                // Unblock every waiter, then let the runtime's own
                // panic plumbing report the failure.
                st.poisoned = true;
                self.cv.notify_all();
                drop(st);
                std::panic::resume_unwind(e);
            }
            if self.record {
                st.events.push(Event::End(TaskId(t)));
            }
            st.remaining -= 1;
            let mut released = 0usize;
            for &s in self.graph.succs(TaskId(t)) {
                st.indegree[s] -= 1;
                if st.indegree[s] == 0 {
                    // Depth-first: the successor reads what we just
                    // wrote; front of the deque keeps it hot.
                    st.ready.push_front(s);
                    released += 1;
                }
            }
            st.peak_ready = st.peak_ready.max(st.ready.len());
            if st.remaining == 0 {
                // Drain: every sleeper must observe remaining == 0
                // and exit, so this one is a broadcast.
                self.cv.notify_all();
            } else {
                // Wake exactly as many sleepers as there are new
                // tasks. `notify_all` here would thundering-herd every
                // blocked worker through the mutex to fight over
                // `released` tasks (and over zero tasks for fan-in
                // completions late in the factorisation). The
                // lock-free executor needs neither form of wakeup:
                // idle workers rediscover work by scanning deques on a
                // bounded timer, so completions never signal anyone.
                for _ in 0..released {
                    self.cv.notify_one();
                }
            }
        }
    }

    fn into_stats(self) -> ExecStats {
        let st = self.st.into_inner().unwrap();
        ExecStats {
            executed: self.graph.len() - st.remaining,
            events: st.events,
            peak_ready: st.peak_ready,
        }
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Shared executor-dispatch core: build the executor `opts` selects,
/// hand `spawn` a per-worker entry point (`spawn` must run it on every
/// worker and block until all return), then collect the stats. Both
/// runtime front ends funnel through here so the steal/mutex protocol
/// lives in one place.
fn run_with(
    graph: &TaskGraph,
    n_workers: usize,
    opts: ExecOpts,
    run: &(dyn Fn(TaskId) + Sync),
    spawn: impl FnOnce(&(dyn Fn(usize) + Sync)) -> Result<(), String>,
) -> Result<ExecStats, String> {
    let stats = if opts.steal {
        let ex = StealExec::new(
            graph,
            n_workers,
            opts.record_events,
            opts.domains,
        );
        let exr = &ex;
        spawn(&|w| exr.work(w, run))?;
        ex.into_stats()
    } else {
        let sb = MutexScoreboard::new(graph, opts.record_events);
        let sbr = &sb;
        spawn(&|_w| sbr.work(run))?;
        sb.into_stats()
    };
    debug_assert_eq!(stats.executed, graph.len());
    Ok(stats)
}

/// Execute `graph` on an OpenMP-style team with default options
/// (work-stealing, no event log). See [`execute_omp_opts`].
pub fn execute_omp(
    rt: &OmpRuntime,
    graph: &TaskGraph,
    run: impl Fn(TaskId) + Sync,
) -> Result<ExecStats, String> {
    execute_omp_opts(rt, graph, run, ExecOpts::default())
}

/// Execute `graph` on an OpenMP-style team: every team thread runs the
/// worker loop inside one parallel region. `run` receives the id of a
/// claimed task and must perform its kernel; it may be called from any
/// team thread, one task at a time per thread.
pub fn execute_omp_opts(
    rt: &OmpRuntime,
    graph: &TaskGraph,
    run: impl Fn(TaskId) + Sync,
    opts: ExecOpts,
) -> Result<ExecStats, String> {
    run_with(graph, rt.num_threads(), opts, &run, |worker| {
        rt.parallel(|ctx| worker(ctx.thread_num())).map(|_| ())
    })
}

/// Execute `graph` on the GPRM machine with default options
/// (work-stealing, no event log). See [`execute_gprm_opts`].
pub fn execute_gprm(
    rt: &GprmRuntime,
    graph: &TaskGraph,
    run: impl Fn(TaskId) + Sync,
) -> Result<ExecStats, String> {
    execute_gprm_opts(rt, graph, run, ExecOpts::default())
}

/// Execute `graph` on the GPRM machine: `CL` coordinator task
/// instances (one per tile, wrapping modulo the tile count) each run
/// the worker loop, mapping ready tasks onto tiles. Work stealing here
/// is *our* extension: the paper's GPRM distributes work statically
/// and steal-free (see DIVERGENCES.md).
pub fn execute_gprm_opts(
    rt: &GprmRuntime,
    graph: &TaskGraph,
    run: impl Fn(TaskId) + Sync,
    opts: ExecOpts,
) -> Result<ExecStats, String> {
    let cl = rt.concurrency_level();
    run_with(graph, cl, opts, &run, |worker| rt.par_invoke(cl, worker))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat_pattern;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn lu_graph(nb: usize) -> TaskGraph {
        TaskGraph::sparselu(&genmat_pattern(nb), nb)
    }

    fn both_modes() -> [ExecOpts; 2] {
        [
            ExecOpts::default().with_events(),
            ExecOpts::mutex_baseline().with_events(),
        ]
    }

    #[test]
    fn omp_executes_every_task_in_edge_order() {
        let rt = OmpRuntime::new(4);
        let g = lu_graph(8);
        for opts in both_modes() {
            let hits = AtomicUsize::new(0);
            let stats = execute_omp_opts(
                &rt,
                &g,
                |_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                },
                opts,
            )
            .unwrap();
            assert_eq!(hits.load(Ordering::Relaxed), g.len());
            assert_eq!(stats.executed, g.len());
            check_event_ordering(&g, &stats.events).unwrap();
        }
        rt.shutdown();
    }

    #[test]
    fn gprm_executes_every_task_in_edge_order() {
        let rt = GprmRuntime::with_tiles(6);
        let g = lu_graph(8);
        for opts in both_modes() {
            let hits = AtomicUsize::new(0);
            let stats = execute_gprm_opts(
                &rt,
                &g,
                |_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                },
                opts,
            )
            .unwrap();
            assert_eq!(hits.load(Ordering::Relaxed), g.len());
            check_event_ordering(&g, &stats.events).unwrap();
        }
        rt.shutdown();
    }

    #[test]
    fn locality_domains_execute_every_task_in_edge_order() {
        // The locality layer changes victim *order*, never the
        // protocol: with the team split into affinity domains the
        // executor must still drain every task in a legal schedule,
        // on both host runtimes.
        let g = lu_graph(8);
        let omp = OmpRuntime::new(4);
        let gprm = GprmRuntime::with_tiles(4);
        for domains in [2usize, 4, 7] {
            let opts = ExecOpts::default().with_events().with_domains(domains);
            let hits = AtomicUsize::new(0);
            let stats = execute_omp_opts(
                &omp,
                &g,
                |_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                },
                opts,
            )
            .unwrap();
            assert_eq!(hits.load(Ordering::Relaxed), g.len());
            check_event_ordering(&g, &stats.events).unwrap();
            let stats = execute_gprm_opts(&gprm, &g, |_| {}, opts).unwrap();
            assert_eq!(stats.executed, g.len());
            check_event_ordering(&g, &stats.events).unwrap();
        }
        omp.shutdown();
        gprm.shutdown();
    }

    #[test]
    fn event_log_off_by_default_and_hot_path_silent() {
        let rt = OmpRuntime::new(4);
        let g = lu_graph(8);
        let stats = execute_omp(&rt, &g, |_| {}).unwrap();
        assert_eq!(stats.executed, g.len());
        assert!(stats.events.is_empty(), "log must be opt-in");
        rt.shutdown();
    }

    #[test]
    fn single_worker_degenerates_to_topological_order() {
        let rt = OmpRuntime::new(1);
        let g = lu_graph(6);
        for opts in both_modes() {
            let stats = execute_omp_opts(&rt, &g, |_| {}, opts).unwrap();
            check_event_ordering(&g, &stats.events).unwrap();
            // One worker: events strictly alternate Start/End.
            for w in stats.events.chunks(2) {
                assert!(matches!(w[0], Event::Start(_)));
                assert!(matches!(w[1], Event::End(_)));
            }
        }
        rt.shutdown();
    }

    #[test]
    fn more_workers_than_tasks_terminates() {
        let rt = OmpRuntime::new(16);
        let g = lu_graph(2); // 2x2: a handful of tasks
        for opts in both_modes() {
            let stats = execute_omp_opts(&rt, &g, |_| {}, opts).unwrap();
            assert_eq!(stats.executed, g.len());
        }
        rt.shutdown();
    }

    #[test]
    fn panic_in_task_propagates_and_unblocks() {
        let rt = OmpRuntime::new(4);
        let g = lu_graph(8);
        for opts in both_modes() {
            let e = execute_omp_opts(
                &rt,
                &g,
                |t| {
                    if t.0 == 3 {
                        panic!("dataflow task exploded");
                    }
                },
                opts,
            )
            .unwrap_err();
            assert!(e.contains("dataflow task exploded"), "{e}");
            // Runtime survives.
            rt.parallel(|_| {}).unwrap();
        }
        rt.shutdown();
    }

    #[test]
    fn panic_on_gprm_backend_propagates() {
        let rt = GprmRuntime::with_tiles(4);
        let g = lu_graph(6);
        for opts in both_modes() {
            let e = execute_gprm_opts(
                &rt,
                &g,
                |t| {
                    if t.0 == 1 {
                        panic!("gprm dataflow task exploded");
                    }
                },
                opts,
            )
            .unwrap_err();
            assert!(e.contains("gprm dataflow task exploded"), "{e}");
            rt.par_invoke(4, |_| {}).unwrap();
        }
        rt.shutdown();
    }

    #[test]
    fn event_checker_rejects_bad_schedules() {
        let g = lu_graph(4);
        // Empty log: nothing ran.
        assert!(check_event_ordering(&g, &[]).is_err());
        // End before start.
        assert!(check_event_ordering(&g, &[Event::End(TaskId(0))]).is_err());
        // A dependent task starting before its predecessor ends.
        let t = (0..g.len())
            .find(|&t| !g.preds(TaskId(t)).is_empty())
            .unwrap();
        let p = g.preds(TaskId(t))[0];
        let bad = vec![
            Event::Start(TaskId(t)),
            Event::End(TaskId(t)),
            Event::Start(TaskId(p)),
            Event::End(TaskId(p)),
        ];
        assert!(check_event_ordering(&g, &bad).is_err());
    }

    #[test]
    fn peak_ready_reflects_available_parallelism() {
        let rt = OmpRuntime::new(2);
        let g = lu_graph(10);
        for opts in both_modes() {
            let stats = execute_omp_opts(&rt, &g, |_| {}, opts).unwrap();
            // After the first lu0, a whole fwd+bdiv front becomes
            // ready (stat survives the lock-free rewrite).
            assert!(stats.peak_ready > 1, "peak {}", stats.peak_ready);
            assert_eq!(stats.executed, g.len());
        }
        rt.shutdown();
    }

    #[test]
    fn stealing_spreads_work_across_workers() {
        // The SparseLU DAG has a single root (the step-0 lu0), and a
        // worker pushes released tasks onto its *own* deque — so other
        // workers can only ever get work by stealing; with slow tasks
        // and a wide graph, more than one thread must end up running.
        let rt = OmpRuntime::new(4);
        let g = lu_graph(12);
        let threads = Mutex::new(std::collections::HashSet::new());
        let stats = execute_omp_opts(
            &rt,
            &g,
            |_| {
                // Slow enough that idle workers go hunting.
                for _ in 0..5_000 {
                    std::hint::spin_loop();
                }
                threads.lock().unwrap().insert(std::thread::current().id());
            },
            ExecOpts::default(),
        )
        .unwrap();
        assert_eq!(stats.executed, g.len());
        assert!(
            threads.lock().unwrap().len() > 1,
            "only one worker ever ran a task — stealing is dead"
        );
        rt.shutdown();
    }
}
