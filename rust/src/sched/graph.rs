//! Dependency-graph construction from per-task read/write sets.
//!
//! The engine is **kernel-agnostic**: a [`Task`] is an opaque op id
//! (an index into the graph's [`OpSpec`] dispatch vocabulary) plus its
//! block access sets — which blocks it reads and which single block it
//! writes (read-modify-write). [`GraphBuilder`] derives the dependence
//! edges purely from those access sets, the way a superscalar
//! scoreboard would:
//!
//! * **RAW** — a task reading block `b` depends on the last writer of
//!   `b` (the write target counts as a read: every kernel here is a
//!   read-modify-write);
//! * **WAW** — a task writing `b` depends on the previous writer of
//!   `b`;
//! * **WAR** — a task writing `b` depends on every reader of `b` since
//!   the previous write.
//!
//! Tasks are registered in the *sequential* program order, so every
//! edge points from a lower to a higher task index and the graph is a
//! DAG by construction; any execution respecting the edges touches
//! each block in exactly the sequential per-block order, which keeps
//! parallel results bit-identical (f32) to the sequential reference.
//!
//! Nothing above this line knows which kernels exist. The workload
//! constructors below instantiate the builder for the two evaluation
//! workloads: [`TaskGraph::sparselu`] (the BOTS SparseLU structure
//! with fill-in — the DAG that replaces the paper's phase-barrier
//! Listings 5–6) and [`TaskGraph::cholesky`] (tiled dense Cholesky in
//! the style of Buttari et al., arXiv:0709.1272). Executors
//! ([`super::exec`]) and the simulator ([`crate::tilesim`]) dispatch
//! through the op table and never match on a concrete kernel, so new
//! workloads (tiled QR, …) only add a constructor plus a kernel
//! table — see DIVERGENCES.md.

use crate::linalg::cholesky::{chol_kernel_flops, CholOp};
use crate::linalg::lu::{kernel_flops, BlockOp};

/// Index of a task inside its [`TaskGraph`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct TaskId(pub usize);

/// Index of a kernel inside a workload's op table (`&[OpSpec]`).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct OpId(pub usize);

/// One entry of a workload's kernel-dispatch vocabulary: a display
/// name plus the flop count the simulator/benches charge per `bs×bs`
/// block. The *executable* kernels live with the drivers
/// ([`crate::apps::dataflow::run_dataflow`] takes a table of closures
/// indexed the same way), so the engine itself stays kernel-agnostic.
#[derive(Clone, Copy, Debug)]
pub struct OpSpec {
    pub name: &'static str,
    pub flops: fn(usize) -> u64,
}

/// SparseLU op ids into [`LU_OPS`].
pub const OP_LU0: OpId = OpId(0);
pub const OP_FWD: OpId = OpId(1);
pub const OP_BDIV: OpId = OpId(2);
pub const OP_BMOD: OpId = OpId(3);

fn flops_lu0(bs: usize) -> u64 {
    kernel_flops(BlockOp::Lu0, bs)
}
fn flops_fwd(bs: usize) -> u64 {
    kernel_flops(BlockOp::Fwd, bs)
}
fn flops_bdiv(bs: usize) -> u64 {
    kernel_flops(BlockOp::Bdiv, bs)
}
fn flops_bmod(bs: usize) -> u64 {
    kernel_flops(BlockOp::Bmod, bs)
}

/// The SparseLU kernel vocabulary, indexed by `OP_LU0`…`OP_BMOD`.
pub const LU_OPS: &[OpSpec] = &[
    OpSpec { name: "lu0", flops: flops_lu0 },
    OpSpec { name: "fwd", flops: flops_fwd },
    OpSpec { name: "bdiv", flops: flops_bdiv },
    OpSpec { name: "bmod", flops: flops_bmod },
];

/// Cholesky op ids into [`CHOLESKY_OPS`].
pub const OP_POTRF: OpId = OpId(0);
pub const OP_TRSM: OpId = OpId(1);
pub const OP_SYRK: OpId = OpId(2);
pub const OP_GEMM: OpId = OpId(3);

fn flops_potrf(bs: usize) -> u64 {
    chol_kernel_flops(CholOp::Potrf, bs)
}
fn flops_trsm(bs: usize) -> u64 {
    chol_kernel_flops(CholOp::Trsm, bs)
}
fn flops_syrk(bs: usize) -> u64 {
    chol_kernel_flops(CholOp::Syrk, bs)
}
fn flops_gemm(bs: usize) -> u64 {
    chol_kernel_flops(CholOp::Gemm, bs)
}

/// The tiled-Cholesky kernel vocabulary, indexed by
/// `OP_POTRF`…`OP_GEMM`.
pub const CHOLESKY_OPS: &[OpSpec] = &[
    OpSpec { name: "potrf", flops: flops_potrf },
    OpSpec { name: "trsm", flops: flops_trsm },
    OpSpec { name: "syrk", flops: flops_syrk },
    OpSpec { name: "gemm", flops: flops_gemm },
];

/// Blocked-matmul op id into [`MATMUL_OPS`].
pub const OP_MADD: OpId = OpId(0);

fn flops_madd(bs: usize) -> u64 {
    let b = bs as u64;
    2 * b * b * b
}

/// The blocked-matmul kernel vocabulary: a single multiply-accumulate
/// op (`C[i,j] += A[i,k]·B[k,j]` on `bs×bs` blocks).
pub const MATMUL_OPS: &[OpSpec] =
    &[OpSpec { name: "madd", flops: flops_madd }];

/// One block task: an op id plus its block access sets. Every kernel
/// in both workloads reads at most two blocks *besides* its write
/// target and read-modify-writes exactly one block, so the read set is
/// a fixed-capacity inline array (the executor hot path never
/// allocates).
#[derive(Clone, Copy, Debug)]
pub struct Task {
    /// Index into the graph's op table (and the driver's kernel table).
    pub op: OpId,
    /// Blocks read (write target excluded); first `n_reads` valid.
    pub reads: [(usize, usize); 2],
    pub n_reads: u8,
    /// The block this task read-modify-writes.
    pub write: (usize, usize),
    /// The write block may be structurally absent before this task
    /// runs; the driver must materialise it zero-filled first (BOTS
    /// `allocate_clean_block` fill-in). Also the simulator's marker
    /// for the extra DRAM traffic of a fresh block.
    pub alloc_write: bool,
}

impl Task {
    /// Pack a task from a read-set slice (≤ 2 entries).
    pub fn new(
        op: OpId,
        reads: &[(usize, usize)],
        write: (usize, usize),
        alloc_write: bool,
    ) -> Self {
        assert!(reads.len() <= 2, "tasks carry at most two extra reads");
        let mut r = [(0, 0); 2];
        r[..reads.len()].copy_from_slice(reads);
        Self { op, reads: r, n_reads: reads.len() as u8, write, alloc_write }
    }

    /// The valid prefix of the read set (write target excluded).
    pub fn reads(&self) -> &[(usize, usize)] {
        &self.reads[..self.n_reads as usize]
    }
}

/// Immutable task DAG: tasks plus predecessor/successor adjacency and
/// the op table describing the kernel vocabulary the tasks index into.
///
/// Successors are stored in one flat CSR layout (`succ_off` /
/// `succ_dat`) rather than per-task `Vec`s: the lock-free executor
/// walks a completed task's successor list while hammering the atomic
/// in-degree counters, and a single contiguous array keeps that walk
/// on one or two cache lines with zero pointer chasing. In-degrees
/// and roots are pre-computed at build time and handed out as slices
/// ([`Self::indegrees`] / [`Self::roots`]) — executors copy them into
/// their own state instead of re-deriving (or re-allocating) them per
/// launch.
pub struct TaskGraph {
    nb: usize,
    ops: &'static [OpSpec],
    tasks: Vec<Task>,
    preds: Vec<Vec<usize>>,
    /// CSR: successors of task `t` are `succ_dat[succ_off[t]..succ_off[t+1]]`.
    succ_off: Vec<usize>,
    succ_dat: Vec<usize>,
    /// Pre-computed in-degree per task.
    indeg: Vec<usize>,
    /// Pre-computed roots (in-degree zero), in task order.
    roots: Vec<usize>,
}

impl TaskGraph {
    /// Build the SparseLU DAG for an `nb×nb` allocation `pattern`
    /// (row-major booleans), tracking fill-in exactly like the
    /// sequential factorisation. Task order matches `sparselu_seq`.
    /// The task stream itself is declared once, by the
    /// [`Sparselu`](super::workload::Sparselu) registry entry.
    pub fn sparselu(pattern: &[bool], nb: usize) -> Self {
        let mut b = GraphBuilder::new(nb);
        super::workload::Sparselu::build_pattern(&mut b, pattern, nb);
        b.build(LU_OPS)
    }

    /// Build the tiled dense Cholesky DAG (lower-triangular storage)
    /// for an `nb×nb` block grid — Buttari et al.'s right-looking
    /// tiled algorithm, declared by the
    /// [`Cholesky`](super::workload::Cholesky) registry entry. Task
    /// order matches [`crate::linalg::cholesky::cholesky_seq`], so any
    /// edge-respecting execution is bit-identical (f32) to it.
    pub fn cholesky(nb: usize) -> Self {
        use super::workload::{Cholesky, Params, Workload as _};
        // Block size is irrelevant to the graph structure.
        Cholesky.graph(&Params::new(nb, 1))
    }

    /// Build the blocked dense matmul DAG `C = A·B` on an `nbc×nbc`
    /// block grid — the paper's §V micro-benchmark workload ported
    /// onto the dataflow engine so all three workloads share one
    /// scheduling path (and can be mixed in a pool job stream).
    ///
    /// The three matrices are embedded in one `2·nbc`-wide block grid
    /// so the access-set machinery applies unchanged: `C[i,j]` lives
    /// at block `(i, j)`, `A[i,k]` at `(i, nbc+k)`, `B[k,j]` at
    /// `(nbc+k, j)` (the fourth quadrant stays unallocated). Each task
    /// is one multiply-accumulate `C[i,j] += A[i,k]·B[k,j]`; A/B
    /// blocks are never written, so the only edges are the per-`C`-
    /// block WAW/RAW chains over `k` — `nbc²` independent chains of
    /// length `nbc`, reproducing the sequential accumulation order
    /// bit-for-bit while exposing `nbc²`-way parallelism. Declared by
    /// the [`Matmul`](super::workload::Matmul) registry entry.
    pub fn matmul(nbc: usize) -> Self {
        use super::workload::{Matmul, Params, Workload as _};
        Matmul.graph(&Params::new(nbc, 1))
    }

    pub fn nb(&self) -> usize {
        self.nb
    }

    /// The kernel vocabulary the tasks' op ids index into.
    pub fn ops(&self) -> &'static [OpSpec] {
        self.ops
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id.0]
    }

    pub fn tasks(&self) -> &[Task] {
        &self.tasks
    }

    pub fn preds(&self, id: TaskId) -> &[usize] {
        &self.preds[id.0]
    }

    /// Successors of `id` — a contiguous CSR slice, ascending task
    /// order (the order PR-1's per-task `Vec`s had).
    pub fn succs(&self, id: TaskId) -> &[usize] {
        &self.succ_dat[self.succ_off[id.0]..self.succ_off[id.0 + 1]]
    }

    /// In-degree of every task — a borrow of the precomputed array
    /// (executors copy it into their own countdown state; nothing is
    /// allocated per launch).
    pub fn indegrees(&self) -> &[usize] {
        &self.indeg
    }

    /// Total number of edges.
    pub fn n_edges(&self) -> usize {
        self.succ_dat.len()
    }

    /// Tasks with no predecessors (initially ready), in task order —
    /// a borrow of the precomputed array.
    pub fn roots(&self) -> &[usize] {
        &self.roots
    }
}

/// Records tasks in sequential order and derives dependence edges from
/// their declared block access sets (see module docs). Fully
/// kernel-agnostic: op ids are opaque to the builder.
pub struct GraphBuilder {
    nb: usize,
    tasks: Vec<Task>,
    preds: Vec<Vec<usize>>,
    /// Per block: last task that wrote it.
    last_writer: Vec<Option<usize>>,
    /// Per block: tasks that read it since the last write.
    readers: Vec<Vec<usize>>,
}

impl GraphBuilder {
    pub fn new(nb: usize) -> Self {
        assert!(nb > 0);
        Self {
            nb,
            tasks: Vec::new(),
            preds: Vec::new(),
            last_writer: vec![None; nb * nb],
            readers: vec![Vec::new(); nb * nb],
        }
    }

    fn bid(&self, (ii, jj): (usize, usize)) -> usize {
        debug_assert!(ii < self.nb && jj < self.nb);
        ii * self.nb + jj
    }

    /// Register the next task in sequential order: op id, blocks read
    /// besides the target, and the block it read-modify-writes.
    /// Returns its id. Edges to earlier tasks are derived
    /// (RAW ∪ WAW ∪ WAR, deduplicated).
    pub fn add_task(
        &mut self,
        op: OpId,
        reads: &[(usize, usize)],
        write: (usize, usize),
        alloc_write: bool,
    ) -> TaskId {
        let task = Task::new(op, reads, write, alloc_write);
        let id = self.tasks.len();
        let mut preds: Vec<usize> = Vec::new();
        let wb = self.bid(write);
        // RAW: the extra reads plus the rmw read of the target.
        for &r in reads {
            let b = self.bid(r);
            if let Some(w) = self.last_writer[b] {
                preds.push(w);
            }
        }
        if let Some(prev) = self.last_writer[wb] {
            preds.push(prev); // RAW on the target == WAW
        }
        preds.extend(self.readers[wb].iter().copied()); // WAR
        preds.sort_unstable();
        preds.dedup();
        preds.retain(|&p| p != id);
        // Update the scoreboard *after* deriving edges.
        for &r in reads {
            let b = self.bid(r);
            self.readers[b].push(id);
        }
        self.last_writer[wb] = Some(id);
        self.readers[wb].clear();
        self.tasks.push(task);
        self.preds.push(preds);
        TaskId(id)
    }

    pub fn build(self, ops: &'static [OpSpec]) -> TaskGraph {
        let n = self.tasks.len();
        // Count out-degrees, prefix-sum into CSR offsets, then fill.
        // Iterating tasks in ascending order keeps each successor
        // slice sorted ascending, like PR-1's per-task Vec push order.
        let mut succ_off = vec![0usize; n + 1];
        for (t, ps) in self.preds.iter().enumerate() {
            for &p in ps {
                debug_assert!(p < t, "edges must point forward");
                succ_off[p + 1] += 1;
            }
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut cursor = succ_off.clone();
        let mut succ_dat = vec![0usize; succ_off[n]];
        for (t, ps) in self.preds.iter().enumerate() {
            for &p in ps {
                succ_dat[cursor[p]] = t;
                cursor[p] += 1;
            }
        }
        let indeg: Vec<usize> = self.preds.iter().map(Vec::len).collect();
        let roots: Vec<usize> =
            (0..n).filter(|&t| indeg[t] == 0).collect();
        TaskGraph {
            nb: self.nb,
            ops,
            tasks: self.tasks,
            preds: self.preds,
            succ_off,
            succ_dat,
            indeg,
            roots,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat_pattern;
    use crate::linalg::lu::lu_task_counts;

    #[test]
    fn single_block_is_one_task() {
        let g = TaskGraph::sparselu(&[true], 1);
        assert_eq!(g.len(), 1);
        assert!(g.preds(TaskId(0)).is_empty());
        assert_eq!(g.roots().to_vec(), vec![0]);
    }

    #[test]
    fn task_counts_match_structural_walk() {
        let nb = 12;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        let counts = lu_task_counts(&genmat_pattern(nb), nb);
        let want = nb
            + counts.fwd.iter().sum::<usize>()
            + counts.bdiv.iter().sum::<usize>()
            + counts.bmod.iter().sum::<usize>();
        assert_eq!(g.len(), want);
    }

    #[test]
    fn edges_point_forward_and_first_lu0_is_root() {
        let nb = 10;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            for &p in g.preds(TaskId(t)) {
                assert!(p < t, "edge {p} -> {t} must point forward");
            }
        }
        assert_eq!(g.task(TaskId(0)).op, OP_LU0);
        assert!(g.preds(TaskId(0)).is_empty());
        // Succ lists mirror pred lists.
        let from_preds: usize = g.indegrees().iter().sum();
        let from_succs: usize =
            (0..g.len()).map(|t| g.succs(TaskId(t)).len()).sum();
        assert_eq!(from_preds, from_succs);
        assert_eq!(from_preds, g.n_edges());
    }

    #[test]
    fn fwd_depends_on_its_steps_lu0() {
        let nb = 6;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            let task = *g.task(TaskId(t));
            if task.op == OP_FWD || task.op == OP_BDIV {
                // Some predecessor must be the lu0 writing this task's
                // diagonal read block.
                let diag = task.reads()[0];
                let has_lu0 = g.preds(TaskId(t)).iter().any(|&p| {
                    let pt = g.task(TaskId(p));
                    pt.op == OP_LU0 && pt.write == diag
                });
                assert!(has_lu0, "task {t} ({task:?}) misses its lu0 dep");
            }
        }
    }

    #[test]
    fn bmod_depends_on_row_and_col_panels() {
        let nb = 8;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            let task = *g.task(TaskId(t));
            if task.op != OP_BMOD {
                continue;
            }
            // Predecessors must include the writers of both panels
            // this bmod reads (the step's bdiv and fwd outputs).
            for &r in task.reads() {
                let has_writer = g.preds(TaskId(t)).iter().any(|&p| {
                    let pt = g.task(TaskId(p));
                    pt.write == r
                        && (pt.op == OP_BDIV || pt.op == OP_FWD)
                });
                assert!(
                    has_writer,
                    "bmod {task:?} misses the writer of its read {r:?}"
                );
            }
        }
    }

    #[test]
    fn same_block_tasks_are_chained_in_step_order() {
        // All writers of one block must form a total order (a chain) —
        // this is what makes parallel execution f32-identical to seq.
        let nb = 10;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        use std::collections::HashMap;
        let mut writers: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for t in 0..g.len() {
            let task = g.task(TaskId(t));
            writers.entry(task.write).or_default().push(t);
        }
        for ((ii, jj), ws) in writers {
            for pair in ws.windows(2) {
                // Later writer must (transitively) depend on the
                // earlier; the direct WAW/RAW edge makes it immediate.
                assert!(
                    g.preds(TaskId(pair[1])).contains(&pair[0]),
                    "writers of ({ii},{jj}) not chained: {pair:?}"
                );
            }
        }
    }

    #[test]
    fn war_edges_derived_for_generic_sets() {
        // reader of block (0,0) then writer of (0,0): WAR edge. The
        // builder is kernel-agnostic — any op id works.
        let mut b = GraphBuilder::new(2);
        let t0 = b.add_task(OpId(0), &[(0, 0)], (1, 1), false);
        let t1 = b.add_task(OpId(0), &[], (0, 0), false);
        let g = b.build(LU_OPS);
        assert_eq!(g.preds(t1), &[t0.0]);
        assert_eq!(g.succs(t0), &[t1.0]);
    }

    #[test]
    fn fill_in_flagged_once_per_block() {
        let nb = 10;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        use std::collections::HashSet;
        let mut fresh: HashSet<(usize, usize)> = HashSet::new();
        let mut n_fill = 0;
        for t in g.tasks() {
            if t.alloc_write {
                assert!(fresh.insert(t.write), "double fill-in {t:?}");
                n_fill += 1;
            }
        }
        assert!(n_fill > 0, "genmat structure must produce fill-in");
    }

    #[test]
    fn cholesky_task_count_closed_form() {
        // Per step kk with s = nb-kk-1 trailing rows: 1 potrf + s trsm
        // + s syrk + s(s-1)/2 gemm.
        for nb in [1usize, 2, 3, 8, 13] {
            let g = TaskGraph::cholesky(nb);
            let want: usize = (0..nb)
                .map(|kk| {
                    let s = nb - kk - 1;
                    1 + s + s + s * s.saturating_sub(1) / 2
                })
                .sum();
            assert_eq!(g.len(), want, "nb={nb}");
            assert_eq!(g.roots().to_vec(), vec![0], "single potrf root");
        }
    }

    #[test]
    fn cholesky_trsm_depends_on_potrf_and_syrk_on_trsm() {
        let g = TaskGraph::cholesky(8);
        for t in 0..g.len() {
            let task = *g.task(TaskId(t));
            if task.op == OP_TRSM
                || task.op == OP_SYRK
                || task.op == OP_GEMM
            {
                // Every extra read must have a predecessor writing it.
                for &r in task.reads() {
                    let has_writer = g.preds(TaskId(t)).iter().any(|&p| {
                        g.task(TaskId(p)).write == r
                    });
                    assert!(
                        has_writer,
                        "task {t} ({task:?}) misses writer of {r:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn cholesky_touches_only_lower_triangle() {
        let g = TaskGraph::cholesky(9);
        for t in g.tasks() {
            assert!(t.write.0 >= t.write.1, "upper-triangle write {t:?}");
            for &r in t.reads() {
                assert!(r.0 >= r.1, "upper-triangle read {t:?}");
            }
            assert!(!t.alloc_write, "cholesky has no fill-in");
        }
    }

    #[test]
    fn matmul_graph_shape() {
        for nbc in [1usize, 2, 4, 6] {
            let g = TaskGraph::matmul(nbc);
            assert_eq!(g.nb(), 2 * nbc);
            assert_eq!(g.len(), nbc * nbc * nbc, "one madd per (k,i,j)");
            // k = 0 layer is the root front; every other task chains on
            // the previous writer of its C block.
            assert_eq!(g.roots().len(), nbc * nbc);
            assert_eq!(g.n_edges(), nbc * nbc * (nbc - 1));
            for t in 0..g.len() {
                let task = *g.task(TaskId(t));
                assert_eq!(task.op, OP_MADD);
                // Write lands in the C quadrant, reads in A/B quadrants.
                assert!(task.write.0 < nbc && task.write.1 < nbc);
                let [a, b] = [task.reads()[0], task.reads()[1]];
                assert!(a.0 < nbc && a.1 >= nbc, "A-quadrant read {a:?}");
                assert!(b.0 >= nbc && b.1 < nbc, "B-quadrant read {b:?}");
                assert!(g.preds(TaskId(t)).len() <= 1, "chains only");
            }
        }
    }

    #[test]
    fn matmul_chains_preserve_accumulation_order() {
        // Writers of each C block must form a k-ordered chain — the
        // bit-identity guarantee for the dataflow matmul.
        let nbc = 4;
        let g = TaskGraph::matmul(nbc);
        use std::collections::HashMap;
        let mut writers: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for t in 0..g.len() {
            writers.entry(g.task(TaskId(t)).write).or_default().push(t);
        }
        assert_eq!(writers.len(), nbc * nbc);
        for (blk, ws) in writers {
            assert_eq!(ws.len(), nbc, "block {blk:?}");
            for pair in ws.windows(2) {
                assert_eq!(
                    g.preds(TaskId(pair[1])),
                    &[pair[0]],
                    "writers of {blk:?} not chained"
                );
            }
        }
    }

    #[test]
    fn ops_tables_align_with_op_ids() {
        assert_eq!(LU_OPS[OP_LU0.0].name, "lu0");
        assert_eq!(LU_OPS[OP_FWD.0].name, "fwd");
        assert_eq!(LU_OPS[OP_BDIV.0].name, "bdiv");
        assert_eq!(LU_OPS[OP_BMOD.0].name, "bmod");
        assert_eq!(CHOLESKY_OPS[OP_POTRF.0].name, "potrf");
        assert_eq!(CHOLESKY_OPS[OP_TRSM.0].name, "trsm");
        assert_eq!(CHOLESKY_OPS[OP_SYRK.0].name, "syrk");
        assert_eq!(CHOLESKY_OPS[OP_GEMM.0].name, "gemm");
        let g = TaskGraph::sparselu(&[true], 1);
        assert_eq!(g.ops()[g.task(TaskId(0)).op.0].name, "lu0");
        let c = TaskGraph::cholesky(1);
        assert_eq!(c.ops()[c.task(TaskId(0)).op.0].name, "potrf");
        assert_eq!(MATMUL_OPS[OP_MADD.0].name, "madd");
        let m = TaskGraph::matmul(1);
        assert_eq!(m.ops()[m.task(TaskId(0)).op.0].name, "madd");
    }
}
