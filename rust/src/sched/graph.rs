//! Dependency-graph construction from per-task read/write sets.
//!
//! [`GraphBuilder`] records, for each block task, which blocks it reads
//! and which it writes, and derives the dependence edges the way a
//! superscalar scoreboard would:
//!
//! * **RAW** — a task reading block `b` depends on the last writer of
//!   `b`;
//! * **WAW** — a task writing `b` depends on the previous writer of
//!   `b`;
//! * **WAR** — a task writing `b` depends on every reader of `b` since
//!   the previous write.
//!
//! Tasks are registered in the *sequential* program order, so every
//! edge points from a lower to a higher task index and the graph is a
//! DAG by construction; any execution respecting the edges touches
//! each block in exactly the sequential per-block order, which keeps
//! parallel results bit-identical (f32) to the sequential reference.
//!
//! [`TaskGraph::sparselu`] applies the builder to the BOTS SparseLU
//! structure (fill-in included) — the DAG that replaces the paper's
//! phase-barrier Listings 5–6 (see DIVERGENCES.md).

use crate::linalg::lu::BlockOp;

/// Index of a task inside its [`TaskGraph`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct TaskId(pub usize);

/// One block task: which kernel, on which blocks, at which elimination
/// step.
#[derive(Clone, Copy, Debug)]
pub struct BlockTask {
    pub op: BlockOp,
    /// Elimination step the task belongs to.
    pub kk: usize,
    /// Block row of the task's written block (`kk` for `Lu0`/`Fwd`).
    pub ii: usize,
    /// Block column of the written block (`kk` for `Lu0`/`Bdiv`).
    pub jj: usize,
    /// `Bmod` only: the written block did not exist before this step
    /// (BOTS `allocate_clean_block` fill-in path).
    pub fill_in: bool,
}

/// Immutable task DAG: tasks plus predecessor/successor adjacency.
///
/// Successors are stored in one flat CSR layout (`succ_off` /
/// `succ_dat`) rather than per-task `Vec`s: the lock-free executor
/// walks a completed task's successor list while hammering the atomic
/// in-degree counters, and a single contiguous array keeps that walk
/// on one or two cache lines with zero pointer chasing. In-degrees
/// and roots are pre-computed at build time for the same reason —
/// executors copy them into atomics instead of re-deriving them.
pub struct TaskGraph {
    nb: usize,
    tasks: Vec<BlockTask>,
    preds: Vec<Vec<usize>>,
    /// CSR: successors of task `t` are `succ_dat[succ_off[t]..succ_off[t+1]]`.
    succ_off: Vec<usize>,
    succ_dat: Vec<usize>,
    /// Pre-computed in-degree per task.
    indeg: Vec<usize>,
    /// Pre-computed roots (in-degree zero), in task order.
    roots: Vec<usize>,
}

impl TaskGraph {
    /// Build the SparseLU DAG for an `nb×nb` allocation `pattern`
    /// (row-major booleans), tracking fill-in exactly like the
    /// sequential factorisation. Task order matches `sparselu_seq`.
    pub fn sparselu(pattern: &[bool], nb: usize) -> Self {
        assert_eq!(pattern.len(), nb * nb, "pattern shape");
        let mut alloc = pattern.to_vec();
        let mut b = GraphBuilder::new(nb);
        for kk in 0..nb {
            b.add_task(
                BlockTask { op: BlockOp::Lu0, kk, ii: kk, jj: kk, fill_in: false },
                &[(kk, kk)],
                &[(kk, kk)],
            );
            for jj in kk + 1..nb {
                if alloc[kk * nb + jj] {
                    b.add_task(
                        BlockTask { op: BlockOp::Fwd, kk, ii: kk, jj, fill_in: false },
                        &[(kk, kk), (kk, jj)],
                        &[(kk, jj)],
                    );
                }
            }
            for ii in kk + 1..nb {
                if alloc[ii * nb + kk] {
                    b.add_task(
                        BlockTask { op: BlockOp::Bdiv, kk, ii, jj: kk, fill_in: false },
                        &[(kk, kk), (ii, kk)],
                        &[(ii, kk)],
                    );
                }
            }
            for ii in kk + 1..nb {
                if !alloc[ii * nb + kk] {
                    continue;
                }
                for jj in kk + 1..nb {
                    if !alloc[kk * nb + jj] {
                        continue;
                    }
                    let fill_in = !alloc[ii * nb + jj];
                    alloc[ii * nb + jj] = true;
                    b.add_task(
                        BlockTask { op: BlockOp::Bmod, kk, ii, jj, fill_in },
                        &[(ii, kk), (kk, jj), (ii, jj)],
                        &[(ii, jj)],
                    );
                }
            }
        }
        b.build()
    }

    pub fn nb(&self) -> usize {
        self.nb
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn task(&self, id: TaskId) -> &BlockTask {
        &self.tasks[id.0]
    }

    pub fn tasks(&self) -> &[BlockTask] {
        &self.tasks
    }

    pub fn preds(&self, id: TaskId) -> &[usize] {
        &self.preds[id.0]
    }

    /// Successors of `id` — a contiguous CSR slice, ascending task
    /// order (the order PR-1's per-task `Vec`s had).
    pub fn succs(&self, id: TaskId) -> &[usize] {
        &self.succ_dat[self.succ_off[id.0]..self.succ_off[id.0 + 1]]
    }

    /// In-degree of every task (fresh copy — executors count it down).
    pub fn indegrees(&self) -> Vec<usize> {
        self.indeg.clone()
    }

    /// Total number of edges.
    pub fn n_edges(&self) -> usize {
        self.succ_dat.len()
    }

    /// Tasks with no predecessors (initially ready), in task order.
    pub fn roots(&self) -> Vec<usize> {
        self.roots.clone()
    }
}

/// Records tasks in sequential order and derives dependence edges from
/// their declared read/write sets (see module docs).
pub struct GraphBuilder {
    nb: usize,
    tasks: Vec<BlockTask>,
    preds: Vec<Vec<usize>>,
    /// Per block: last task that wrote it.
    last_writer: Vec<Option<usize>>,
    /// Per block: tasks that read it since the last write.
    readers: Vec<Vec<usize>>,
}

impl GraphBuilder {
    pub fn new(nb: usize) -> Self {
        assert!(nb > 0);
        Self {
            nb,
            tasks: Vec::new(),
            preds: Vec::new(),
            last_writer: vec![None; nb * nb],
            readers: vec![Vec::new(); nb * nb],
        }
    }

    fn bid(&self, (ii, jj): (usize, usize)) -> usize {
        debug_assert!(ii < self.nb && jj < self.nb);
        ii * self.nb + jj
    }

    /// Register the next task in sequential order with its block
    /// read/write sets; returns its id. Edges to earlier tasks are
    /// derived (RAW ∪ WAW ∪ WAR, deduplicated, self-edges dropped —
    /// a read-modify-write task lists its target in both sets).
    pub fn add_task(
        &mut self,
        meta: BlockTask,
        reads: &[(usize, usize)],
        writes: &[(usize, usize)],
    ) -> TaskId {
        let id = self.tasks.len();
        let mut preds: Vec<usize> = Vec::new();
        for &r in reads {
            let b = self.bid(r);
            if let Some(w) = self.last_writer[b] {
                preds.push(w); // RAW
            }
        }
        for &w in writes {
            let b = self.bid(w);
            if let Some(prev) = self.last_writer[b] {
                preds.push(prev); // WAW
            }
            preds.extend(self.readers[b].iter().copied()); // WAR
        }
        preds.sort_unstable();
        preds.dedup();
        preds.retain(|&p| p != id);
        // Update the scoreboard *after* deriving edges.
        for &r in reads {
            let b = self.bid(r);
            self.readers[b].push(id);
        }
        for &w in writes {
            let b = self.bid(w);
            self.last_writer[b] = Some(id);
            self.readers[b].clear();
        }
        self.tasks.push(meta);
        self.preds.push(preds);
        TaskId(id)
    }

    pub fn build(self) -> TaskGraph {
        let n = self.tasks.len();
        // Count out-degrees, prefix-sum into CSR offsets, then fill.
        // Iterating tasks in ascending order keeps each successor
        // slice sorted ascending, like PR-1's per-task Vec push order.
        let mut succ_off = vec![0usize; n + 1];
        for (t, ps) in self.preds.iter().enumerate() {
            for &p in ps {
                debug_assert!(p < t, "edges must point forward");
                succ_off[p + 1] += 1;
            }
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut cursor = succ_off.clone();
        let mut succ_dat = vec![0usize; succ_off[n]];
        for (t, ps) in self.preds.iter().enumerate() {
            for &p in ps {
                succ_dat[cursor[p]] = t;
                cursor[p] += 1;
            }
        }
        let indeg: Vec<usize> = self.preds.iter().map(Vec::len).collect();
        let roots: Vec<usize> =
            (0..n).filter(|&t| indeg[t] == 0).collect();
        TaskGraph {
            nb: self.nb,
            tasks: self.tasks,
            preds: self.preds,
            succ_off,
            succ_dat,
            indeg,
            roots,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::genmat::genmat_pattern;
    use crate::linalg::lu::lu_task_counts;

    #[test]
    fn single_block_is_one_task() {
        let g = TaskGraph::sparselu(&[true], 1);
        assert_eq!(g.len(), 1);
        assert!(g.preds(TaskId(0)).is_empty());
        assert_eq!(g.roots(), vec![0]);
    }

    #[test]
    fn task_counts_match_structural_walk() {
        let nb = 12;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        let counts = lu_task_counts(&genmat_pattern(nb), nb);
        let want = nb
            + counts.fwd.iter().sum::<usize>()
            + counts.bdiv.iter().sum::<usize>()
            + counts.bmod.iter().sum::<usize>();
        assert_eq!(g.len(), want);
    }

    #[test]
    fn edges_point_forward_and_first_lu0_is_root() {
        let nb = 10;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            for &p in g.preds(TaskId(t)) {
                assert!(p < t, "edge {p} -> {t} must point forward");
            }
        }
        assert_eq!(g.task(TaskId(0)).op, BlockOp::Lu0);
        assert!(g.preds(TaskId(0)).is_empty());
        // Succ lists mirror pred lists.
        let from_preds: usize = g.indegrees().iter().sum();
        let from_succs: usize =
            (0..g.len()).map(|t| g.succs(TaskId(t)).len()).sum();
        assert_eq!(from_preds, from_succs);
        assert_eq!(from_preds, g.n_edges());
    }

    #[test]
    fn fwd_depends_on_its_steps_lu0() {
        let nb = 6;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            let task = *g.task(TaskId(t));
            if task.op == BlockOp::Fwd || task.op == BlockOp::Bdiv {
                // Some predecessor must be the lu0 of the same step.
                let has_lu0 = g.preds(TaskId(t)).iter().any(|&p| {
                    let pt = g.task(TaskId(p));
                    pt.op == BlockOp::Lu0 && pt.kk == task.kk
                });
                assert!(has_lu0, "task {t} ({task:?}) misses its lu0 dep");
            }
        }
    }

    #[test]
    fn bmod_depends_on_row_and_col_panels() {
        let nb = 8;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        for t in 0..g.len() {
            let task = *g.task(TaskId(t));
            if task.op != BlockOp::Bmod {
                continue;
            }
            let dep_on = |op: BlockOp, ii: usize, jj: usize| {
                g.preds(TaskId(t)).iter().any(|&p| {
                    let pt = g.task(TaskId(p));
                    pt.op == op && pt.ii == ii && pt.jj == jj && pt.kk == task.kk
                })
            };
            assert!(
                dep_on(BlockOp::Bdiv, task.ii, task.kk),
                "bmod {task:?} misses bdiv dep"
            );
            assert!(
                dep_on(BlockOp::Fwd, task.kk, task.jj),
                "bmod {task:?} misses fwd dep"
            );
        }
    }

    #[test]
    fn same_block_tasks_are_chained_in_step_order() {
        // All writers of one block must form a total order (a chain) —
        // this is what makes parallel execution f32-identical to seq.
        let nb = 10;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        use std::collections::HashMap;
        let mut writers: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for t in 0..g.len() {
            let task = g.task(TaskId(t));
            writers.entry((task.ii, task.jj)).or_default().push(t);
        }
        for ((ii, jj), ws) in writers {
            for pair in ws.windows(2) {
                // Later writer must (transitively) depend on the
                // earlier; the direct WAW/RAW edge makes it immediate.
                assert!(
                    g.preds(TaskId(pair[1])).contains(&pair[0]),
                    "writers of ({ii},{jj}) not chained: {pair:?}"
                );
            }
        }
    }

    #[test]
    fn war_edges_derived_for_generic_sets() {
        // reader of block 0 then writer of block 0: WAR edge.
        let mut b = GraphBuilder::new(2);
        let t0 = b.add_task(
            BlockTask { op: BlockOp::Lu0, kk: 0, ii: 0, jj: 0, fill_in: false },
            &[(0, 0)],
            &[(1, 1)],
        );
        let t1 = b.add_task(
            BlockTask { op: BlockOp::Lu0, kk: 0, ii: 0, jj: 0, fill_in: false },
            &[],
            &[(0, 0)],
        );
        let g = b.build();
        assert_eq!(g.preds(t1), &[t0.0]);
        assert_eq!(g.succs(t0), &[t1.0]);
    }

    #[test]
    fn fill_in_flagged_once_per_block() {
        let nb = 10;
        let g = TaskGraph::sparselu(&genmat_pattern(nb), nb);
        use std::collections::HashSet;
        let mut fresh: HashSet<(usize, usize)> = HashSet::new();
        let mut n_fill = 0;
        for t in g.tasks() {
            if t.fill_in {
                assert!(fresh.insert((t.ii, t.jj)), "double fill-in {t:?}");
                n_fill += 1;
            }
        }
        assert!(n_fill > 0, "genmat structure must produce fill-in");
    }
}
