//! The crate-level scheduling error — one typed surface for
//! everything that can go wrong between declaring a workload and
//! collecting its result.
//!
//! PR 4 introduced the typed [`SubmitError`] for capacity/shutdown
//! pressure but left the other failure modes scattered: graph/matrix
//! mismatches were `assert!`s, a poisoned pool job surfaced as a bare
//! `String`, and executor-option misuse panicked. [`Error`] unifies
//! them: every fallible entry point of the scheduling stack
//! ([`crate::apps::dataflow::run_dataflow`],
//! [`super::pool::PoolScope::submit`], [`super::pool::JobHandle::wait`],
//! [`super::session::Session`]) returns this one type, which is
//! `Display` + [`std::error::Error`] and never panics on an error
//! path.
//!
//! The fault/recovery layer (PR 7) sharpened the job-failure story:
//! a poisoned job now carries a structured [`JobFailure`] — every
//! attempt's failing `(op, task index, panic message)` — instead of a
//! bare string, and cooperative cancellation surfaces as its own
//! [`Error::Cancelled`] variant rather than masquerading as a panic.

use super::pool::SubmitError;

/// Where one attempt of a job died: the failing kernel's op name, the
/// task index within the job's graph, the 1-based attempt number, and
/// the captured panic message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FailedAttempt {
    /// 1-based attempt number (1 = the original submission).
    pub attempt: usize,
    /// Display name of the failing task's op (e.g. `"potrf"`).
    pub op: &'static str,
    /// Task index within the job's graph.
    pub task: usize,
    /// The captured panic message.
    pub msg: String,
}

impl std::fmt::Display for FailedAttempt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "attempt {}: `{}` task {} panicked: {}",
            self.attempt, self.op, self.task, self.msg
        )
    }
}

/// The full poison record of a failed job: one [`FailedAttempt`] per
/// attempt, in attempt order. Under a
/// [`super::fault::RetryPolicy`] this is the exhausted attempt
/// history; without one it holds the single original attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobFailure {
    pub attempts: Vec<FailedAttempt>,
}

impl JobFailure {
    /// The record of a first (and so far only) failed attempt.
    pub fn single(op: &'static str, task: usize, msg: String) -> Self {
        Self { attempts: vec![FailedAttempt { attempt: 1, op, task, msg }] }
    }

    /// The most recent attempt's record.
    pub fn last(&self) -> &FailedAttempt {
        self.attempts.last().expect("a job failure records >= 1 attempt")
    }
}

impl std::fmt::Display for JobFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for a in &self.attempts {
            if !first {
                write!(f, "; ")?;
            }
            write!(f, "{a}")?;
            first = false;
        }
        Ok(())
    }
}

/// Why a scheduling operation failed. Clonable (job results are
/// broadcast to every waiter) and comparable (tests match variants).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Error {
    /// The pool did not accept the submission (graph too large for the
    /// capacity, overload shed, drain, or shutdown). See
    /// [`SubmitError`].
    Submit(SubmitError),
    /// A task of the job panicked; the job was poisoned and every
    /// attempt's failing coordinates captured. Sibling jobs and the
    /// pool are unaffected.
    Job(JobFailure),
    /// The job was cooperatively cancelled (an explicit
    /// [`super::pool::CancelToken`] or a missed deadline) after `ran`
    /// of its kernels had executed. Cancellation is not poisoning:
    /// the remaining tasks were skipped, not failed.
    Cancelled { ran: usize },
    /// The task graph's block grid does not match the matrix it was
    /// asked to run over.
    GridMismatch { graph_nb: usize, matrix_nb: usize },
    /// The kernel table does not cover the graph's op vocabulary
    /// (lengths must match — op ids index both).
    KernelTable { ops: usize, kernels: usize },
    /// No registered workload carries this name; see
    /// [`super::workload::registry`] (CLI: `--list-apps`).
    UnknownWorkload(String),
    /// An inter-job dependency handle belongs to a different pool —
    /// a foreign predecessor's completion could never re-run this
    /// pool's admission pass, so the submission is rejected instead
    /// of deadlocking.
    CrossPoolDependency,
    /// The handle names no job tracked by this
    /// [`super::session::Session`] — it was never submitted through
    /// it, or its output was already retired by
    /// [`super::session::Session::take_output`].
    UnknownJob,
    /// One-shot executor options ([`super::exec::ExecOpts`]) were
    /// passed to a host that does not consult them (the persistent
    /// pool always work-steals and records no event log).
    ExecOpts(&'static str),
    /// A host runtime refused the execution region (e.g. a nested or
    /// shut-down runtime).
    Host(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Submit(e) => write!(f, "{e}"),
            Error::Job(failure) => write!(
                f,
                "job failed after {} attempt(s): {failure}",
                failure.attempts.len()
            ),
            Error::Cancelled { ran } => write!(
                f,
                "job cancelled after running {ran} of its tasks"
            ),
            Error::GridMismatch { graph_nb, matrix_nb } => write!(
                f,
                "graph block grid {graph_nb}x{graph_nb} does not match \
                 matrix grid {matrix_nb}x{matrix_nb}"
            ),
            Error::KernelTable { ops, kernels } => write!(
                f,
                "kernel table covers {kernels} ops but the graph's \
                 vocabulary has {ops}"
            ),
            Error::UnknownWorkload(name) => write!(
                f,
                "unknown workload {name:?} (see `--list-apps` for the \
                 registry)"
            ),
            Error::CrossPoolDependency => write!(
                f,
                "inter-job dependency handle belongs to a different \
                 pool"
            ),
            Error::UnknownJob => write!(
                f,
                "handle names no job tracked by this session (never \
                 submitted through it, or already retired)"
            ),
            Error::ExecOpts(msg) => write!(f, "{msg}"),
            Error::Host(msg) => write!(f, "host runtime failed: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Submit(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SubmitError> for Error {
    fn from(e: SubmitError) -> Self {
        Error::Submit(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_failure_records_where_each_attempt_died() {
        let mut f = JobFailure::single("potrf", 3, "boom".into());
        assert_eq!(f.last().attempt, 1);
        f.attempts.push(FailedAttempt {
            attempt: 2,
            op: "trsm",
            task: 7,
            msg: "boom again".into(),
        });
        let e = Error::Job(f.clone());
        let s = e.to_string();
        assert!(s.contains("after 2 attempt(s)"), "{s}");
        assert!(s.contains("attempt 1: `potrf` task 3 panicked: boom"));
        assert!(
            s.contains("attempt 2: `trsm` task 7 panicked: boom again")
        );
        assert_eq!(f.last().task, 7);
    }

    #[test]
    fn display_covers_every_variant() {
        // Submission rejections, including the recovery-layer ones.
        let cases: Vec<(Error, &str)> = vec![
            (Error::from(SubmitError::ShutDown), "pool is shut down"),
            (
                Error::from(SubmitError::GraphTooLarge {
                    tasks: 9,
                    capacity: 4,
                }),
                "9",
            ),
            (
                Error::from(SubmitError::Overloaded {
                    pending: 5,
                    limit: 4,
                }),
                "shed limit 4",
            ),
            (
                Error::from(SubmitError::Draining),
                "draining",
            ),
            (
                Error::Job(JobFailure::single("lu0", 0, "div".into())),
                "`lu0` task 0 panicked: div",
            ),
            (
                Error::Cancelled { ran: 12 },
                "cancelled after running 12",
            ),
            (Error::GridMismatch { graph_nb: 4, matrix_nb: 5 }, "4x4"),
            (Error::KernelTable { ops: 4, kernels: 3 }, "3"),
            (Error::UnknownWorkload("qr".into()), "qr"),
            (Error::CrossPoolDependency, "different"),
            (Error::UnknownJob, "retired"),
            (Error::ExecOpts("no events"), "no events"),
            (Error::Host("nested".into()), "nested"),
        ];
        for (e, needle) in cases {
            let s = e.to_string();
            assert!(s.contains(needle), "{e:?} -> {s}");
        }
    }

    #[test]
    fn source_is_the_submit_error_and_nothing_else() {
        for e in [
            Error::from(SubmitError::ShutDown),
            Error::from(SubmitError::Draining),
            Error::from(SubmitError::Overloaded { pending: 1, limit: 1 }),
            Error::from(SubmitError::GraphTooLarge {
                tasks: 2,
                capacity: 1,
            }),
        ] {
            assert!(std::error::Error::source(&e).is_some(), "{e:?}");
        }
        for e in [
            Error::Job(JobFailure::single("madd", 1, "x".into())),
            Error::Cancelled { ran: 0 },
            Error::GridMismatch { graph_nb: 1, matrix_nb: 2 },
            Error::KernelTable { ops: 1, kernels: 2 },
            Error::UnknownWorkload("x".into()),
            Error::CrossPoolDependency,
            Error::UnknownJob,
            Error::ExecOpts("opts"),
            Error::Host("h".into()),
        ] {
            assert!(std::error::Error::source(&e).is_none(), "{e:?}");
        }
    }

    #[test]
    fn errors_stay_comparable_and_clonable() {
        // Job results are broadcast to every waiter: the error type
        // must stay `Clone + PartialEq` even with structured payloads.
        let a = Error::Job(JobFailure::single("syrk", 2, "m".into()));
        assert_eq!(a.clone(), a);
        assert_ne!(a, Error::Cancelled { ran: 2 });
        assert_eq!(
            Error::Cancelled { ran: 2 },
            Error::Cancelled { ran: 2 }
        );
    }
}
