//! The crate-level scheduling error — one typed surface for
//! everything that can go wrong between declaring a workload and
//! collecting its result.
//!
//! PR 4 introduced the typed [`SubmitError`] for capacity/shutdown
//! pressure but left the other failure modes scattered: graph/matrix
//! mismatches were `assert!`s, a poisoned pool job surfaced as a bare
//! `String`, and executor-option misuse panicked. [`Error`] unifies
//! them: every fallible entry point of the scheduling stack
//! ([`crate::apps::dataflow::run_dataflow`],
//! [`super::pool::PoolScope::submit`], [`super::pool::JobHandle::wait`],
//! [`super::session::Session`]) returns this one type, which is
//! `Display` + [`std::error::Error`] and never panics on an error
//! path.

use super::pool::SubmitError;

/// Why a scheduling operation failed. Clonable (job results are
/// broadcast to every waiter) and comparable (tests match variants).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Error {
    /// The pool did not accept the submission (graph too large for the
    /// capacity, or the pool is shutting down). See [`SubmitError`].
    Submit(SubmitError),
    /// A task of the job panicked; the job was poisoned and the
    /// message captured. Sibling jobs and the pool are unaffected.
    Job(String),
    /// The task graph's block grid does not match the matrix it was
    /// asked to run over.
    GridMismatch { graph_nb: usize, matrix_nb: usize },
    /// The kernel table does not cover the graph's op vocabulary
    /// (lengths must match — op ids index both).
    KernelTable { ops: usize, kernels: usize },
    /// No registered workload carries this name; see
    /// [`super::workload::registry`] (CLI: `--list-apps`).
    UnknownWorkload(String),
    /// An inter-job dependency handle belongs to a different pool —
    /// a foreign predecessor's completion could never re-run this
    /// pool's admission pass, so the submission is rejected instead
    /// of deadlocking.
    CrossPoolDependency,
    /// The handle names no job tracked by this
    /// [`super::session::Session`] — it was never submitted through
    /// it, or its output was already retired by
    /// [`super::session::Session::take_output`].
    UnknownJob,
    /// One-shot executor options ([`super::exec::ExecOpts`]) were
    /// passed to a host that does not consult them (the persistent
    /// pool always work-steals and records no event log).
    ExecOpts(&'static str),
    /// A host runtime refused the execution region (e.g. a nested or
    /// shut-down runtime).
    Host(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Submit(e) => write!(f, "{e}"),
            Error::Job(msg) => write!(f, "job failed: {msg}"),
            Error::GridMismatch { graph_nb, matrix_nb } => write!(
                f,
                "graph block grid {graph_nb}x{graph_nb} does not match \
                 matrix grid {matrix_nb}x{matrix_nb}"
            ),
            Error::KernelTable { ops, kernels } => write!(
                f,
                "kernel table covers {kernels} ops but the graph's \
                 vocabulary has {ops}"
            ),
            Error::UnknownWorkload(name) => write!(
                f,
                "unknown workload {name:?} (see `--list-apps` for the \
                 registry)"
            ),
            Error::CrossPoolDependency => write!(
                f,
                "inter-job dependency handle belongs to a different \
                 pool"
            ),
            Error::UnknownJob => write!(
                f,
                "handle names no job tracked by this session (never \
                 submitted through it, or already retired)"
            ),
            Error::ExecOpts(msg) => write!(f, "{msg}"),
            Error::Host(msg) => write!(f, "host runtime failed: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Submit(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SubmitError> for Error {
    fn from(e: SubmitError) -> Self {
        Error::Submit(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_source() {
        let e = Error::from(SubmitError::ShutDown);
        assert_eq!(e.to_string(), "pool is shut down");
        assert!(std::error::Error::source(&e).is_some());
        let e = Error::Job("boom".into());
        assert!(e.to_string().contains("boom"));
        assert!(std::error::Error::source(&e).is_none());
        let e = Error::GridMismatch { graph_nb: 4, matrix_nb: 5 };
        assert!(e.to_string().contains("4x4"));
        let e = Error::UnknownWorkload("qr".into());
        assert!(e.to_string().contains("qr"));
        let e = Error::KernelTable { ops: 4, kernels: 3 };
        assert!(e.to_string().contains('3'));
        let e = Error::CrossPoolDependency;
        assert!(e.to_string().contains("different"));
        let e = Error::UnknownJob;
        assert!(e.to_string().contains("retired"));
        assert!(std::error::Error::source(&e).is_none());
    }
}
