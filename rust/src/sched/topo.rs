//! Worker topology: affinity domains and nearest-first victim orders
//! for the locality-aware work-stealing layer.
//!
//! The paper's GPRM places tasks statically and never steals; our
//! executors steal, and on any machine with more than one cache or
//! memory domain a steal's cost depends on *where* the victim sits.
//! [`Topology`] captures the minimum structure needed to exploit
//! that: the worker team is split into `domains` contiguous affinity
//! domains (on Linux, workers are additionally pinned to cores via
//! the `sched_setaffinity` FFI in [`crate::coordinator::pool`]), and
//! every worker gets a precomputed **victim order** — all other
//! workers sorted own-domain-first, then by domain distance, with a
//! seeded-random rotation inside each distance ring so concurrent
//! thieves don't convoy on the same victim.
//!
//! The virtual-time counterpart is
//! [`crate::tilesim::SchedModel::LocalitySteal`], which prices this
//! exact policy on the simulated mesh and predicted the
//! random-vs-nearest crossover before the host measured it.

/// Affinity-domain layout of a worker team.
///
/// Workers `0..n_workers` are split into `domains` contiguous ranges:
/// worker `w` belongs to domain `w*domains/n` and, inversely, domain
/// `d` holds workers `ceil(d*n/domains) .. ceil((d+1)*n/domains)` —
/// [`Topology::workers_of`] is the exact inverse of
/// [`Topology::domain_of`] even when `domains` does not divide `n`,
/// and the simulator uses the same arithmetic. `domains` is clamped
/// to `[1, n_workers]` at construction, so
/// every domain is nonempty and `domains == 1` means "no topology" —
/// every distance is zero and the victim order degenerates to a
/// seeded-rotated ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    n_workers: usize,
    domains: usize,
}

/// SplitMix64 — the same tiny seeded mixer the scenario engine uses:
/// deterministic, stateless, good enough to decorrelate per-worker
/// ring rotations.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl Topology {
    /// Lay out `n_workers` workers over `domains` contiguous affinity
    /// domains. `domains` is clamped to `[1, n_workers]`; `n_workers`
    /// must be at least 1.
    pub fn new(n_workers: usize, domains: usize) -> Self {
        assert!(n_workers >= 1, "a team needs at least one worker");
        Self { n_workers, domains: domains.clamp(1, n_workers) }
    }

    /// Workers in the team.
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Affinity domains (post-clamp: `1 <= domains <= n_workers`).
    pub fn domains(&self) -> usize {
        self.domains
    }

    /// Domain of worker `w` — contiguous ranges, same formula as the
    /// simulator's `SchedModel::LocalitySteal`.
    pub fn domain_of(&self, w: usize) -> usize {
        w * self.domains / self.n_workers
    }

    /// Distance between two workers' domains (0 = same domain).
    pub fn domain_distance(&self, a: usize, b: usize) -> usize {
        self.domain_of(a).abs_diff(self.domain_of(b))
    }

    /// The contiguous worker range of domain `d` — the exact inverse
    /// of [`Topology::domain_of`]: `w` is in `workers_of(d)` iff
    /// `domain_of(w) == d`. The ceiling split is forced by the floor
    /// in `domain_of` (`floor(w*D/n) = d  ⟺  ceil(d*n/D) <= w <
    /// ceil((d+1)*n/D)`); a floor split here would disagree with the
    /// membership formula whenever `domains` does not divide
    /// `n_workers`.
    pub fn workers_of(&self, d: usize) -> std::ops::Range<usize> {
        let lo = (d * self.n_workers).div_ceil(self.domains);
        let hi = ((d + 1) * self.n_workers).div_ceil(self.domains);
        lo..hi
    }

    /// Core a worker pins to on an `n_cores` machine: domains are
    /// contiguous worker ranges, so contiguous core ids keep a domain
    /// on neighbouring cores (sharing L2/LLC where the machine has
    /// them).
    pub fn core_of(&self, w: usize, n_cores: usize) -> usize {
        w % n_cores.max(1)
    }

    /// Worker `w`'s steal-victim order: every other worker, sorted
    /// own-domain-first then by domain distance, with a
    /// `seed`-derived rotation *within* each equal-distance ring so
    /// different workers (and different seeds) probe the ring from
    /// different starting points. Deterministic for a given
    /// `(w, seed)`; always a permutation of the other workers.
    pub fn victim_order(&self, w: usize, seed: u64) -> Vec<usize> {
        let n = self.n_workers;
        if n <= 1 {
            return Vec::new();
        }
        let rot = splitmix64(seed ^ w as u64) as usize % n;
        let start = (w + 1 + rot) % n;
        let ring_pos = |v: usize| (v + n - start) % n;
        let mut order: Vec<usize> = (0..n).filter(|&v| v != w).collect();
        order.sort_by_key(|&v| (self.domain_distance(w, v), ring_pos(v)));
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn domains_are_contiguous_and_cover_the_team() {
        for n in 1..=16 {
            for d in 1..=5 {
                let t = Topology::new(n, d);
                let mut covered = 0;
                for dom in 0..t.domains() {
                    let r = t.workers_of(dom);
                    assert!(!r.is_empty(), "n={n} d={d}: empty domain {dom}");
                    for w in r.clone() {
                        assert_eq!(t.domain_of(w), dom);
                    }
                    assert_eq!(r.start, covered, "domains must be contiguous");
                    covered = r.end;
                }
                assert_eq!(covered, n, "domains must cover all workers");
            }
        }
    }

    #[test]
    fn domains_clamp_to_team_size() {
        let t = Topology::new(3, 8);
        assert_eq!(t.domains(), 3);
        let t = Topology::new(4, 0);
        assert_eq!(t.domains(), 1);
    }

    #[test]
    fn victim_order_is_a_distance_sorted_permutation() {
        // The satellite's property test: for every worker, the victim
        // order is exactly a permutation of the other workers, with
        // nondecreasing domain distance and the own domain first.
        for (n, d, seed) in
            [(2, 2, 1u64), (7, 2, 9), (8, 2, 42), (12, 4, 7), (16, 3, 0)]
        {
            let t = Topology::new(n, d);
            for w in 0..n {
                let order = t.victim_order(w, seed);
                let mut sorted = order.clone();
                sorted.sort_unstable();
                let expect: Vec<usize> = (0..n).filter(|&v| v != w).collect();
                assert_eq!(
                    sorted, expect,
                    "n={n} d={d} w={w}: victims must be the other workers"
                );
                let dists: Vec<usize> = order
                    .iter()
                    .map(|&v| t.domain_distance(w, v))
                    .collect();
                assert!(
                    dists.windows(2).all(|p| p[0] <= p[1]),
                    "n={n} d={d} w={w}: distances {dists:?} not sorted"
                );
                // Every own-domain sibling precedes every outsider.
                let own = t.workers_of(t.domain_of(w)).len() - 1;
                assert!(
                    dists[..own].iter().all(|&x| x == 0),
                    "n={n} d={d} w={w}: own domain must come first"
                );
            }
        }
    }

    #[test]
    fn victim_order_is_deterministic_and_seed_rotates_rings() {
        let t = Topology::new(8, 2);
        for w in 0..8 {
            assert_eq!(t.victim_order(w, 5), t.victim_order(w, 5));
        }
        // Some seed pair must reorder at least one worker's rings —
        // the rotation is what spreads concurrent thieves out.
        let differs = (0..8).any(|w| {
            t.victim_order(w, 1) != t.victim_order(w, 2)
        });
        assert!(differs, "seed must influence ring rotation");
    }

    #[test]
    fn single_worker_has_no_victims() {
        assert!(Topology::new(1, 1).victim_order(0, 3).is_empty());
    }

    #[test]
    fn core_mapping_wraps() {
        let t = Topology::new(8, 2);
        assert_eq!(t.core_of(3, 4), 3);
        assert_eq!(t.core_of(5, 4), 1);
        assert_eq!(t.core_of(5, 0), 0, "zero cores must not divide by zero");
    }
}
